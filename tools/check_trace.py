"""Chrome-trace validator for ``--trace-out`` exports: proves the file
any launcher wrote is a well-formed ``trace_event`` JSON that Perfetto /
``chrome://tracing`` will load, and (optionally) that the sampling
pipeline's worker-thread ``pipe_prepare`` spans really overlap
main-thread ``execute`` spans — the whole point of ``--pipeline-depth``.
Wired into ``make trace-check`` (part of ``make check``).

Checks:
  * top level is ``{"traceEvents": [...]}``;
  * every event carries ``ph``/``name``/``pid``/``tid``/``ts`` with
    ``ph`` in {M, B, E} and a finite numeric ``ts``;
  * within each (pid, tid) track, non-metadata timestamps are
    monotonically non-decreasing in file order;
  * B/E events are LIFO-balanced per track with matching names (a
    dangling B or stray E would render as a torn bar);
  * every B-span name is a phase ``repro.gcn.obs.KNOWN_PHASES`` knows
    about, so dashboards keyed on phase names never see strangers.

    PYTHONPATH=src python tools/check_trace.py TRACE.json \
        [--require-overlap]
    python tools/check_trace.py --selftest
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
try:
    from repro.gcn.obs import KNOWN_PHASES
except ImportError:  # run as a bare script without PYTHONPATH=src
    sys.path.insert(0, str(ROOT / "src"))
    from repro.gcn.obs import KNOWN_PHASES

#: thread-name prefix SamplePipeline gives its workers
PIPE_THREAD_PREFIX = "gcn-pipe"

REQUIRED_KEYS = ("ph", "name", "pid", "tid", "ts")


class TraceError(Exception):
    """One validation failure, with enough context to locate it."""


def validate(doc: dict) -> dict:
    """Validate one parsed trace document; returns summary stats.
    Raises :class:`TraceError` on the first violation."""
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        raise TraceError("top level must be {'traceEvents': [...]}")
    events = doc["traceEvents"]
    spans = 0
    threads: dict[tuple, str] = {}
    last_ts: dict[tuple, float] = {}
    stacks: dict[tuple, list[str]] = {}
    for i, ev in enumerate(events):
        for k in REQUIRED_KEYS:
            if k not in ev:
                raise TraceError(f"event {i} missing key {k!r}: {ev}")
        ph, ts = ev["ph"], ev["ts"]
        if ph not in ("M", "B", "E"):
            raise TraceError(f"event {i} has unknown ph {ph!r}")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts):
            raise TraceError(f"event {i} has non-finite ts {ts!r}")
        track = (ev["pid"], ev["tid"])
        if ph == "M":
            if ev["name"] == "thread_name":
                threads[track] = ev.get("args", {}).get("name", "")
            continue
        if ts < last_ts.get(track, 0.0):
            raise TraceError(
                f"event {i} ts {ts} < previous {last_ts[track]} on "
                f"track {track} (timestamps must be monotonic)")
        last_ts[track] = ts
        stack = stacks.setdefault(track, [])
        if ph == "B":
            if ev["name"] not in KNOWN_PHASES:
                raise TraceError(
                    f"event {i} span name {ev['name']!r} not in "
                    f"KNOWN_PHASES {sorted(KNOWN_PHASES)}")
            stack.append(ev["name"])
            spans += 1
        else:  # E
            if not stack:
                raise TraceError(
                    f"event {i}: E {ev['name']!r} with no open B on "
                    f"track {track}")
            opened = stack.pop()
            if opened != ev["name"]:
                raise TraceError(
                    f"event {i}: E {ev['name']!r} closes B {opened!r} "
                    f"on track {track} (names must match LIFO)")
    for track, stack in stacks.items():
        if stack:
            raise TraceError(
                f"track {track} ends with unclosed span(s) {stack}")
    return {"events": len(events), "spans": spans, "threads": threads}


def _intervals(events, want_name: str, tids) -> list[tuple]:
    """(start, end) pairs of ``want_name`` spans on the given tids,
    reconstructed from balanced B/E order (validate() ran first)."""
    out, open_ts = [], {}
    for ev in events:
        if ev["ph"] not in ("B", "E") or ev["name"] != want_name:
            continue
        track = (ev["pid"], ev["tid"])
        if ev["tid"] not in tids:
            continue
        if ev["ph"] == "B":
            open_ts.setdefault(track, []).append(ev["ts"])
        else:
            out.append((open_ts[track].pop(), ev["ts"]))
    return sorted(out)


def _merge(iv: list[tuple]) -> list[tuple]:
    merged: list[list] = []
    for s, e in iv:
        if merged and s <= merged[-1][1]:
            merged[-1][1] = max(merged[-1][1], e)
        else:
            merged.append([s, e])
    return [tuple(m) for m in merged]


def pipeline_overlap_us(doc: dict, threads: dict) -> float:
    """Microseconds during which a ``gcn-pipe`` worker's
    ``pipe_prepare`` span ran concurrently with an ``execute`` span on
    any other thread — the observable signature of pipelined
    sampling."""
    pipe_tids = {tid for (_, tid), name in threads.items()
                 if name.startswith(PIPE_THREAD_PREFIX)}
    other_tids = {ev["tid"] for ev in doc["traceEvents"]
                  if ev["tid"] not in pipe_tids}
    prep = _merge(_intervals(doc["traceEvents"], "pipe_prepare",
                             pipe_tids))
    execute = _merge(_intervals(doc["traceEvents"], "execute",
                                other_tids))
    total, j = 0.0, 0
    for s, e in prep:
        while j < len(execute) and execute[j][1] <= s:
            j += 1
        k = j
        while k < len(execute) and execute[k][0] < e:
            total += min(e, execute[k][1]) - max(s, execute[k][0])
            k += 1
    return total


def check_file(path: Path, require_overlap: bool) -> int:
    doc = json.loads(path.read_text())
    try:
        stats = validate(doc)
    except TraceError as e:
        print(f"check_trace: {path}: INVALID: {e}")
        return 1
    overlap = pipeline_overlap_us(doc, stats["threads"])
    names = sorted(set(stats["threads"].values()))
    print(f"check_trace: {path}: OK — {stats['spans']} spans across "
          f"{len(stats['threads'])} thread(s) {names}; "
          f"pipeline prepare/execute overlap {overlap / 1e3:.2f} ms")
    if require_overlap and overlap <= 0.0:
        print("check_trace: FAIL — --require-overlap set but no "
              "gcn-pipe pipe_prepare span overlaps an execute span "
              "on another thread")
        return 1
    return 0


# ---------------------------------------------------------------------------
# selftest
# ---------------------------------------------------------------------------


def _doc(events):
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def _ev(ph, name, tid, ts, **kw):
    return {"ph": ph, "name": name, "pid": 1, "tid": tid, "ts": ts, **kw}


def selftest() -> int:
    ok = _doc([
        _ev("M", "thread_name", 1, 0.0, args={"name": "MainThread"}),
        _ev("M", "thread_name", 2, 0.0, args={"name": "gcn-pipe-0"}),
        _ev("B", "execute", 1, 10.0), _ev("E", "execute", 1, 40.0),
        _ev("B", "pipe_prepare", 2, 20.0),
        _ev("E", "pipe_prepare", 2, 50.0),
    ])
    stats = validate(ok)
    assert stats["spans"] == 2, stats
    ov = pipeline_overlap_us(ok, stats["threads"])
    assert abs(ov - 20.0) < 1e-9, ov  # [20, 40) of [10, 40) x [20, 50)

    bad = {
        "unbalanced": [_ev("B", "execute", 1, 1.0)],
        "stray E": [_ev("E", "execute", 1, 1.0)],
        "name mismatch": [_ev("B", "execute", 1, 1.0),
                          _ev("E", "sample", 1, 2.0)],
        "non-monotonic": [_ev("B", "execute", 1, 5.0),
                          _ev("E", "execute", 1, 3.0)],
        "unknown phase": [_ev("B", "frobnicate", 1, 1.0),
                          _ev("E", "frobnicate", 1, 2.0)],
        "missing key": [{"ph": "B", "name": "execute", "pid": 1,
                         "ts": 1.0}],
    }
    for label, events in bad.items():
        try:
            validate(_doc(events))
        except TraceError:
            continue
        raise AssertionError(f"selftest: {label!r} was not rejected")
    print("check_trace: selftest OK")
    return 0


def main(argv) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", nargs="?", help="trace_event JSON to check")
    ap.add_argument("--require-overlap", action="store_true",
                    help="additionally fail unless a gcn-pipe "
                         "pipe_prepare span overlaps an execute span "
                         "on another thread")
    ap.add_argument("--selftest", action="store_true",
                    help="run the checker's own fixture suite and exit")
    args = ap.parse_args(argv[1:])
    if args.selftest:
        return selftest()
    if not args.trace:
        ap.error("trace path required (or --selftest)")
    return check_file(Path(args.trace), args.require_overlap)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
