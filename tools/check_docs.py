"""Docs snippet checker: extract fenced ```python blocks from README.md
and docs/*.md and EXECUTE them, so the documented quickstarts can never
rot. Wired into `make docs-check`.

Rules:
  * only ```python fences run (bash/text fences are illustrative);
  * blocks in one file share a namespace, in order, like a REPL session —
    later blocks may use names defined by earlier ones;
  * a fence immediately preceded by a line containing
    `<!-- docs-check: skip -->` is skipped (for intentionally
    non-runnable fragments);
  * jax is forced to 8 host devices BEFORE any import, so snippets may
    build multi-device meshes exactly as users would on real hardware.

    PYTHONPATH=src python tools/check_docs.py [files...]
"""
from __future__ import annotations

import os
import re
import sys
import traceback
from pathlib import Path

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

FENCE = re.compile(r"^```python[ \t]*$")
SKIP_MARK = "docs-check: skip"


def blocks_of(text: str):
    """Yield (start_line, source, skipped) for each python fence —
    skipped fences are surfaced (not silently dropped) so the runner
    can report exactly which documented snippets are NOT executed."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        if FENCE.match(lines[i]):
            skip = i > 0 and SKIP_MARK in lines[i - 1]
            j = i + 1
            while j < len(lines) and not lines[j].startswith("```"):
                j += 1
            yield i + 2, "\n".join(lines[i + 1:j]), skip
            i = j + 1
        else:
            i += 1


def check_file(path: Path) -> int:
    ns: dict = {"__name__": "__docs_check__", "__file__": str(path)}
    failures = 0
    n = skipped = 0
    for lineno, src, skip in blocks_of(path.read_text()):
        if skip:
            skipped += 1
            print(f"# SKIP {path.name}:{lineno} ({SKIP_MARK})", flush=True)
            continue
        n += 1
        try:
            code = compile(src, f"{path.name}:{lineno}", "exec")
            exec(code, ns)  # noqa: S102 - executing our own docs is the point
        except Exception:
            failures += 1
            print(f"FAIL {path.name}:{lineno}", flush=True)
            traceback.print_exc()
    note = f" ({skipped} skipped)" if skipped else ""
    print(f"# {path.relative_to(ROOT)}: {n - failures}/{n} blocks OK{note}",
          flush=True)
    return failures


def main(argv) -> int:
    files = ([Path(a).resolve() for a in argv[1:]] if len(argv) > 1 else
             [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))])
    failures = sum(check_file(f) for f in files if f.exists())
    if failures:
        print(f"docs-check: {failures} block(s) FAILED")
        return 1
    print("docs-check: all snippet blocks ran")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
