"""glm4-9b [hf:THUDM/glm-4-9b; hf].

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552. RoPE, SwiGLU."""
from repro.config import LMConfig, register_lm


def full() -> LMConfig:
    return LMConfig(
        name="glm4-9b",
        family="dense",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=151_552,
        rope_theta=10_000.0,
        act="swiglu",
        source="hf:THUDM/glm-4-9b; hf",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="glm4-9b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=96,
        vocab_size=512,
    )


register_lm("glm4-9b", full=full, smoke=smoke)
