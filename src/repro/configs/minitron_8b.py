"""minitron-8b — width-pruned Nemotron-4 [arXiv:2407.14679; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000. RoPE, squared-ReLU
MLP family (Nemotron uses relu^2, non-gated)."""
from repro.config import LMConfig, register_lm


def full() -> LMConfig:
    return LMConfig(
        name="minitron-8b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=256_000,
        rope_theta=500_000.0,
        act="relu2",
        norm="layernorm",
        source="arXiv:2407.14679; hf",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="minitron-8b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        act="relu2",
        norm="layernorm",
    )


register_lm("minitron-8b", full=full, smoke=smoke)
