"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407; unverified].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768. RoPE, SwiGLU."""
from repro.config import LMConfig, register_lm


def full() -> LMConfig:
    return LMConfig(
        name="mistral-large-123b",
        family="dense",
        num_layers=88,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=32_768,
        rope_theta=1_000_000.0,
        act="swiglu",
        source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="mistral-large-123b-smoke",
        family="dense",
        num_layers=3,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
    )


register_lm("mistral-large-123b", full=full, smoke=smoke)
