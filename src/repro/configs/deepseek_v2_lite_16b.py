"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434; hf].

27L d_model=2048 16H (MLA kv_lora=512, rope_dim=64) vocab=102400.
MoE: 64 routed experts top-6 + 2 shared, moe_d_ff=1408; first layer is a
dense MLP with d_ff=10944 (hf config)."""
from repro.config import BlockSpec, LMConfig, register_lm


def _blocks(n: int) -> tuple[BlockSpec, ...]:
    return tuple(
        BlockSpec(mixer="mla", ffn="dense" if i == 0 else "moe") for i in range(n)
    )


def full() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,  # MLA: latent shared across heads; kept for bookkeeping
        head_dim=192,  # qk_nope 128 + qk_rope 64
        d_ff=10944,  # dense first layer
        vocab_size=102_400,
        blocks=_blocks(27),
        kv_lora_rank=512,
        qk_rope_dim=64,
        qk_nope_dim=128,
        v_head_dim=128,
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        moe_d_ff=1408,
        rope_theta=10_000.0,
        act="swiglu",
        source="arXiv:2405.04434; hf",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-lite-16b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=24,
        d_ff=160,
        vocab_size=512,
        blocks=_blocks(2),
        kv_lora_rank=32,
        qk_rope_dim=8,
        qk_nope_dim=16,
        v_head_dim=16,
        num_experts=8,
        num_shared_experts=1,
        top_k=2,
        moe_d_ff=48,
    )


register_lm("deepseek-v2-lite-16b", full=full, smoke=smoke)
