"""zamba2-2.7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf].

54L d_model=2560 32H (GQA kv=32, used only in the shared block) d_ff=10240
vocab=32000, ssm_state=64. Mamba2 layers carry no per-layer MLP; the MLP
(d_ff=10240) lives inside the shared transformer block (one set of weights,
reused), applied every 6th layer per the paper's interleaving. (The
published model adds per-invocation LoRA deltas to the shared block; we
share the weights exactly — noted in DESIGN.md.)"""
from repro.config import BlockSpec, LMConfig, register_lm


def _blocks(n: int, period: int) -> tuple[BlockSpec, ...]:
    return tuple(
        BlockSpec(mixer="mamba2", ffn="none", shared_attn=(i % period == period - 1))
        for i in range(n)
    )


def full() -> LMConfig:
    return LMConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32_000,
        blocks=_blocks(54, 6),
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        tie_embeddings=True,
        source="arXiv:2411.15242; hf",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="zamba2-2.7b-smoke",
        family="hybrid",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        blocks=_blocks(4, 2),
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=16,
        tie_embeddings=True,
    )


register_lm("zamba2-2.7b", full=full, smoke=smoke)
