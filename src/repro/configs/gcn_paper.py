"""The paper's own GCN workloads (Table 3): GCN / GIN / GraphSAGE on
Reddit / Orkut / LiveJournal (degree-matched RMAT twins offline) and
RMAT-19..23 synthetic graphs."""
from repro.config import GCNConfig, GraphSpec, register_gcn

# Table 3 — real graphs get degree/size-matched RMAT twins in this
# offline container (SNAP data is not redistributable here); the synthetic
# RMAT-19..23 rows are generated exactly as specified.
GRAPHS: dict[str, GraphSpec] = {
    "RD": GraphSpec("RD", 233_000, 114_000_000, 602, 128, avg_degree=489.0,
                    rmat_seed=19, synthetic_twin_of="Reddit"),
    "OR": GraphSpec("OR", 3_000_000, 117_000_000, 500, 128, avg_degree=39.0,
                    rmat_seed=23, synthetic_twin_of="Orkut"),
    "LJ": GraphSpec("LJ", 5_000_000, 69_000_000, 500, 128, avg_degree=14.0,
                    rmat_seed=29, synthetic_twin_of="LiveJournal"),
    "RM19": GraphSpec("RM19", 1 << 19, 16_800_000, 512, 128, avg_degree=32.0, rmat_seed=31),
    "RM20": GraphSpec("RM20", 1 << 20, 33_600_000, 512, 128, avg_degree=32.0, rmat_seed=37),
    "RM21": GraphSpec("RM21", 1 << 21, 67_100_000, 512, 128, avg_degree=32.0, rmat_seed=41),
    "RM22": GraphSpec("RM22", 1 << 22, 134_000_000, 512, 128, avg_degree=32.0, rmat_seed=43),
    "RM23": GraphSpec("RM23", 1 << 23, 268_000_000, 512, 128, avg_degree=32.0, rmat_seed=47),
}

# small graphs for smoke tests / CPU execution
SMOKE_GRAPHS: dict[str, GraphSpec] = {
    name: GraphSpec(f"{name}-smoke", 1 << 10, 1 << 14, 32, 16,
                    avg_degree=16.0, rmat_seed=g.rmat_seed)
    for name, g in GRAPHS.items()
}


def _register(model: str):
    for gname in GRAPHS:
        arch = f"gcn-{model}-{gname.lower()}"

        def full(model=model, gname=gname) -> GCNConfig:
            # paper-scale serving always wants the ELL/MXU aggregation
            # kernel (block_slots=128 mirrors the paper's 1x128 systolic
            # reduction rows); off-TPU it runs in interpret mode
            return GCNConfig(name=f"{model}.{gname}", model=model,
                             graph=GRAPHS[gname], agg_impl="pallas")

        def smoke(model=model, gname=gname) -> GCNConfig:
            # smoke stays on auto-resolution: "jnp" on CPU test runners,
            # "pallas" when the container actually has a TPU
            return GCNConfig(
                name=f"{model}.{gname}-smoke",
                model=model,
                graph=SMOKE_GRAPHS[gname],
                agg_buffer_bytes=16 << 10,
                agg_impl="auto",
            )

        register_gcn(arch, full=full, smoke=smoke)


for _m in ("gcn", "gin", "sage"):
    _register(_m)
