"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay
[arXiv:2404.05892; unverified].

24L d_model=2048 d_ff=7168 vocab=65536. Token mixer = WKV6 linear
attention with per-channel data-dependent decay; O(1) state per token."""
from repro.config import LMConfig, register_lm


def full() -> LMConfig:
    return LMConfig(
        name="rwkv6-1.6b",
        family="ssm",
        num_layers=24,
        d_model=2048,
        num_heads=32,  # wkv heads = d_model / wkv_head_dim
        num_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab_size=65_536,
        default_mixer="wkv6",
        wkv_head_dim=64,
        act="relu2",  # rwkv channel-mix uses squared relu
        norm="layernorm",
        source="arXiv:2404.05892; unverified",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="rwkv6-1.6b-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        default_mixer="wkv6",
        wkv_head_dim=16,
        act="relu2",
        norm="layernorm",
    )


register_lm("rwkv6-1.6b", full=full, smoke=smoke)
