"""Importing this package registers every assigned architecture."""
from . import (  # noqa: F401
    deepseek_v2_lite_16b,
    gcn_paper,
    glm4_9b,
    internvl2_76b,
    minitron_8b,
    mistral_large_123b,
    mixtral_8x7b,
    rwkv6_1p6b,
    starcoder2_15b,
    whisper_tiny,
    zamba2_2p7b,
)
