"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2, SWA
window 4096 (the SWA makes long_500k decode O(window))."""
from repro.config import LMConfig, register_lm


def full() -> LMConfig:
    return LMConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32_000,
        default_ffn="moe",
        num_experts=8,
        top_k=2,
        moe_d_ff=14336,
        sliding_window=4096,
        rope_theta=1_000_000.0,
        act="swiglu",
        source="arXiv:2401.04088; hf",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="mixtral-8x7b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        default_ffn="moe",
        num_experts=4,
        top_k=2,
        moe_d_ff=128,
        sliding_window=32,
    )


register_lm("mixtral-8x7b", full=full, smoke=smoke)
