"""whisper-tiny — encoder-decoder audio model [arXiv:2212.04356; unverified].

4L (enc) + 4L (dec) d_model=384 6H (kv=6) d_ff=1536 vocab=51865. The conv
audio frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, 1500, d_model)."""
from repro.config import LMConfig, register_lm


def full() -> LMConfig:
    return LMConfig(
        name="whisper-tiny",
        family="audio",
        num_layers=4,  # decoder layers
        encoder_layers=4,
        encoder_seq_len=1500,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51_865,
        frontend="audio_stub",
        frontend_seq_len=1500,
        norm="layernorm",
        act="gelu",
        rope_theta=0.0,  # whisper uses learned/sinusoidal positions
        tie_embeddings=True,
        source="arXiv:2212.04356; unverified",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="whisper-tiny-smoke",
        family="audio",
        num_layers=2,
        encoder_layers=2,
        encoder_seq_len=64,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        frontend="audio_stub",
        frontend_seq_len=64,
        norm="layernorm",
        act="gelu",
        rope_theta=0.0,
        tie_embeddings=True,
    )


register_lm("whisper-tiny", full=full, smoke=smoke)
