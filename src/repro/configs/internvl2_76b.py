"""internvl2-76b — InternViT + LLM backbone [arXiv:2404.16821; unverified].

Backbone only per the assignment: 80L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256. The InternViT frontend is a STUB: input_specs()
provides precomputed patch embeddings prepended to the token stream."""
from repro.config import LMConfig, register_lm


def full() -> LMConfig:
    return LMConfig(
        name="internvl2-76b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128_256,
        rope_theta=500_000.0,
        frontend="patch_stub",
        frontend_seq_len=256,  # one image tile = 256 patch embeddings
        source="arXiv:2404.16821; unverified",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="internvl2-76b-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        frontend="patch_stub",
        frontend_seq_len=8,
    )


register_lm("internvl2-76b", full=full, smoke=smoke)
