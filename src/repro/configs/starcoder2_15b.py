"""starcoder2-15b [arXiv:2402.19173; hf].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152. RoPE, GeLU MLP,
LayerNorm (starcoder2 uses standard LN + gelu)."""
from repro.config import LMConfig, register_lm


def full() -> LMConfig:
    return LMConfig(
        name="starcoder2-15b",
        family="dense",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab_size=49_152,
        rope_theta=100_000.0,
        act="gelu",
        norm="layernorm",
        source="arXiv:2402.19173; hf",
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="starcoder2-15b-smoke",
        family="dense",
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
        act="gelu",
        norm="layernorm",
    )


register_lm("starcoder2-15b", full=full, smoke=smoke)
