"""Batched serving engine: continuous-batching decode over a fixed slot
pool (the paper's domain is inference; this is the LM-side serving
substrate used by examples/lm_serve.py and the decode dry-run cells).

Design: N slots, each holding one request's KV-cache rows. Prefill fills
a slot (one request at a time — prefill and decode phases are separately
jitted, as in production engines); every decode step advances ALL active
slots one token (padding slots just recompute garbage — the standard
static-shape trade). Finished requests free their slot for the next
queued request.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LMConfig
from repro.models import lm


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: LMConfig, params, *, slots: int = 4,
                 max_len: int = 512, rules=None, temperature: float = 0.0,
                 cache_dtype=None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.rules = rules
        self.temperature = temperature
        # cache_dtype: KV-cache precision (default bf16 for memory);
        # float32 makes greedy decode bit-stable against the
        # single-request path (used by the parity test)
        self.state = lm.init_decode_state(
            cfg, slots, max_len,
            **({"dtype": cache_dtype} if cache_dtype is not None else {}))
        self.active: list[Request | None] = [None] * slots
        self.pos = np.zeros(slots, np.int32)  # per-slot lengths
        self.queue: list[Request] = []

        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)

    # --- jitted bodies -------------------------------------------------
    def _prefill_impl(self, params, caches, tokens, slot):
        """Prefill one request into cache rows [slot]. tokens: (1, S)."""
        sub = jax.tree.map(
            lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
            caches)
        st = lm.DecodeState(caches=sub, pos=jnp.zeros((), jnp.int32))
        last_h, st2 = lm.prefill(self.cfg, params, tokens, st,
                                 rules=self.rules)
        merged = jax.tree.map(
            lambda full, part: jax.lax.dynamic_update_slice_in_dim(
                full, part.astype(full.dtype), slot, axis=1),
            caches, st2.caches)
        W = lm.lm_head_matrix(params.get("head", {}), params["embed"], self.cfg)
        logits = (last_h @ W.astype(last_h.dtype)).astype(jnp.float32)
        return logits[0], merged

    def _decode_impl(self, params, caches, tokens, pos):
        """One decode step for all slots. tokens: (slots, 1); pos: (slots,)."""
        # per-slot positions differ: run with per-slot pos via vmap-style
        # masking — we use the max pos for cache writes at distinct slots,
        # so each slot's cache row is updated at its own position using
        # a scatter built from pos.
        st = lm.DecodeState(caches=caches, pos=pos)
        hidden, new_caches, _ = lm.forward_hidden(
            self.cfg, params, tokens, rules=self.rules, remat=False,
            caches=caches, pos=pos, positions=pos[:, None])
        W = lm.lm_head_matrix(params.get("head", {}), params["embed"], self.cfg)
        logits = (hidden[:, -1] @ W.astype(hidden.dtype)).astype(jnp.float32)
        return logits, new_caches

    # --- scheduling ----------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                toks = jnp.asarray(req.prompt[None, :])
                logits, merged = self._prefill(
                    self.params, self.state.caches, toks, s)
                self.state = lm.DecodeState(merged, self.state.pos,
                                            self.state.memory)
                self.pos[s] = len(req.prompt)
                nxt = int(jnp.argmax(logits))
                req.out.append(nxt)
                self.active[s] = req

    def step(self):
        """One engine tick: admit + one decode step for all active slots."""
        self._admit()
        if not any(r is not None for r in self.active):
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for s, r in enumerate(self.active):
            if r is not None and r.out:
                toks[s, 0] = r.out[-1]
        logits, new_caches = self._decode(
            self.params, self.state.caches, jnp.asarray(toks),
            jnp.asarray(self.pos))
        self.state = lm.DecodeState(new_caches, self.state.pos,
                                    self.state.memory)
        for s, r in enumerate(self.active):
            if r is None:
                continue
            self.pos[s] += 1
            nxt = int(jnp.argmax(logits[s]))
            r.out.append(nxt)
            if len(r.out) >= r.max_new or self.pos[s] >= self.max_len - 1:
                r.done = True
                self.active[s] = None

    def run_until_done(self, max_ticks: int = 1000):
        done: list[Request] = []
        for _ in range(max_ticks):
            self.step()
            if not self.queue and not any(self.active):
                break
        return done
