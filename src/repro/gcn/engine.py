"""``GCNEngine`` — the one-object session API for MultiGCN execution.

The paper's pipeline is "one-time host-side graph mapping, then replay
the static relay schedule many times" (§4.3). The engine owns everything
that mapping produces so callers never rebuild it by hand:

  * a single ``mesh_dims`` spec from which BOTH the jax ``Mesh`` and the
    planner's ``TorusMesh`` are derived (they can never disagree);
  * a process-wide **plan cache** keyed by (graph fingerprint, model,
    message-passing model, rounds, mesh dims, buffer bytes, bidir) so
    switching among oppe/oppr/oppm — or rebuilding an engine on the same
    workload — reuses the host-side mapping work;
  * the **aggregation backend** (``agg_impl``): the executor's Compute
    step runs either as a COO scatter-add (``"jnp"``) or through the
    Pallas blocked-ELL SpMM kernel (``"pallas"``; interpret mode
    off-TPU), with the host-side ELL layout cached alongside the plan;
  * the **compiled exchange**: one jitted layer step (shard_map exchange
    + combination) per aggregation backend, reused across layers/calls;
  * the message-passing-model registry (:mod:`repro.gcn.registry`), so
    GCN/GIN/SAGE and user-registered models share one execution path.

Typical use::

    eng = GCNEngine.build(cfg, graph, (4, 2))
    params = eng.init_params(jax.random.PRNGKey(0), [64, 16])
    out = eng.forward(feats)              # (V, F) in -> (V, F_out) out
    pal = eng.forward(feats, agg_impl="pallas")   # ELL-kernel backend
    ref = eng.reference(feats)            # single-device oracle
    st = eng.stats()                      # link bytes + agg traffic

``forward`` accepts either a global host ``(V, F)`` array (sharded and
unsharded transparently) or a pre-sharded ``(*dims, Vp, F)`` device
array, and returns the same form it was given.

Cache-invalidation contract (``PlanKey``)
-----------------------------------------

``PlanKey`` is the full identity of everything the engine caches for a
workload. Its fields split into two groups:

  * **plan-shaping** fields (graph fingerprint, model + registry
    generation, message-passing model, rounds, mesh dims, buffer bytes,
    alpha, feat_in, bidir) — any change means a genuinely different
    relay schedule, so the plan cache misses and a new ``CommPlan`` is
    built;
  * **aggregation-backend** fields (``agg_impl``, ``ell_block_slots``,
    ``ell_edge_align``) — they select/shape the Compute-step encoding of
    the SAME schedule. :meth:`PlanKey.plan_identity` zeroes them, and
    the plan cache is keyed on that sub-key, so switching backends NEVER
    replans; the ELL layout cache is keyed on the FULL key, so a layout
    can never be served for the wrong plan or the wrong block shape.

Re-registering a model (``register_model(..., overwrite=True)``) bumps
the registry generation baked into every key, so stale engines can keep
running their old spec but can never poison the caches for fresh ones.

All process-wide cached state (plans, ELL layouts, prepared graphs,
compiled layer steps) lives in :mod:`repro.gcn.cache` — the engine is a
thin per-graph *session* over those shared layers, which is what lets
:class:`repro.gcn.service.GCNService` serve many graphs from one
substrate. The module-level ``plan_cache_stats`` / ``clear_plan_cache``
/ ``invalidate_model`` names are kept as aliases of the cache module's
coherent operations.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import GCNConfig
from repro.core import cost_model as cm
from repro.core import gcn_models as gm
from repro.core import jax_compat
from repro.core import message_passing as mp
from repro.core.graph import Graph
from repro.core.partition import RoundPartition, TorusMesh, make_partition
from repro.core.plan import CommPlan, build_plan
from repro.gcn import cache, obs
from repro.gcn.cache import PlanKey, graph_fingerprint
from repro.gcn.registry import ModelSpec, get_model
from repro.kernels.spmm import ops as spmm_ops

resolve_agg_impl = spmm_ops.resolve_impl  # "auto" -> "pallas" | "jnp"

# back-compat aliases: one coherent call clears/reports ALL cache layers
# (plan + ELL + prepared graph + compiled step) — see repro.gcn.cache
plan_cache_stats = cache.cache_stats
clear_plan_cache = cache.clear_all
invalidate_model = cache.invalidate_model


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class GCNEngine:
    """One MultiGCN session: mesh + partition + cached plan + compiled
    exchange. Construct with :meth:`build`."""

    def __init__(self, cfg: GCNConfig, graph: Graph, dims: tuple[int, ...],
                 axis_names: tuple[str, ...], spec: ModelSpec,
                 part: RoundPartition, *, bidir: bool, donate: bool,
                 mesh_jax=None):
        self.cfg = cfg
        self.graph = graph
        self.dims = dims
        self.axis_names = axis_names
        self.model_spec = spec
        self.torus = TorusMesh(dims)
        self.part = part
        self.bidir = bidir
        self.donate = donate
        self.params: list[dict] | None = None
        # lazy state — nothing below touches jax devices or builds a plan
        # until an execution path actually needs it
        self._mesh_jax = mesh_jax
        # construction mode, NOT current materialization: derived-mesh
        # engines must keep one step-cache identity before and after the
        # lazy mesh materializes (all derived meshes over the same
        # dims/names are equal by construction)
        self._mesh_explicit = mesh_jax is not None
        self._graph_fp: str | None = None
        self._plan: CommPlan | None = None
        self._agg_impl: str | None = None  # resolved lazily (touches jax)
        # lazies memoizing shared-cache lookups: device plan arrays per
        # backend, compiled layer steps per (backend, batched) pair,
        # compiled training functions per (kind, backend[, opt]) key.
        # All of these are RELEASED when the shared store evicts this
        # session's plan (repro.gcn.cache registers a weakref), so a
        # long-lived session can no longer pin evicted plans/uploads
        # past the configured byte budget.
        self._plan_dev: dict[str, object] = {}
        self._layer_step: dict[tuple[str, bool], object] = {}
        self._train_fns: dict[tuple, object] = {}
        # batch-size bucketing (forward_batched pads B to powers of two
        # so distinct request counts share one compiled step)
        self._batch_buckets: set[tuple] = set()
        self._bucket_calls = 0
        self._bucket_hits = 0
        # sampling-pipeline telemetry of the LAST fit_sampled run on
        # this engine (set by GCNTrainer; zeros until one runs)
        self._pipeline_stats: dict | None = None
        # layer-major chunked inference (repro.gcn.inference): pow2
        # chunk-bucket ledger (a hit = that padded chunk size already
        # executed on this engine) + the last run's telemetry
        self._chunk_buckets: set[tuple] = set()
        self._chunk_calls = 0
        self._chunk_hits = 0
        self._inference_stats: dict | None = None

    # ---------------- construction ----------------

    @classmethod
    def build(cls, cfg: GCNConfig, graph: Graph,
              mesh_dims: Sequence[int] | None = None, *,
              mesh=None, axis_names: Sequence[str] | None = None,
              bidir: bool = False, donate: bool = False) -> "GCNEngine":
        """Create an engine from ONE mesh spec.

        Pass either ``mesh_dims`` (a tuple like ``(4, 2)``; the jax
        ``Mesh`` is derived lazily when execution first needs devices) or
        an existing jax ``Mesh``/``AbstractMesh`` via ``mesh=`` (dry-run
        path); never both. ``donate=True`` donates the feature buffer to
        each compiled layer step (in-place friendly serving loops).
        """
        if (mesh_dims is None) == (mesh is None):
            raise ValueError("pass exactly one of mesh_dims or mesh")
        if mesh is not None:
            names = tuple(mesh.axis_names)
            dims = tuple(int(mesh.shape[n]) for n in names)
        else:
            dims = tuple(int(d) for d in mesh_dims)
            names = (tuple(axis_names) if axis_names is not None
                     else tuple(f"gcn{i}" for i in range(len(dims))))
        if len(names) != len(dims):
            raise ValueError(f"axis_names {names} vs mesh_dims {dims}")
        spec = get_model(cfg.model)
        tor = TorusMesh(dims)
        part = make_partition(cfg, tor.num_nodes,
                              num_vertices=graph.num_vertices)
        return cls(cfg, graph, dims, names, spec, part,
                   bidir=bidir, donate=donate, mesh_jax=mesh)

    @classmethod
    def from_plan(cls, cfg: GCNConfig, plan: CommPlan,
                  mesh_dims: Sequence[int], *, graph_fp: str,
                  axis_names: Sequence[str] | None = None,
                  name: str = "subplan") -> "GCNEngine":
        """Session over an EXTERNALLY built plan — the sampled
        mini-batch path (``repro.gcn.train.fit_sampled``).

        The plan store is bypassed entirely: the caller owns the plan's
        lifetime (batch plans live in the separate byte-bounded
        ``batch`` layer of :mod:`repro.gcn.cache`), so this session is
        never registered for plan eviction and ``set_cache_budget(plan_
        bytes=...)`` cannot touch it. ``graph_fp`` is the caller's
        content identity for the plan's graph (e.g. a
        ``SampledBatch.fingerprint()``) — it keys the ELL-layout and
        compiled-step stores, so equal fingerprints share and distinct
        ones never collide. The session carries a placeholder edgeless
        graph of ``plan.part.num_vertices`` vertices: execution paths
        (``forward`` / ``loss_and_grad`` / compiled steps / ELL layout
        / stats — all plan-derived) are fully functional, but
        graph-derived paths (``prepared_graph``, ``reference``) see no
        edges — aggregation structure comes from the plan, which
        already encodes the prepared edges."""
        dims = tuple(int(d) for d in mesh_dims)
        if tuple(plan.mesh.dims) != dims:
            raise ValueError(
                f"plan mesh {tuple(plan.mesh.dims)} != mesh_dims {dims}")
        V = plan.part.num_vertices
        placeholder = Graph(V, np.zeros(0, np.int32),
                            np.zeros(0, np.int32), name=name)
        eng = cls.build(cfg, placeholder, dims, axis_names=axis_names)
        if eng.part != plan.part:
            raise ValueError(
                f"plan partition {plan.part} disagrees with the one "
                f"cfg/mesh imply ({eng.part})")
        eng._graph_fp = str(graph_fp)
        eng._plan = plan
        return eng

    def with_config(self, **overrides) -> "GCNEngine":
        """Sibling engine on the same graph/mesh with cfg fields replaced
        (e.g. ``message_passing="oppr"``). Shares the plan cache, so
        flipping a field back and forth never replans."""
        cfg = dataclasses.replace(self.cfg, **overrides)
        # siblings inherit the construction MODE: a derived-mesh engine
        # spawns derived-mesh siblings even after its lazy mesh
        # materialized, so they all share one step-cache mesh identity
        return GCNEngine.build(
            cfg, self.graph,
            None if self._mesh_explicit else self.dims,
            mesh=self._mesh_jax if self._mesh_explicit else None,
            axis_names=self.axis_names,
            bidir=self.bidir, donate=self.donate)

    # ---------------- host-side mapping (cached) ----------------

    @property
    def graph_fp(self) -> str:
        if self._graph_fp is None:
            self._graph_fp = graph_fingerprint(self.graph)
        return self._graph_fp

    @property
    def agg_impl(self) -> str:
        """The engine's default aggregation backend, resolved from
        ``cfg.agg_impl`` ("auto" picks by jax backend; cached because
        resolution initializes the jax backend)."""
        if self._agg_impl is None:
            self._agg_impl = resolve_agg_impl(self.cfg.agg_impl)
        return self._agg_impl

    def _impl(self, agg_impl: str | None) -> str:
        """Per-call backend override -> concrete impl."""
        return self.agg_impl if agg_impl is None else \
            resolve_agg_impl(agg_impl)

    def plan_key_for(self, agg_impl: str | None = None) -> PlanKey:
        return PlanKey(self.graph_fp, self.cfg.model,
                       self.cfg.message_passing, self.cfg.use_rounds,
                       self.dims, self.cfg.agg_buffer_bytes, self.bidir,
                       self.cfg.alpha, self.cfg.graph.feat_in,
                       self.model_spec.gen,
                       agg_impl=self._impl(agg_impl),
                       ell_block_slots=self.cfg.ell_block_slots,
                       ell_edge_align=self.cfg.ell_edge_align)

    @property
    def plan_key(self) -> PlanKey:
        return self.plan_key_for(None)

    @property
    def plan_cached(self) -> bool:
        """True when this engine's plan is already in the process cache
        (checking does not build or count as a hit/miss)."""
        return cache.plan_cached(self.plan_key)

    def prepared_graph(self) -> tuple[Graph, np.ndarray]:
        """Model-weighted graph (self loops + edge weights), cached per
        (graph, model, registry generation) so switching message-passing
        models reuses it but a re-registered model never sees stale
        weights. Byte-bounded LRU (prepared graphs can be large)."""
        key = (self.graph_fp, self.cfg.model, self.model_spec.gen)
        return cache.get_prep(key, lambda: self.model_spec.prepare(self.graph))

    @property
    def plan(self) -> CommPlan:
        """The static relay schedule — built once per plan identity,
        ever (aggregation-backend fields do not participate: switching
        ``agg_impl`` never replans). Byte-bounded LRU under
        :func:`repro.gcn.cache.set_cache_budget`; evicting a plan also
        drops the ELL layouts / compiled steps derived from it."""
        if self._plan is None:
            def build():
                with obs.trace.span("plan_build", graph=self.graph_fp[:12],
                                    scope="full"):
                    g2, w = self.prepared_graph()
                    plan = build_plan(self.cfg, g2, self.torus, self.part,
                                      edge_weights=w, bidir=self.bidir)
                obs.metrics.counter(
                    "engine.plan_builds", unit="plans",
                    help="relay plans built (cache misses)").add(1)
                return plan

            # the pinned getter registers this session and assigns
            # self._plan (via _pin_plan) under the store lock, so an
            # eviction racing the build/commit can never leave this
            # session holding a dead plan while deregistered. Return
            # the getter's plan, not self._plan — an eviction may
            # legitimately release the memo again before we read it.
            return cache.get_plan_pinned(self.plan_key, build, self)
        return self._plan

    def _pin_plan(self, plan: CommPlan) -> None:
        """Memo assignment hook, called by the cache under its lock
        (see :func:`repro.gcn.cache.get_plan_pinned`)."""
        self._plan = plan

    def _release_plan_memos(self) -> None:
        """Called by :mod:`repro.gcn.cache` when this session's plan is
        evicted under byte pressure: drop every memoized derivative —
        the plan object, per-backend device arrays (the uploads), the
        compiled layer/training steps, and the batch-bucket ledger
        (released steps recompile, so old buckets are no longer
        hits). The session stays fully usable; its next execution
        transparently replans/re-uploads through the shared store
        (counted as one plan miss)."""
        self._plan = None
        self._plan_dev.clear()
        self._layer_step.clear()
        self._train_fns.clear()
        self._batch_buckets.clear()

    def statics_for(self, agg_impl: str | None = None) -> mp.ExchangeStatics:
        return mp.exchange_statics(
            self.plan, self.axis_names, agg_impl=self._impl(agg_impl),
            ell_block_slots=self.cfg.ell_block_slots)

    @property
    def statics(self) -> mp.ExchangeStatics:
        return self.statics_for(None)

    def ell_layout(self):
        """Blocked-ELL encoding of this plan's aggregation edge list —
        ``(seg, rows, w)``, each ``(R, N, nb, Eb)`` (see
        ``repro.kernels.spmm.ops`` for the layout invariants). Built
        host-side once per full PlanKey and cached alongside the plan
        (evicting the plan drops the layout with it)."""
        key = dataclasses.replace(self.plan_key, agg_impl="pallas")

        def build():
            with obs.trace.span("ell_build", graph=self.graph_fp[:12]):
                plan = self.plan
                ell = spmm_ops.build_ell_layout_rounds(
                    plan.edge_repl, plan.edge_slot, plan.edge_w,
                    plan.part.slots_per_round,
                    block_slots=self.cfg.ell_block_slots,
                    edge_align=self.cfg.ell_edge_align)
            obs.metrics.counter(
                "engine.ell_builds", unit="layouts",
                help="blocked-ELL layouts built (cache misses)").add(1)
            return ell

        return cache.get_ell(key, build)

    def plan_arrays(self, agg_impl: str | None = None):
        """Device-layout plan arrays (cached jnp views of the plan), one
        tree per aggregation backend: the ``"pallas"`` tree carries the
        precomputed ELL tensors in place of the COO edge arrays, so each
        backend uploads its encoding exactly once."""
        impl = self._impl(agg_impl)
        if impl not in self._plan_dev:
            with obs.trace.span("upload", what="plan_arrays", impl=impl,
                                graph=self.graph_fp[:12]):
                ell = self.ell_layout() if impl == "pallas" else None
                self._plan_dev[impl] = mp.plan_device_arrays(self.plan,
                                                             ell=ell)
            obs.metrics.counter(
                "engine.plan_uploads", unit="uploads",
                help="plan-array device uploads (per backend)").add(1)
        return self._plan_dev[impl]

    def plan_uploaded(self, agg_impl: str | None = None) -> bool:
        """True when this session's plan arrays for the backend are
        already materialized on device (checking builds nothing) — the
        service's prefetcher uses this to skip redundant uploads."""
        return self._impl(agg_impl) in self._plan_dev

    @property
    def mesh_jax(self):
        if self._mesh_jax is None:
            self._mesh_jax = jax_compat.make_mesh(self.dims,
                                                  self.axis_names)
        return self._mesh_jax

    # ---------------- compiled exchange ----------------

    def exchange_fn(self, agg_impl: str | None = None):
        """Public accessor for the engine's shard_map'd exchange closure
        (``(pdev, feats) -> (*dims, R, slots, F)``) — e.g. the dry-run
        lowers exactly this, so it can never drift from ``forward``.
        Pair it with :meth:`plan_arrays` for the matching input tree."""
        return self._exchange_fn(agg_impl)

    def _exchange_fn(self, agg_impl: str | None = None):
        """The shard_map'd exchange ``(pdev, feats) -> (*dims, R, slots,
        F)`` — the one closure both the compiled layer step and the
        traced byte measurement use, so they can never diverge.
        ``check_rep`` is disabled for the pallas backend (pallas_call has
        no shard_map replication rule); the exchange's out_specs make the
        replication explicit either way."""
        from jax.sharding import PartitionSpec as P

        impl = self._impl(agg_impl)
        st = self.statics_for(impl)
        mesh = self.mesh_jax
        names = self.axis_names
        nd = len(self.dims)
        plan_spec = P(None, *names)  # (R, *dims, ...)
        feat_spec = P(*names)  # (*dims, Vp, F)
        pdev_tree = self.plan_arrays(impl)

        @partial(jax_compat.shard_map, mesh=mesh,
                 in_specs=(jax.tree.map(lambda _: plan_spec, pdev_tree),
                           feat_spec),
                 out_specs=P(*(names + (None, None, None))),
                 check_rep=impl != "pallas")
        def _exchange(pdev, feats):
            accs = mp.exchange_and_aggregate(st, pdev, feats)
            return accs[(None,) * nd]  # re-add mesh dims

        return _exchange

    def _exec_fp(self, impl: str, batched: bool) -> tuple:
        """Trace identity of the compiled layer step: everything baked
        into the jitted computation that is NOT a runtime argument — the
        static schedule (``ExchangeStatics``), the combine callable's
        registry identity, the mesh the shard_map binds, the donate
        flag, and the plan-array tree structure (the shard_map in_specs
        mirror it). Two engines with equal fingerprints share one
        compiled step even across different graphs ("plan_identity
        modulo graph fingerprint, where shapes match"); jax's jit cache
        re-specializes per feature shape underneath."""
        mesh_token = (("explicit", id(self._mesh_jax))
                      if self._mesh_explicit
                      else ("derived", self.dims, self.axis_names))
        treedef = jax.tree.structure(self.plan_arrays(impl))
        return (self.statics_for(impl), self.cfg.model,
                self.model_spec.gen, self.donate, batched,
                mesh_token, treedef)

    def _compiled_layer_step(self, agg_impl: str | None = None, *,
                             batched: bool = False):
        """jit(shard_map exchange + combine): one layer of the network.
        Cached process-wide (``repro.gcn.cache``) per executor identity
        and per aggregation backend, so sibling engines — and service
        sessions re-admitted after eviction — reuse one compiled step.
        Shapes vary per layer; jax's jit cache specializes per shape."""
        impl = self._impl(agg_impl)
        memo = (impl, batched)
        if memo not in self._layer_step:
            nd = len(self.dims)
            combine = self.model_spec.combine
            donate = self.donate

            def build():
                exchange = self._exchange_fn(impl)

                def step(pdev, x, layer, last):
                    accs = exchange(pdev, x)  # (*dims, R, slots, F)
                    agg = accs.reshape(
                        accs.shape[:nd] + (-1, accs.shape[-1]))
                    return combine(layer, agg, x, last)

                def step_batched(pdev, x, layer, last):
                    # x: (*dims, B, Vp, F). The exchange is LINEAR and
                    # independent per feature column, so a batch of
                    # requests rides folded into the feature axis — one
                    # relay replay serves all B requests — and is
                    # unfolded before the (nonlinear) combine.
                    B, F = x.shape[nd], x.shape[-1]
                    xf = jnp.moveaxis(x, nd, -2)  # (*dims, Vp, B, F)
                    xf = xf.reshape(xf.shape[:nd + 1] + (B * F,))
                    accs = exchange(pdev, xf)  # (*dims, R, slots, B*F)
                    S = accs.shape[nd] * accs.shape[nd + 1]
                    agg = accs.reshape(accs.shape[:nd] + (S, B, F))
                    agg = jnp.moveaxis(agg, -2, nd)  # (*dims, B, S, F)
                    return combine(layer, agg, x, last)

                return jax.jit(
                    step_batched if batched else step,
                    static_argnames=("last",),
                    donate_argnums=(1,) if donate else ())

            self._layer_step[memo] = cache.get_step(
                self.plan_key_for(impl), self._exec_fp(impl, batched),
                build)
        return self._layer_step[memo]

    # ---------------- parameters ----------------

    def init_params(self, key, dims: Sequence[int]) -> list[dict]:
        """dims = [feat_in, hidden..., out]; stores and returns params."""
        init = self.model_spec.init_layer
        keys = jax.random.split(key, len(dims) - 1)
        self.params = [init(k, dims[i], dims[i + 1])
                       for i, k in enumerate(keys)]
        return self.params

    def _resolve_params(self, params):
        params = params if params is not None else self.params
        if params is None:
            raise ValueError("no params: call init_params() or pass params=")
        return params

    # ---------------- execution ----------------

    def shard(self, feats_global: np.ndarray) -> np.ndarray:
        """(V, F) global features -> (*dims, Vp, F) node-major layout."""
        return mp.shard_features(self.plan, np.asarray(feats_global))

    def _resolve_feature_source(self, feats):
        """A :class:`~repro.gcn.featurestore.FeatureHandle` resolves to
        its full ``(V, F)`` table through the store (device-resident hot
        blocks hit; absent rows gather from the host column store —
        full-graph execution is full-V by nature, the SAMPLED path
        gathers per batch instead); anything else passes through."""
        from repro.gcn import featurestore

        if isinstance(feats, featurestore.FeatureHandle):
            if feats.num_vertices != self.graph.num_vertices:
                raise ValueError(
                    f"feature handle covers V={feats.num_vertices}, "
                    f"engine graph has V={self.graph.num_vertices}")
            if feats.graph_fp != self.graph_fp:
                raise ValueError(
                    "feature handle is registered for a different graph "
                    f"({feats.graph_fp[:12]} != {self.graph_fp[:12]})")
            return feats.gather_all()
        return feats

    def _shard_input(self, feats) -> tuple:
        """Validate + normalize a feature input: a global ``(V, F)``
        host array is sharded onto the mesh, a pre-sharded ``(*dims,
        Vp, F)`` device array passes through, and a
        :class:`~repro.gcn.featurestore.FeatureHandle` is gathered
        through the store first. Returns ``(x, is_global)`` — the ONE
        dispatch ``forward``, ``loss_and_grad`` and the trainer all
        share, so the input contract can never diverge between
        inference and training."""
        feats = self._resolve_feature_source(feats)
        nd = len(self.dims)
        feats_nd = np.ndim(feats)
        if feats_nd == 2:
            if feats.shape[0] != self.graph.num_vertices:
                raise ValueError(
                    f"global feats rows {feats.shape[0]} != |V| "
                    f"{self.graph.num_vertices}")
            return jnp.asarray(self.shard(np.asarray(feats))), True
        if feats_nd == nd + 2:
            return feats, False
        raise ValueError(
            f"feats must be (V, F) or (*{self.dims}, Vp, F); "
            f"got ndim={feats_nd}")

    def unshard(self, local) -> np.ndarray:
        """Inverse of :meth:`shard` for (*dims, Vp, F) tables."""
        return mp.unshard_features(self.plan, np.asarray(local),
                                   self.graph.num_vertices)

    def forward(self, feats, params=None, *, agg_impl: str | None = None):
        """Run the full network through the compiled exchange.

        ``feats`` is a global ``(V, F)`` host array (returns a global
        ``(V, F_out)`` numpy array), a pre-sharded ``(*dims, Vp, F)``
        device array (returns the sharded result), or a
        :class:`~repro.gcn.featurestore.FeatureHandle` (the rows are
        served through the store's device-resident cache; numerically
        identical to passing the registered array).
        ``agg_impl`` overrides the engine's aggregation backend for this
        call ("jnp" | "pallas" | "auto"); switching never replans — only
        the Compute step's encoding changes.
        """
        impl = self._impl(agg_impl)
        params = self._resolve_params(params)
        x, is_global = self._shard_input(feats)
        step = self._compiled_layer_step(impl)
        pdev = self.plan_arrays(impl)
        for li, layer in enumerate(params):
            x = step(pdev, x, layer, last=li == len(params) - 1)
        return self.unshard(np.asarray(x)) if is_global else x

    def forward_batched(self, feats_batch, params=None, *,
                        agg_impl: str | None = None) -> np.ndarray:
        """Run B feature-inference requests through ONE exchange replay
        per layer.

        ``feats_batch`` is ``(B, V, F)`` global host features (B
        independent requests over the same graph and params) or a
        :class:`~repro.gcn.featurestore.FeatureHandle` (one request
        over the store-registered features, gathered through the
        device-resident cache); returns ``(B, V, F_out)``. The distributed exchange is linear and
        independent per feature column, so the batch folds into the
        feature axis — all B requests share each round's ppermute relay
        (one launch moving B x the payload, the bandwidth-friendly
        regime the paper's Observation 2 targets) — and unfolds before
        the nonlinear combine. Numerics are identical to B separate
        :meth:`forward` calls up to fp32 summation order (the relay sums
        in the same order; only the matmul tiling differs).

        ``B == 1`` is valid. The batch is padded up to the next power of
        two with zero-feature rows (**bucketing**): the compiled step
        specializes per (padded B, F), so request counts 5, 6, 7, 8 all
        share the B=8 executable instead of each triggering a fresh
        compile — padding rows cost relay payload, never a recompile
        (the zero columns ride the same linear exchange and are sliced
        off before returning). :meth:`stats` reports the bucket hit
        rate. :class:`~repro.gcn.service.GCNService` uses this to serve
        compatible queued requests in one step.
        """
        impl = self._impl(agg_impl)
        params = self._resolve_params(params)
        resolved = self._resolve_feature_source(feats_batch)
        if resolved is not feats_batch:
            # a store handle is one request over the registered features
            resolved = resolved[None]
        fb = np.asarray(resolved)
        if fb.ndim != 3 or fb.shape[1] != self.graph.num_vertices:
            raise ValueError(
                f"feats_batch must be (B, V={self.graph.num_vertices}, F); "
                f"got shape {fb.shape}")
        nd = len(self.dims)
        B, V, F = fb.shape
        Bpad = 1 << (B - 1).bit_length()  # next power of two >= B
        bucket = (impl, Bpad, F)
        self._bucket_calls += 1
        if bucket in self._batch_buckets:
            self._bucket_hits += 1
        else:
            self._batch_buckets.add(bucket)
        if Bpad != B:
            fb = np.concatenate(
                [fb, np.zeros((Bpad - B, V, F), fb.dtype)])
        # host-side layout, one scatter for the whole batch: fold the
        # batch into the feature axis (the same B-major fold the
        # compiled step uses on device), shard once, then unfold the
        # batch axis to land right after the mesh dims
        xs = self.shard(np.moveaxis(fb, 0, 1).reshape(V, Bpad * F))
        xs = xs.reshape(xs.shape[:-1] + (Bpad, F))  # (*dims, Vp, Bp, F)
        x = jnp.asarray(np.moveaxis(xs, -2, nd))  # (*dims, Bp, Vp, F)
        step = self._compiled_layer_step(impl, batched=True)
        pdev = self.plan_arrays(impl)
        for li, layer in enumerate(params):
            x = step(pdev, x, layer, last=li == len(params) - 1)
        out = np.moveaxis(np.asarray(x), nd, -2)  # (*dims, Vp, Bp, F_out)
        out = self.unshard(out.reshape(out.shape[:-2] + (-1,)))
        # slice the zero-padding requests back off
        return np.moveaxis(out.reshape(V, Bpad, -1), 0, 1)[:B]

    def forward_layer_major(self, feats, params=None, *,
                            agg_impl: str | None = None,
                            chunk_size: int = 128,
                            pipeline_depth: int = 2,
                            pipeline_workers: int = 2) -> np.ndarray:
        """Whole-network inference computed layer-major over bounded
        vertex chunks (:func:`repro.gcn.inference.forward_layer_major`)
        — bit-identical to :meth:`forward`, but the full-graph plan is
        never built and the device never holds a full ``(V, F)``
        feature table: each layer runs for ALL vertices in 1-hop
        chunks (cached, pow2-padded sub-plans through the ``batch``
        cache layer) with ``h_l`` materialized on the host between
        layers. The serving path for graphs whose plan exceeds
        ``set_cache_budget(plan_bytes=...)``; telemetry (peak feature
        bytes, prepare/execute overlap, chunk-bucket hit rate) lands in
        :meth:`stats` / :meth:`inference_stats`."""
        from repro.gcn import inference

        return inference.forward_layer_major(
            self, feats, params, agg_impl=agg_impl,
            chunk_size=chunk_size, pipeline_depth=pipeline_depth,
            pipeline_workers=pipeline_workers)

    # ---------------- training (repro.gcn.train) ----------------

    def _compiled_loss_grad(self, agg_impl: str | None = None):
        """jit(value_and_grad(masked CE through the exchange)):
        ``(pdev, params, x, labels, mask) -> (loss, grads)``. Cached
        process-wide alongside the layer steps (same executor-identity
        sharing and plan-eviction coherence)."""
        from repro.gcn import train as _train

        impl = self._impl(agg_impl)
        memo = ("loss_grad", impl)
        if memo not in self._train_fns:
            fp = ("loss_grad", self._exec_fp(impl, False))
            self._train_fns[memo] = cache.get_step(
                self.plan_key_for(impl), fp,
                lambda: _train.build_loss_grad(self, impl))
        return self._train_fns[memo]

    def _compiled_train_step(self, opt_cfg, agg_impl: str | None = None):
        """One jitted full-batch training step (loss + grads through the
        exchange + AdamW update): ``(pdev, params, opt_state, x,
        labels, mask) -> (params, opt_state, metrics)``. Keyed by the
        executor identity PLUS the (frozen, hashable) optimizer config,
        so two trainers with the same schedule share one compile."""
        from repro.gcn import train as _train

        impl = self._impl(agg_impl)
        memo = ("train_step", impl, opt_cfg)
        if memo not in self._train_fns:
            fp = ("train_step", opt_cfg, self._exec_fp(impl, False))
            self._train_fns[memo] = cache.get_step(
                self.plan_key_for(impl), fp,
                lambda: _train.build_train_step(self, impl, opt_cfg))
        return self._train_fns[memo]

    def _compiled_cv_loss_grad(self, agg_impl: str | None = None):
        """:meth:`_compiled_loss_grad` for the control-variate forward:
        ``(pdev, params, x, corrs, labels, mask) -> (loss, grads)``.
        ``corrs`` (one ``(*dims, Vp, F_l)`` table per layer) enters as a
        constant input — no gradient path, no extra exchange — so the
        traced ppermute payload equals the plain step's (pinned by
        ``tests/test_gcn_train_cv.py``)."""
        from repro.gcn import train as _train

        impl = self._impl(agg_impl)
        memo = ("cv_loss_grad", impl)
        if memo not in self._train_fns:
            fp = ("cv_loss_grad", self._exec_fp(impl, False))
            self._train_fns[memo] = cache.get_step(
                self.plan_key_for(impl), fp,
                lambda: _train.build_cv_loss_grad(self, impl))
        return self._train_fns[memo]

    def _compiled_cv_train_step(self, opt_cfg, agg_impl: str | None = None):
        """:meth:`_compiled_train_step` for control-variate sampled
        training: ``(pdev, params, opt_state, x, corrs, labels, mask)
        -> (params, opt_state, metrics, hiddens)``. The extra
        ``hiddens`` output carries each hidden layer's freshly computed
        activations so the trainer can write them back to the
        :class:`~repro.gcn.history.HistoryStore` after the optimizer
        update."""
        from repro.gcn import train as _train

        impl = self._impl(agg_impl)
        memo = ("cv_train_step", impl, opt_cfg)
        if memo not in self._train_fns:
            fp = ("cv_train_step", opt_cfg, self._exec_fp(impl, False))
            self._train_fns[memo] = cache.get_step(
                self.plan_key_for(impl), fp,
                lambda: _train.build_cv_train_step(self, impl, opt_cfg))
        return self._train_fns[memo]

    def loss_and_grad(self, feats, labels, mask=None, params=None, *,
                      agg_impl: str | None = None):
        """Masked cross-entropy and its parameter gradients, computed
        THROUGH the distributed exchange (forward relay replay +
        transposed replay for the backward pass).

        ``feats`` is a global ``(V, F)`` host array or pre-sharded
        ``(*dims, Vp, F)``; ``labels`` a global ``(V,)`` int array;
        ``mask`` an optional ``(V,)`` 0/1 array of labeled vertices
        (SPMD padding is always excluded). Returns ``(loss, grads)`` as
        device values; gradients match
        :func:`repro.gcn.train.reference_loss_and_grad` (the dense
        single-node oracle) to fp32 tolerance on either aggregation
        backend."""
        from repro.gcn import train as _train

        impl = self._impl(agg_impl)
        params = self._resolve_params(params)
        labels_sh, mask_sh = _train.shard_training_inputs(
            self, labels, mask)
        x, _ = self._shard_input(feats)
        fn = self._compiled_loss_grad(impl)
        return fn(self.plan_arrays(impl), params, x, labels_sh, mask_sh)

    def reference(self, feats, params=None):
        """Exact single-device oracle for this engine's model (numpy in,
        numpy out), via :func:`repro.core.gcn_models.reference_loop` with
        this engine's prepared graph and registered combine."""
        params = self._resolve_params(params)
        g2, w = self.prepared_graph()
        return np.asarray(gm.reference_loop(
            g2, w, self.model_spec.combine, params, feats))

    # ---------------- accounting ----------------

    def stats(self, feat_dim: int | None = None,
              dtype_bytes: int = 4) -> dict:
        """Plan stats merged with link-byte accounting.

        * ``link_bytes`` — analytic hop-weighted payload bytes (the
          deduplicated item x hops count the cost model reports);
        * ``executor_link_bytes`` — ppermute payload bytes implied by the
          hop schedule (``hop_lens``) the executor replays: every hop
          moves L_h rows of F features on all N nodes x R rounds
          (includes SPMD padding). Derived from the same plan data as
          ``plan_executor_link_bytes`` below — for an INDEPENDENT
          measurement of what the executor moves, use
          :meth:`measured_link_bytes` (traces the exchange and counts
          actual ppermute operands);
        * ``plan_executor_link_bytes`` — the planner's own analytic count
          of the same quantity (``executor_feat_slots``);
        * ``agg_dense_bytes`` / ``agg_ell_bytes`` — estimated off-chip
          traffic of one full exchange's Compute step under each
          aggregation backend, sized from the ACTUAL layouts the two
          backends encode (the padded COO edge slots the dense scatter
          reads + read-modify-writes, vs the padded ELL message stream +
          one accumulator-tile writeback — the kernel keeps the
          accumulator resident in VMEM). ``agg_traffic_reduction`` is
          ``1 - ell/dense``: the repo-level mirror of the paper's 73 %
          off-chip-access-reduction claim (§III). Two honesty caveats:
          on padding-dominated smoke graphs the reduction can go
          negative (alignment overhead is counted), and the ELL figure
          models the kernel's *streaming design* — today's unfused
          implementation materializes the gathered message array via XLA
          before the pallas_call, adding roughly one extra message-
          stream write+read until the gather is fused into the kernel
          (tracked in ROADMAP.md);
        * ``feature_byte_reduction`` — MEASURED feature-byte savings of
          the storage tier (:mod:`repro.gcn.featurestore`): ``1 -
          feature_bytes_gathered / feature_bytes_dense`` for this
          graph's access history, where ``gathered`` counts what was
          actually read from the host tier and ``dense`` is the
          dense-slice baseline (every accessed row read from host every
          time — the pre-store code path). The storage-side companion
          of ``agg_traffic_reduction`` under the paper's 73 %
          off-chip-access-reduction claim; all zeros until features are
          registered with the process-wide store.
        """
        plan = self.plan
        if feat_dim is None:
            feat_dim = self._default_feat_dim()
        st = self.statics
        N, R = plan.num_nodes, plan.num_rounds
        exec_slots = sum(
            (sum(hl) + sum(hlr)) * N * R
            for hl, hlr in zip(st.hop_lens, st.hop_lens_rev))
        # ELL shape only (no layout materialization — stats() must stay
        # cheap for jnp-only engines); identical to what ell_layout()
        # would build, by construction
        nb, Eb = spmm_ops.ell_layout_shape(
            plan.edge_slot, plan.edge_w, plan.part.slots_per_round,
            self.cfg.ell_block_slots, self.cfg.ell_edge_align)
        # dense COO scatter: gather-read each padded edge slot, then
        # read-modify-write the accumulator row per edge + final table
        dense_slots = 3 * plan.stats["agg_edge_slots_padded"] \
            + plan.stats["agg_acc_slots"]
        # blocked ELL: stream the padded message rows once; accumulator
        # tiles stay in VMEM and are written back once per block
        ell_slots = R * N * nb * (Eb + self.cfg.ell_block_slots)
        out = dict(plan.stats)
        out.update(
            feat_dim=feat_dim,
            dtype_bytes=dtype_bytes,
            agg_impl=self.agg_impl,
            link_bytes=plan.stats["link_feat_hops"] * feat_dim * dtype_bytes,
            executor_link_bytes=exec_slots * feat_dim * dtype_bytes,
            plan_executor_link_bytes=(
                plan.stats["executor_feat_slots"] * feat_dim * dtype_bytes),
            agg_dense_bytes=dense_slots * feat_dim * dtype_bytes,
            agg_ell_bytes=ell_slots * feat_dim * dtype_bytes,
            agg_traffic_reduction=1.0 - ell_slots / max(dense_slots, 1),
            # forward_batched bucketing: a hit = the padded batch size
            # had already been executed, so the call compiled nothing
            batch_bucket_calls=self._bucket_calls,
            batch_bucket_hits=self._bucket_hits,
            # None (not 0.0) before any forward_batched call — an unrun
            # ledger is not a measured zero hit rate
            batch_bucket_hit_rate=obs.ratio(
                self._bucket_hits, self._bucket_calls, default=None),
            batch_buckets=sorted({b for (_, b, _) in self._batch_buckets}),
        )
        # sampling-pipeline overlap of the last fit_sampled run on this
        # engine (repro.gcn.pipeline). None until a fit_sampled runs —
        # a serial run then reports a genuine 0.0 (nothing was hidden)
        ps = self._pipeline_stats
        out.update(
            pipeline_depth=ps.get("pipeline_depth", 0) if ps else 0,
            pipeline_overlap_fraction=(
                ps.get("pipeline_overlap_fraction") if ps else None),
            pipeline_queue_occupancy=(
                ps.get("pipeline_queue_occupancy") if ps else None),
        )
        out.update(self.inference_stats())
        from repro.gcn import featurestore

        fs = featurestore.default_store().graph_stats(self.graph_fp)
        frows = fs["hit_rows"] + fs["miss_rows"]
        out.update(
            # None until a gather touches this graph's features
            feature_hit_rate=obs.ratio(fs["hit_rows"], frows,
                                       default=None),
            feature_bytes_gathered=fs["gathered_bytes"],
            feature_bytes_dense=fs["dense_bytes"],
            feature_byte_reduction=(
                1.0 - fs["gathered_bytes"] / fs["dense_bytes"]
                if fs["dense_bytes"] else None),
        )
        return out

    def telemetry(self) -> dict:
        """Schema-versioned snapshot of the process-wide typed metrics
        registry (:mod:`repro.gcn.obs`) — counters are cumulative across
        the whole process (every engine, service and pipeline), not
        scoped to this session. Bench records embed this next to the
        per-session :meth:`stats`."""
        return obs.telemetry()

    def inference_stats(self) -> dict:
        """Layer-major inference telemetry of the LAST
        :meth:`forward_layer_major` call on this engine, plus the
        cumulative chunk-bucket ledger. Ratio fields are ``None`` (not
        ``0.0``) before any run measures them — counts stay 0.
        Deliberately **plan-free**: :meth:`stats` builds the full plan,
        which is exactly what an over-budget layer-major session must
        never do — the service reports through this accessor."""
        inf = self._inference_stats or {}
        calls, hits = self._chunk_calls, self._chunk_hits
        return {
            "inference_chunks": inf.get("chunks", 0),
            "inference_chunk_size": inf.get("chunk_size", 0),
            "inference_pipeline_depth": inf.get("pipeline_depth", 0),
            # device-resident feature high-water mark of the chunked
            # schedule vs what one full-graph forward would allocate
            "peak_feature_bytes": inf.get("peak_feature_bytes", 0),
            "dense_feature_bytes": inf.get("dense_feature_bytes", 0),
            # share of chunk-prepare wall time hidden behind execution
            # (None until a layer-major call runs)
            "inference_overlap_fraction": inf.get("overlap_fraction"),
            "chunk_plan_hits": inf.get("chunk_plan_hits", 0),
            "chunk_plan_misses": inf.get("chunk_plan_misses", 0),
            "chunk_bucket_calls": calls,
            "chunk_bucket_hits": hits,
            "chunk_bucket_hit_rate": obs.ratio(hits, calls, default=None),
        }

    def measured_link_bytes(self, feat_dim: int | None = None,
                            dtype=jnp.float32,
                            agg_impl: str | None = None) -> int:
        """Bytes one exchange actually moves through ``ppermute``,
        measured from the TRACED executor: the exchange is traced to a
        jaxpr and every ppermute operand is summed (x scan trip counts,
        x mesh size). Independent of ``CommPlan.stats`` — this is the
        real cross-check against ``stats()['executor_link_bytes']``.
        The count is backend-invariant (aggregation never touches the
        links); ``agg_impl`` lets tests assert exactly that. Note that
        ``agg_impl="pallas"`` traces through the pallas plan tree, which
        builds (and caches) the ELL layout if no prior pallas execution
        has — intended for parity checks on test-scale plans, not as a
        cheap accounting call on paper-scale ones."""
        if feat_dim is None:
            feat_dim = self._default_feat_dim()
        Vp = self.plan.part.vertices_per_node()
        feats_abs = jax.ShapeDtypeStruct(self.dims + (Vp, feat_dim), dtype)
        jaxpr = jax.make_jaxpr(self._exchange_fn(agg_impl))(
            self.plan_arrays(agg_impl), feats_abs)
        return _ppermute_payload_bytes(jaxpr.jaxpr, 1)

    def _default_feat_dim(self, params=None) -> int:
        """Feature width for byte accounting: the params' input width
        when recoverable (registered models may use any layer dict
        layout), else the config's feat_in. ``params`` defaults to the
        engine's stored params (the trainer passes its own)."""
        params = params if params is not None else self.params
        if params:
            try:
                return int(params[0]["w"].shape[0])
            except (KeyError, TypeError, AttributeError, IndexError):
                pass
        return self.cfg.graph.feat_in

    def analyze(self, *, name: str | None = None, bidir: bool | None = None,
                **cfg_overrides) -> cm.CostReport:
        """Analytical cost report (no plan construction — tractable at
        paper scale). ``cfg_overrides`` replace GCNConfig fields, e.g.
        ``analyze(message_passing="oppe", use_rounds=False)``; the
        engine's build-time partition is reused across variants so
        comparisons share one vertex mapping."""
        c = (dataclasses.replace(self.cfg, **cfg_overrides)
             if cfg_overrides else self.cfg)
        return cm.analyze(c, self.graph, self.torus, part=self.part,
                          name=name,
                          bidir=self.bidir if bidir is None else bidir)


def _ppermute_payload_bytes(jaxpr, mult: int) -> int:
    """Sum ppermute operand bytes in a jaxpr, multiplying through scan
    trip counts and shard_map mesh sizes (each device runs the body)."""
    total = 0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        m = mult
        if prim == "ppermute":
            aval = eqn.invars[0].aval
            total += m * aval.size * np.dtype(aval.dtype).itemsize
            continue
        if prim == "scan":
            m = mult * int(eqn.params["length"])
        elif prim == "shard_map":
            m = mult * int(eqn.params["mesh"].size)
        for sub in jax_compat.subjaxprs_in_params(eqn.params):
            total += _ppermute_payload_bytes(sub, m)
    return total
