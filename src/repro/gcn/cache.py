"""Process-wide caches for the GCN serving stack.

This is the middle layer of the session/cache/service split:

  * :class:`GCNEngine` (``repro.gcn.engine``) is a thin per-graph
    *session* — it holds no cached state of its own beyond memoized
    lookups into this module;
  * this module owns every process-wide cache the one-time host-side
    mapping produces, so N engines (or one :class:`~repro.gcn.service.
    GCNService` juggling N graphs) share mapping work and device
    uploads;
  * ``repro.gcn.service`` schedules requests across sessions on top.

Six cache layers, all keyed off :class:`PlanKey` (the feature layer off
its graph-fingerprint component):

  ``plan``   ``PlanKey.plan_identity()`` -> ``CommPlan``. Byte-bounded
             LRU: the host-side relay schedules of many admitted graphs
             must fit a configurable budget (``set_cache_budget``), and
             the least-recently-served graph is evicted first.
  ``ell``    full ``PlanKey`` -> blocked-ELL tensors (the pallas
             backend's re-encoding of the plan's aggregation edge
             list). Byte-bounded LRU.
  ``prep``   ``(graph_fp, model, gen)`` -> model-weighted graph.
             Byte-bounded LRU (prepared graphs can be tens of MB).
  ``step``   executor identity -> jit-compiled layer step. Compiled
             executors are shared across engines whose
             ``PlanKey.plan_identity()`` agrees *modulo graph
             fingerprint* whenever the traced schedule (the
             ``ExchangeStatics``) matches — two sessions on the same
             graph, or on different graphs that happen to produce the
             same static schedule, compile once. Count-bounded LRU
             (compiled executables have no portable byte size).
  ``batch``  subgraph-fingerprinted ``PlanKey`` -> padded sub-plan
             session (plan + local<->global node map + sub-engine).
             Byte-bounded LRU with its OWN budget, deliberately
             separate from ``plan`` — the sampled/chunked paths exist
             to run under a plan budget the full-graph plan would not
             fit, so sub-plans must never compete with full plans for
             one budget. TWO producers share this layer, namespaced
             through the key's ``graph_fp`` slot (the rest of the
             ``plan_identity()`` is the parent engine's):

               * ``"batch:{parent_fp}:{batch_fp}"`` — sampled
                 mini-batch sessions (``repro.gcn.train.fit_sampled``),
                 ``batch_fp`` = the sampled subgraph's content
                 fingerprint;
               * ``"chunk:{parent_fp}:{sha1(V, lo, hi, nodes)}"`` —
                 layer-major inference chunk sessions
                 (``repro.gcn.inference``), hashed over the chunk
                 range and its 1-hop node set.

             ``parent_fp`` keeps identical node sets on different
             graphs apart; the ``batch:``/``chunk:`` prefixes keep the
             two producers apart (both pinned by the collision
             regressions in ``tests/test_gcn_inference.py``).
  ``features``  ``(graph fingerprint, vertex block)`` -> device-resident
             vertex-feature blocks (:mod:`repro.gcn.featurestore`): a
             degree-ordered pinned hot tier plus an LRU cold tier over
             one byte budget (``set_cache_budget(feature_bytes=...)``),
             backed by a host column store. Owned by the process-wide
             :func:`repro.gcn.featurestore.default_store`; this module
             budgets/clears/reports it so the six layers stay one
             coherent surface.

Coherence contract: the three plan-derived layers can never outlive the plan
they encode. Evicting or clearing a plan drops every ELL layout and
compiled step built from it — and releases the graph's device-resident
feature blocks (the feature layer's host column store survives, so the
graph re-warms through its cold tier); :func:`invalidate_model` and
:func:`clear_all` sweep all layers in one call (this is the home of
what used to be three separate, partially-coherent clears inside
``engine.py``).

Budget honesty: sessions register themselves per plan identity
(:func:`register_session`, weak references), and plan eviction calls
each live session's release hook so their memoized plan/device-array/
compiled-step state is dropped WITH the store entry — ``set_cache_
budget`` bounds the whole process, not just the shared store (the PR-3
known limit: a long-lived session used to pin its plan forever).
``invalidate_model`` deliberately does NOT release sessions: a stale
engine keeps running its superseded spec (session semantics); the
generation stamp in every key keeps it from poisoning fresh engines.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import weakref
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.core.graph import Graph
from repro.core.plan import CommPlan

__all__ = [
    "PlanKey",
    "cache_stats",
    "clear_all",
    "get_batch",
    "graph_fingerprint",
    "invalidate_model",
    "register_session",
    "set_cache_budget",
]


@dataclass(frozen=True)
class PlanKey:
    """Full cache identity of one workload. Fields split into two
    groups (see ``repro.gcn.engine`` module docstring): plan-shaping
    fields (any change -> genuinely different relay schedule) and
    aggregation-backend fields (select the Compute-step encoding of the
    SAME schedule). The plan cache is keyed on :meth:`plan_identity`;
    the ELL layout cache on the full key."""

    graph_fp: str
    model: str
    message_passing: str
    use_rounds: bool
    mesh_dims: tuple[int, ...]
    agg_buffer_bytes: int
    bidir: bool
    # partition-shaping fields beyond the buffer size: the round budget
    # is 2^x <= alpha * M / (feat_in * 4), so both must key the cache
    alpha: float
    feat_in: int
    # registry generation of the model spec: a re-registered model must
    # never hit plans built for its predecessor (even via stale engines)
    model_gen: int
    # aggregation-backend fields: part of the key (a layout/compiled step
    # for one backend is never served for another) but NOT of the plan
    # identity (switching backends never replans)
    agg_impl: str = "jnp"
    ell_block_slots: int = 128
    ell_edge_align: int = 512

    def plan_identity(self) -> "PlanKey":
        """The sub-key that determines the ``CommPlan`` itself: the
        aggregation-backend fields are normalized away, so keys that
        differ only in ``agg_impl`` / ELL shape share one plan."""
        return dataclasses.replace(self, agg_impl="", ell_block_slots=0,
                                   ell_edge_align=0)


def graph_fingerprint(graph: Graph) -> str:
    """Content hash of the edge list — the plan-cache graph identity."""
    h = hashlib.sha1()
    h.update(np.int64(graph.num_vertices).tobytes())
    h.update(np.ascontiguousarray(graph.src).tobytes())
    h.update(np.ascontiguousarray(graph.dst).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Byte-bounded LRU store
# ---------------------------------------------------------------------------


class _LruStore:
    """OrderedDict LRU with a byte budget and hit/miss/eviction stats.

    ``budget_bytes=None`` means unbounded; ``max_entries`` additionally
    caps the entry count (used by the step cache, whose entries have no
    meaningful byte size). ``on_evict`` lets the owner cascade evictions
    into dependent layers.

    Concurrency contract: all stores share one reentrant ``lock``
    (cascades and nested builds re-enter it), and every method takes it
    itself — callers never pre-lock, and builder threads (the service's
    prefetch thread, the sampled pipeline's worker pool in
    ``repro.gcn.pipeline``) may call any method concurrently with the
    main thread. ``get`` RELEASES the lock while building, so a
    background thread planning graph B never blocks the main thread's
    lookups for graph A — first build to commit wins, a losing
    duplicate is discarded (builds must therefore be pure in their
    key). Eviction cascades (``on_evict``) run fully under the lock,
    so a concurrent builder can never observe a plan whose derived
    layers (ELL, steps, device feature blocks, session memos) were not
    dropped with it.
    """

    def __init__(self, name: str, lock, budget_bytes: int | None = None,
                 max_entries: int | None = None, on_evict=None):
        self.name = name
        self.lock = lock
        self.budget_bytes = budget_bytes
        self.max_entries = max_entries
        self.on_evict = on_evict
        self._d: OrderedDict = OrderedDict()
        self._bytes: dict = {}
        self.total_bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key, build, nbytes=None):
        """Return the cached value, building (and charging ``nbytes``,
        a callable of the value) on miss. LRU order is refreshed on
        hit."""
        with self.lock:
            if key in self._d:
                self.hits += 1
                self._d.move_to_end(key)
                return self._d[key]
            self.misses += 1
        val = build()  # outside the lock: builds may be seconds long
        with self.lock:
            if key in self._d:  # a concurrent builder committed first
                self._d.move_to_end(key)
                return self._d[key]
            nb = int(nbytes(val)) if nbytes is not None else 0
            self._d[key] = val
            self._bytes[key] = nb
            self.total_bytes += nb
            self._shrink()
            return val

    def peek(self, key) -> bool:
        """Membership check that neither counts nor refreshes LRU."""
        with self.lock:
            return key in self._d

    def _shrink(self):
        while ((self.budget_bytes is not None
                and self.total_bytes > self.budget_bytes
                and len(self._d) > 1)
               or (self.max_entries is not None
                   and len(self._d) > self.max_entries)):
            key, val = self._d.popitem(last=False)
            self.total_bytes -= self._bytes.pop(key)
            self.evictions += 1
            if self.on_evict is not None:
                self.on_evict(key, val)

    def drop(self, pred) -> int:
        """Remove (without cascading) every entry whose key matches."""
        with self.lock:
            doomed = [k for k in self._d if pred(k)]
            for k in doomed:
                del self._d[k]
                self.total_bytes -= self._bytes.pop(k)
            return len(doomed)

    def clear(self):
        with self.lock:
            self._d.clear()
            self._bytes.clear()
            self.total_bytes = 0
            self.hits = self.misses = self.evictions = 0

    def stats(self) -> dict:
        with self.lock:
            return {"entries": len(self._d), "bytes": self.total_bytes,
                    "budget_bytes": self.budget_bytes, "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions}


# ---------------------------------------------------------------------------
# The four layers
# ---------------------------------------------------------------------------


def _plan_nbytes(plan: CommPlan) -> int:
    """Host-side footprint of one relay schedule (every numpy array the
    plan carries, including per-phase deposit schedules)."""
    total = 0
    for f in dataclasses.fields(plan):
        total += _tree_nbytes(getattr(plan, f.name))
    return total


def _tree_nbytes(obj) -> int:
    if isinstance(obj, np.ndarray):
        return obj.nbytes
    if isinstance(obj, (list, tuple)):
        return sum(_tree_nbytes(o) for o in obj)
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return sum(_tree_nbytes(getattr(obj, f.name))
                   for f in dataclasses.fields(obj))
    return 0


# eviction back-pointers: plan identity -> the compiled-step keys built
# from it (a step key itself excludes the graph fingerprint so equal
# schedules share one compile; see get_step)
_STEP_DEPS: dict[PlanKey, set] = {}

# live sessions per plan identity (weak: a dead engine needs no
# release). Budget eviction walks these and clears each session's
# memoized plan/device-array/compiled-step state, so a long-lived
# session can no longer pin an evicted plan's memory outside the
# budget (the PR-3 known limit). The session transparently rebuilds
# through the store on its next execution.
_SESSIONS: dict[PlanKey, "weakref.WeakSet"] = {}


def register_session(key: PlanKey, session) -> None:
    """Record ``session`` (a ``GCNEngine``) as a live consumer of
    ``key``'s plan; eviction of that plan calls the session's
    ``_release_plan_memos`` hook. Idempotent; entries are weak."""
    with _LOCK:
        _SESSIONS.setdefault(key.plan_identity(),
                             weakref.WeakSet()).add(session)


def _feature_layer():
    """The process-wide feature store (lazy import: featurestore
    imports this module at its top level)."""
    from repro.gcn import featurestore

    return featurestore.default_store()


def _history_layer():
    """The process-wide history store (lazy import: history imports
    this module at its top level, like featurestore)."""
    from repro.gcn import history

    return history.default_history()


def _on_plan_evict(key: PlanKey, _plan):
    # coherence: a plan's derived encodings and compiled executors can
    # never outlive it — else a re-admitted graph could pair a FRESH
    # plan with a stale layout built for the evicted one. A step shared
    # with another live plan simply re-fills on that plan's next use.
    _ELL.drop(lambda k: k.plan_identity() == key)
    deps = _STEP_DEPS.pop(key, set())
    _STEPS.drop(lambda k: k in deps)
    # the evicted graph stops holding device feature bytes too; its
    # host column store survives and re-warms through the cold tier
    _feature_layer().release_device(key.graph_fp)
    # same cascade for historical activations: they re-warm through
    # write-backs (reads fall back to the plain sampled term meanwhile)
    _history_layer().release(key.graph_fp)
    for session in list(_SESSIONS.pop(key, ())):
        session._release_plan_memos()


def _on_step_evict(key, _step):
    # keep the back-pointer sets in lockstep with the store, or
    # long-resident plans accumulate dead exec fingerprints forever
    empty = []
    for ident, deps in _STEP_DEPS.items():
        deps.discard(key)
        if not deps:
            empty.append(ident)
    for ident in empty:
        del _STEP_DEPS[ident]


# the budgets are deliberately generous defaults for a laptop-class
# process; GCNService passes an explicit budget for serving fleets
_LOCK = threading.RLock()  # service prefetch threads share these caches
_PLANS = _LruStore("plan", _LOCK, budget_bytes=512 << 20,
                   on_evict=_on_plan_evict)
_ELL = _LruStore("ell", _LOCK, budget_bytes=256 << 20)
_PREP = _LruStore("prep", _LOCK, budget_bytes=256 << 20)
_STEPS = _LruStore("step", _LOCK, max_entries=64,
                   on_evict=_on_step_evict)
# sampled mini-batch sessions (repro.gcn.train.fit_sampled): subgraph
# fingerprint -> batch session (padded plan + node map + sub-engine).
# Deliberately SEPARATE from the plan store: the whole point of sampled
# training is to run under a plan budget the full-batch plan would not
# fit, so batch plans must not compete with (or be evicted by) full
# plans under one budget knob. Entries are self-contained — eviction
# just drops the session object (nothing derived lives elsewhere keyed
# by it except shared compiled steps, which expire via the step LRU).
_BATCH = _LruStore("batch", _LOCK, budget_bytes=256 << 20)


def set_cache_budget(*, plan_bytes: int | None = ...,
                     ell_bytes: int | None = ...,
                     prep_bytes: int | None = ...,
                     step_entries: int | None = ...,
                     batch_bytes: int | None = ...,
                     feature_bytes: int | None = ...,
                     history_bytes: int | None = ...) -> None:
    """Reconfigure the byte budgets (``None`` = unbounded; omitted
    fields keep their current value). Shrinks immediately —
    ``feature_bytes`` unpins/evicts device feature blocks down to the
    new budget (see :meth:`repro.gcn.featurestore.FeatureStore.
    set_budget`)."""
    with _LOCK:
        if plan_bytes is not ...:
            _PLANS.budget_bytes = plan_bytes
        if ell_bytes is not ...:
            _ELL.budget_bytes = ell_bytes
        if prep_bytes is not ...:
            _PREP.budget_bytes = prep_bytes
        if step_entries is not ...:
            _STEPS.max_entries = step_entries
        if batch_bytes is not ...:
            _BATCH.budget_bytes = batch_bytes
        if feature_bytes is not ...:
            _feature_layer().set_budget(feature_bytes)
        if history_bytes is not ...:
            _history_layer().set_budget(history_bytes)
        for store in (_PLANS, _ELL, _PREP, _STEPS, _BATCH):
            store._shrink()


def get_plan(key: PlanKey, build) -> CommPlan:
    """The plan layer: keyed on ``key.plan_identity()`` (switching
    aggregation backends never replans)."""
    return _PLANS.get(key.plan_identity(), build, nbytes=_plan_nbytes)


def get_plan_pinned(key: PlanKey, build, session) -> CommPlan:
    """:func:`get_plan` + atomic session pin.

    Registers ``session`` and calls its ``_pin_plan`` hook under the
    store lock, AFTER confirming the plan is still resident — the lock
    evictions also hold, so pin and release are strictly ordered and a
    concurrent eviction (e.g. a service prefetch thread committing a
    large plan) can never interleave between the store lookup and the
    session's memo assignment. Without this, a session could end up
    holding an evicted plan while deregistered — re-pinned forever,
    the exact budget leak the release hook exists to prevent. If the
    plan IS evicted between build commit and pin, the lookup simply
    retries through the store (one more counted miss)."""
    while True:
        plan = _PLANS.get(key.plan_identity(), build, nbytes=_plan_nbytes)
        with _LOCK:
            if _PLANS.peek(key.plan_identity()):
                register_session(key, session)
                session._pin_plan(plan)
                return plan


def plan_cached(key: PlanKey) -> bool:
    with _LOCK:
        return _PLANS.peek(key.plan_identity())


def get_ell(key: PlanKey, build):
    """The ELL-layout layer: keyed on the FULL key (a layout can never
    be served for the wrong plan or the wrong block shape)."""
    return _ELL.get(key, build, nbytes=lambda t: sum(a.nbytes for a in t))


def get_prep(key: tuple, build) -> tuple[Graph, np.ndarray]:
    """The prepared-graph layer: ``(graph_fp, model, gen)`` -> model-
    weighted graph, shared across message-passing models."""
    def nbytes(val):
        g2, w = val
        return g2.src.nbytes + g2.dst.nbytes + w.nbytes

    return _PREP.get(key, build, nbytes=nbytes)


def get_step(plan_key: PlanKey, exec_fp: tuple, build):
    """The compiled-executor layer, keyed on ``exec_fp`` ALONE.

    ``exec_fp`` is the full trace identity of the jitted layer step —
    the ``ExchangeStatics`` (hop schedule, capacities, rounds, backend)
    plus model/combine identity, mesh axes and donate flag. The plan's
    graph fingerprint is deliberately NOT part of it: engines whose
    ``plan_identity()`` agrees modulo graph fingerprint share one
    compiled step whenever their schedules match, and jax re-specializes
    per feature shape underneath.

    ``plan_key`` only records the eviction back-pointer: evicting a plan
    drops the step entries built from it (a step shared with another
    live plan simply re-fills on that plan's next use)."""
    with _LOCK:
        _STEP_DEPS.setdefault(plan_key.plan_identity(), set()).add(exec_fp)
    return _STEPS.get(exec_fp, build)


def step_cached(plan_key: PlanKey, exec_fp: tuple) -> bool:
    with _LOCK:
        return _STEPS.peek(exec_fp)


def get_batch(key, build, nbytes=None):
    """The sub-plan layer: subgraph-fingerprinted ``PlanKey`` -> padded
    sub-plan session (plan + local<->global node map + the sub-engine
    holding its device arrays). Byte-bounded LRU
    (``set_cache_budget(batch_bytes=...)``). Two producers share it,
    kept apart by the key's namespaced ``graph_fp`` slot (module
    docstring has the full layout): ``"batch:{parent_fp}:{batch_fp}"``
    sampled-training batches, ``"chunk:{parent_fp}:{sha1}"``
    layer-major inference chunks. A recurring seed set or chunk range
    is a pure hit — no re-sample, no re-plan, no re-upload."""
    return _BATCH.get(key, build, nbytes=nbytes)


def batch_cached(key) -> bool:
    with _LOCK:
        return _BATCH.peek(key)


# ---------------------------------------------------------------------------
# Coherent clearing / reporting
# ---------------------------------------------------------------------------


def clear_all() -> None:
    """Drop every layer (plans, ELL layouts, prepared graphs, compiled
    steps, feature registrations) and reset all counters — the one
    coherent clear. Live sessions are released too (same hook as budget
    eviction), so the memory actually returns; they transparently
    rebuild on next use. Outstanding feature handles go stale
    (re-register after clearing)."""
    with _LOCK:
        for store in (_PLANS, _ELL, _PREP, _STEPS, _BATCH):
            store.clear()
        _feature_layer().clear()
        _history_layer().clear()
        _STEP_DEPS.clear()
        for sessions in list(_SESSIONS.values()):
            for session in list(sessions):
                session._release_plan_memos()
        _SESSIONS.clear()


def invalidate_model(name: str) -> None:
    """Drop cached state for one model name across ALL four layers
    (called by the registry when a model is re-registered with
    ``overwrite``). Correctness does not depend on this — cache keys
    carry the registry generation — it just releases the superseded
    entries' memory."""
    with _LOCK:
        _PREP.drop(lambda k: k[1] == name)
        _PLANS.drop(lambda k: k.model == name)
        _ELL.drop(lambda k: k.model == name)
        _BATCH.drop(lambda k: k.model == name)
        doomed = set()
        for ident in [k for k in _STEP_DEPS if k.model == name]:
            doomed |= _STEP_DEPS.pop(ident)
        _STEPS.drop(lambda k: k in doomed)


def cache_stats() -> dict:
    """Per-layer ``{entries, bytes, budget_bytes, hits, misses,
    evictions}`` — the ``features`` layer adds its row/byte telemetry
    and per-graph admission ranks — plus the legacy flat counters
    (``hits``/``misses`` track the plan layer, as they always have).

    The ``batch`` row aggregates BOTH of that layer's producers —
    sampled-training batch sessions (``batch:``-prefixed keys) and
    layer-major inference chunk sessions (``chunk:``-prefixed keys; see
    the module docstring for the key layout). Per-run splits live on
    the reports instead: ``SampledFitReport.batch_plan_hits/misses``
    and ``engine.inference_stats()["chunk_plan_hits"/"chunk_plan_
    misses"]``."""
    with _LOCK:
        out = {s.name: s.stats()
               for s in (_PLANS, _ELL, _PREP, _STEPS, _BATCH)}
        out["features"] = _feature_layer().layer_stats()
        out["history"] = _history_layer().stats()
        out.update(hits=_PLANS.hits, misses=_PLANS.misses,
                   entries=len(_PLANS._d), ell_entries=len(_ELL._d))
        return out
