"""Historical-activation store for control-variate sampled training.

VR-GCN-style variance reduction (the DGL ``gcn_cv_sc`` update rule,
SNIPPETS.md snippet 2) lets the sampler's fanout drop from 8 to ~2
without losing accuracy: each layer's aggregation is estimated as the
*sampled* aggregation over the induced mini-batch edges plus a
*historical* aggregation over exactly the edges the batch dropped
(:func:`repro.core.sampling.missing_in_edges`), read from the last
activations computed for those vertices. The history term is constant
w.r.t. the current parameters, so gradients still flow only through the
sampled exchange — per-step ``exchange_bytes`` shrink with the fanout,
which is the bandwidth axis the paper's 32 % transmission reduction
targets.

This module is the storage side: per ``(graph fingerprint, layer)`` a
host-resident ``(V, F)`` float32 activation mirror plus a per-vertex
``written`` mask (rows never written read as *invalid* — the trainer
treats them as zero history, i.e. it falls back to the plain sampled
term for those edges, so a cold or evicted history degrades gracefully
instead of biasing the estimate with garbage).

Budget + coherence contract (mirrors :mod:`repro.gcn.featurestore`):

  * byte-budgeted LRU over whole ``(graph, layer)`` entries, wired into
    ``cache.set_cache_budget(history_bytes=...)`` /
    ``cache_stats()["history"]`` / ``clear_plan_cache``;
  * the plan-eviction cascade releases a parent graph's history with
    its plan (``repro.gcn.cache._on_plan_evict`` calls
    :meth:`HistoryStore.release`) — an evicted graph re-warms through
    write-backs exactly like the feature store's cold tier;
  * every public method runs fully under ``self.lock`` (the default
    store shares ``repro.gcn.cache._LOCK``); reads return copies, so a
    concurrent eviction or write-back never mutates a batch mid-step.

Pipelined determinism: history mutates every optimizer step, so —
unlike features and plans — it must NOT be read inside pipeline
``prepare`` closures. The trainer reads history rows on the *training
thread*, in consumption order, which keeps the pipelined CV trajectory
bit-identical to serial (``tests/test_gcn_train_cv.py``).
"""
from __future__ import annotations

import threading
from collections import OrderedDict

import numpy as np

from repro.gcn import cache, obs

__all__ = ["HistoryStore", "default_history"]

_WRITE_ROWS = obs.metrics.counter(
    "history.write_rows", unit="rows",
    help="activation rows written back to the history store")
_READ_ROWS = obs.metrics.counter(
    "history.read_rows", unit="rows",
    help="valid (written) history rows served to CV corrections")
_FALLBACK_ROWS = obs.metrics.counter(
    "history.fallback_rows", unit="rows",
    help="requested history rows served as zero (unwritten or evicted)")
_EVICTIONS = obs.metrics.counter(
    "history.evictions", unit="entries",
    help="(graph, layer) history entries evicted under the byte budget")


def _check_budget(budget_bytes):
    if budget_bytes is None:
        return None
    b = int(budget_bytes)
    if b < 0:
        raise ValueError(f"budget_bytes must be >= 0 or None: "
                         f"{budget_bytes}")
    return b


class _LayerHistory:
    """One entry: the last activations computed for one layer of one
    graph, plus which rows have ever been written."""

    __slots__ = ("values", "written", "version", "nbytes")

    def __init__(self, num_vertices: int, feat_dim: int):
        self.values = np.zeros((num_vertices, feat_dim), np.float32)
        self.written = np.zeros(num_vertices, bool)
        self.version = 0
        self.nbytes = self.values.nbytes + self.written.nbytes


class HistoryStore:
    """Byte-budgeted per-``(graph_fp, layer)`` historical activations.

    Entries allocate lazily on first :meth:`write`; admission evicts
    least-recently-used entries until the newcomer fits, and an entry
    that cannot fit the whole budget is simply not kept (the write is
    dropped, reads fall back to zero — CV degrades to plain sampling
    for that layer rather than holding a partial table).
    """

    def __init__(self, *, budget_bytes: int | None = None, lock=None):
        self.lock = lock if lock is not None else threading.RLock()
        self.budget_bytes = _check_budget(budget_bytes)
        self._layers: OrderedDict[tuple, _LayerHistory] = OrderedDict()
        self._heights: dict[str, int] = {}
        self.total_bytes = 0
        # store-wide counters (cache_stats()["history"])
        self.writes = 0
        self.write_rows = 0
        self.read_rows = 0
        self.fallback_rows = 0
        self.evictions = 0
        self.rejected_writes = 0

    # ---------------- admission / eviction ----------------

    def _evict_until(self, need: int, keep: tuple | None) -> None:
        """Evict LRU entries (never ``keep``) until ``need`` free bytes
        exist under the budget."""
        if self.budget_bytes is None:
            return
        for key in list(self._layers):
            if self.total_bytes + need <= self.budget_bytes:
                break
            if key == keep:
                continue
            ent = self._layers.pop(key)
            self.total_bytes -= ent.nbytes
            self.evictions += 1
            _EVICTIONS.add(1)

    def _entry_for_write(self, key: tuple, num_vertices: int,
                         feat_dim: int) -> _LayerHistory | None:
        ent = self._layers.get(key)
        if ent is not None:
            if (ent.values.shape != (num_vertices, feat_dim)):
                # shape changed (new model/graph padding): start over
                self.total_bytes -= ent.nbytes
                del self._layers[key]
                ent = None
            else:
                self._layers.move_to_end(key)
                return ent
        ent = _LayerHistory(num_vertices, feat_dim)
        if self.budget_bytes is not None:
            self._evict_until(ent.nbytes, keep=None)
            if self.total_bytes + ent.nbytes > self.budget_bytes:
                return None  # cannot fit even after evicting everything
        self._layers[key] = ent
        self.total_bytes += ent.nbytes
        return ent

    # ---------------- the trainer-facing API ----------------

    def write(self, graph_fp: str, layer: int, nodes, values) -> int:
        """Write freshly computed activations for ``nodes`` (global
        vertex ids of the *parent* graph) of ``layer``; returns the
        number of rows written (0 when the entry cannot fit the
        budget)."""
        nodes = np.asarray(nodes, np.int64)
        values = np.asarray(values, np.float32)
        if values.ndim != 2 or values.shape[0] != nodes.size:
            raise ValueError(
                f"values must be (len(nodes), F); got {values.shape} "
                f"for {nodes.size} nodes")
        with self.lock:
            ent = self._entry_for_write(
                (graph_fp, int(layer)),
                num_vertices=self._num_vertices_hint(
                    graph_fp, int(layer), nodes),
                feat_dim=int(values.shape[1]))
            if ent is None:
                self.rejected_writes += 1
                return 0
            ent.values[nodes] = values
            ent.written[nodes] = True
            ent.version += 1
            self.writes += 1
            self.write_rows += int(nodes.size)
        _WRITE_ROWS.add(int(nodes.size))
        return int(nodes.size)

    def _num_vertices_hint(self, graph_fp: str, layer: int,
                           nodes: np.ndarray) -> int:
        """Table height for a lazily allocated entry: the registered
        height when known, else enough to hold ``nodes``. The trainer
        calls :meth:`ensure` with the parent's vertex count first, so
        in practice this is always the registered height."""
        ent = self._layers.get((graph_fp, layer))
        if ent is not None:
            return int(ent.values.shape[0])
        hint = self._heights.get(graph_fp)
        if hint is not None:
            return int(hint)
        return int(nodes.max()) + 1 if nodes.size else 0

    def ensure_height(self, graph_fp: str, num_vertices: int) -> None:
        """Declare the parent graph's vertex count, so lazily allocated
        entries get full-height tables regardless of which batch writes
        first."""
        with self.lock:
            self._heights[graph_fp] = int(num_vertices)

    def read(self, graph_fp: str, layer: int, nodes):
        """History rows for ``nodes``: ``(rows, valid)`` where ``rows``
        is ``(len(nodes), F)`` float32 with unwritten rows zeroed and
        ``valid`` the per-row written mask — or ``None`` when the
        ``(graph, layer)`` entry does not exist (never written, or
        evicted): the caller falls back to the plain sampled term."""
        nodes = np.asarray(nodes, np.int64)
        with self.lock:
            ent = self._layers.get((graph_fp, int(layer)))
            if ent is None:
                self.fallback_rows += int(nodes.size)
                _FALLBACK_ROWS.add(int(nodes.size))
                return None
            self._layers.move_to_end((graph_fp, int(layer)))
            valid = ent.written[nodes]
            rows = ent.values[nodes]  # fancy index: a copy
            rows[~valid] = 0.0
            nvalid = int(valid.sum())
            self.read_rows += nvalid
            self.fallback_rows += int(nodes.size) - nvalid
        _READ_ROWS.add(nvalid)
        _FALLBACK_ROWS.add(int(nodes.size) - nvalid)
        return rows, valid

    def version(self, graph_fp: str, layer: int) -> int:
        """Monotone write counter for one entry (0 = absent) — lets
        tests pin that pipeline workers never observed mid-epoch
        history states."""
        with self.lock:
            ent = self._layers.get((graph_fp, int(layer)))
            return 0 if ent is None else ent.version

    # ---------------- budget / coherence ----------------

    def set_budget(self, budget_bytes: int | None) -> None:
        """Reconfigure and shrink immediately (LRU entries go first);
        ``total_bytes <= budget`` holds on return — a whole-entry store,
        so unlike the plan LRU nothing is kept over budget."""
        with self.lock:
            self.budget_bytes = _check_budget(budget_bytes)
            self._evict_until(0, keep=None)

    def release(self, graph_fp: str) -> int:
        """Drop every layer of one graph (the plan-eviction cascade)."""
        with self.lock:
            doomed = [k for k in self._layers if k[0] == graph_fp]
            for key in doomed:
                self.total_bytes -= self._layers.pop(key).nbytes
            self._heights.pop(graph_fp, None)
            return len(doomed)

    def clear(self) -> int:
        with self.lock:
            n = len(self._layers)
            self._layers.clear()
            self._heights.clear()
            self.total_bytes = 0
            return n

    def stats(self) -> dict:
        with self.lock:
            return {
                "entries": len(self._layers),
                "bytes": self.total_bytes,
                "budget_bytes": self.budget_bytes,
                "writes": self.writes,
                "write_rows": self.write_rows,
                "read_rows": self.read_rows,
                "fallback_rows": self.fallback_rows,
                "evictions": self.evictions,
                "rejected_writes": self.rejected_writes,
            }


def default_history() -> HistoryStore:
    """The process-wide instance the cache layer budgets
    (``set_cache_budget(history_bytes=...)``), reports
    (``cache_stats()["history"]``) and clears. Imported lazily by
    ``repro.gcn.cache`` to avoid an import cycle."""
    return _DEFAULT


_DEFAULT = HistoryStore(lock=cache._LOCK)
