"""``GCNService`` — multi-graph GCN inference serving on one substrate.

The top layer of the session/cache/service split. Where
:class:`~repro.gcn.engine.GCNEngine` is one graph's session and
:mod:`repro.gcn.cache` is the process-wide mapping/compile store, the
service is the scheduler: it owns ONE mesh, admits many named graphs
(``service.admit(name, cfg, graph)``), queues feature-inference
requests across them, and drives execution in steps. It mirrors the
slot-pool design of ``repro.serve.engine.ServeEngine`` (the LM-side
substrate): ``submit`` enqueues, ``step`` admits-and-advances, ``run``
ticks until drained.

Two serving tricks, both straight from the paper's characterization
(Observation 2: MultiAccSys GCN execution is bandwidth-bound and
latency-tolerant):

  * **Per-step request batching** — compatible queued requests (same
    session, same feature shape) execute as one
    :meth:`GCNEngine.forward_batched` call: the batch folds into the
    feature axis of the exchange, so one relay replay moves B requests'
    payload per ppermute (deeper messages over the same link schedule —
    exactly the trade a latency-tolerant, bandwidth-bound system wants).
  * **Pipelined plan prefetch** — while the device executes session
    A's batch, :class:`~repro.gcn.pipeline.SamplePipeline` workers
    build and upload the next up-to-``prefetch_depth`` distinct
    sessions' plan arrays (host-side plan build + ``jnp.asarray``
    upload + ``block_until_ready``) CONCURRENTLY — the single
    prefetch daemon this replaced could only overlap uploads, it
    serialized the plan builds. The consumer *fences* (consumes the
    pipeline strictly in-order) before running a prefetched session,
    so results are bit-identical to the synchronous path
    (``async_upload=False`` falls back to inline uploads and is the
    reference behavior). The overlap won is reported by :meth:`stats`
    as ``upload_overlap_fraction``.

A third trick serves what the first two cannot: **layer-major
admission** (``admission="auto"``, the default). A graph whose full
plan provably exceeds the plan-store budget
(:func:`repro.gcn.inference.plan_over_budget` — a lower-bound test
that never builds the plan) is admitted anyway and served through
:meth:`GCNEngine.forward_layer_major`: every layer runs for all
vertices in bounded 1-hop chunks with ``h_l`` materialized on the
host, bit-identical to full-graph forward. Over-budget graphs become
servable instead of erroring; ``admission="layer-major"`` forces the
chunked path for every session, ``admission="full"`` restores the
pre-PR-8 behavior.

Because every session shares the byte-bounded caches in
``repro.gcn.cache``, admitting more graphs than the plan budget holds
simply evicts the least-recently-served one; re-admission replans
exactly once (see ``tests/test_gcn_cache.py``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Sequence

import jax
import numpy as np

from repro.config import GCNConfig
from repro.core.graph import Graph
from repro.gcn import cache, inference, obs
from repro.gcn.engine import GCNEngine
from repro.gcn.pipeline import SamplePipeline

__all__ = ["GCNService", "ServeRequest"]


@dataclass
class ServeRequest:
    """One feature-inference request against an admitted graph."""

    rid: int
    session: str
    # (V, F) global host features, or None for a store-backed request
    # (served from the session's registered features through the
    # process-wide feature store's device-resident cache)
    feats: np.ndarray | None
    out: np.ndarray | None = None  # (V, F_out) once done
    done: bool = False
    # timing (perf_counter seconds; t_done - t_submit = request latency)
    t_submit: float = 0.0
    t_done: float = 0.0


@dataclass
class _Counters:
    requests: int = 0
    batches: int = 0
    busy_s: float = 0.0  # time inside step(): fence + upload + execute
    exec_s: float = 0.0
    upload_s: float = 0.0
    upload_overlap_s: float = 0.0
    uploads: int = 0
    uploads_async: int = 0
    t_first: float = 0.0
    t_last: float = 0.0
    exec_windows: list = field(default_factory=list)
    # bucket counts retired from evicted sessions, so stats() history
    # survives eviction (live sessions report current - admission base)
    bucket_calls_retired: int = 0
    bucket_hits_retired: int = 0


class GCNService:
    """Multi-graph serving frontend over shared GCN sessions.

    Typical use::

        svc = GCNService((4, 2), plan_budget_bytes=256 << 20)
        svc.admit("social", cfg_a, graph_a, layer_dims=[64, 16])
        svc.admit("web", cfg_b, graph_b, layer_dims=[32, 8])
        svc.submit("social", feats0)
        svc.submit("web", feats1)
        done = svc.run()          # list of completed ServeRequests
        print(svc.stats()["requests_per_sec"])

    ``max_batch`` caps how many compatible requests one step executes;
    ``async_upload=False`` selects the synchronous fallback (identical
    results, no upload/execute overlap). ``plan_budget_bytes``
    reconfigures the PROCESS-GLOBAL plan store (the cache layers are
    shared across all services/engines by design — that sharing is the
    point): the last setter wins, and shrinking can evict another
    service's plans. Omit it to keep the current budget.

    ``admission`` picks each session's serving mode at admit time:
    ``"full"`` = always full-graph ``forward_batched``;
    ``"layer-major"`` = always chunked layer-major inference;
    ``"auto"`` (default) = layer-major only when the session's full
    plan provably cannot fit the plan budget (otherwise full — a
    within-budget graph keeps the batched fast path). ``chunk_size``
    sizes the layer-major chunks; ``prefetch_depth`` /
    ``prefetch_workers`` shape the plan-prefetch pipeline.
    """

    def __init__(self, mesh_dims: Sequence[int], *,
                 axis_names: Sequence[str] | None = None,
                 max_batch: int = 8, async_upload: bool = True,
                 plan_budget_bytes: int | None = None,
                 admission: str = "auto", chunk_size: int = 128,
                 prefetch_depth: int = 2, prefetch_workers: int = 2):
        self.dims = tuple(int(d) for d in mesh_dims)
        self.axis_names = tuple(axis_names) if axis_names else None
        self.max_batch = int(max_batch)
        self.async_upload = bool(async_upload)
        if admission not in ("full", "layer-major", "auto"):
            raise ValueError(
                f"admission must be 'full', 'layer-major' or 'auto'; "
                f"got {admission!r}")
        self.admission = admission
        self.chunk_size = int(chunk_size)
        self.prefetch_depth = max(int(prefetch_depth), 1)
        self.prefetch_workers = max(int(prefetch_workers), 1)
        if plan_budget_bytes is not None:
            cache.set_cache_budget(plan_bytes=int(plan_budget_bytes))
        self.sessions: dict[str, GCNEngine] = {}
        # per-session feature-store handle (None = no registered
        # features; submit() then requires a per-request array)
        self._feat_handles: dict[str, object] = {}
        # per-session serving mode, decided at admit/adopt time:
        # "full" | "layer-major"
        self._mode: dict[str, str] = {}
        self.queue: list[ServeRequest] = []
        self._next_rid = 0
        # in-flight plan-prefetch pipeline (None = idle): task list of
        # (name, engine) pairs consumed strictly in-order at the fence
        self._pf: SamplePipeline | None = None
        self._pf_tasks: list[str] = []
        self._pf_next = 0
        self._c = _Counters()
        # per-session bucket-counter baseline at admission: an adopted
        # engine may arrive with pre-service counts (trainer use), and
        # this service should report only traffic it scheduled
        self._bucket_base: dict[str, tuple[int, int]] = {}

    # ---------------- admission ----------------

    def admit(self, name: str, cfg: GCNConfig, graph: Graph, *,
              layer_dims: Sequence[int] | None = None, params=None,
              seed: int = 0, features=None) -> GCNEngine:
        """Register graph ``graph`` under ``name`` as a servable session
        on the service's mesh. Either pass trained ``params`` or
        ``layer_dims`` (``[feat_in, hidden..., out]``) to initialize
        fresh ones from ``seed``. Admission is host-side bookkeeping
        only — the plan is built (or found in the shared cache) on first
        execution or prefetch.

        ``features`` (a global ``(V, F)`` array or an existing
        :class:`~repro.gcn.featurestore.FeatureHandle`) registers the
        graph's vertex features with the process-wide feature store, so
        ``submit(name)`` (no per-request array) serves them through the
        device-resident hot-vertex cache — repeated requests against
        the same hot vertices stop re-reading host memory."""
        if name in self.sessions:
            raise ValueError(f"session {name!r} already admitted")
        with obs.trace.span("serve_admit", session=name):
            eng = GCNEngine.build(cfg, graph, self.dims,
                                  axis_names=self.axis_names)
            if params is not None:
                eng.params = list(params)
            elif layer_dims is not None:
                eng.init_params(jax.random.PRNGKey(seed),
                                list(layer_dims))
            self.sessions[name] = eng
            self._mode[name] = self._decide_mode(eng)
            self._bucket_base[name] = (eng._bucket_calls,
                                       eng._bucket_hits)
            self._attach_features(name, eng, features)
        return eng

    def _decide_mode(self, eng: GCNEngine) -> str:
        """The session's serving mode under this service's admission
        policy. ``auto`` asks :func:`repro.gcn.inference.
        plan_over_budget` — a provable lower bound on the full plan's
        bytes vs the plan-store budget, evaluated WITHOUT preparing or
        planning anything — so an over-budget graph is admitted
        straight onto the chunked path and its full-graph plan is
        never built."""
        if self.admission == "layer-major":
            return "layer-major"
        if self.admission == "auto" and inference.plan_over_budget(eng):
            return "layer-major"
        return "full"

    def session_mode(self, name: str) -> str:
        """``"full"`` or ``"layer-major"`` for an admitted session."""
        return self._mode[name]

    def _attach_features(self, name: str, eng: GCNEngine,
                         features) -> None:
        """Resolve a session's store-backed feature source: an explicit
        array registers (content-hashed — identical re-registration
        keeps the warm tiers), a handle attaches as-is, and ``None``
        adopts whatever the process-wide store already holds for the
        graph (the train->serve handoff: the trainer registered them)."""
        from repro.gcn import featurestore

        store = featurestore.default_store()
        if features is None:
            self._feat_handles[name] = store.handle_for(eng.graph_fp)
        elif isinstance(features, featurestore.FeatureHandle):
            self._feat_handles[name] = features
        else:
            self._feat_handles[name] = store.register(
                eng.graph, features, graph_fp=eng.graph_fp)

    def session_features(self, name: str):
        """The session's store-registered
        :class:`~repro.gcn.featurestore.FeatureHandle` (None if it has
        none) — what a store-backed ``submit(name)`` serves; gather
        through it to reproduce those requests' inputs exactly."""
        return self._feat_handles.get(name)

    def adopt(self, name: str, engine: GCNEngine, *,
              params=None, features=None) -> GCNEngine:
        """Admit an EXISTING session object — the train->serve handoff.

        A :class:`~repro.gcn.train.GCNTrainer` leaves its trained params
        on its engine; adopting that engine serves them with ZERO
        rebuilt state: the plan, ELL layouts, uploaded device arrays and
        compiled steps the session already holds (all shared through
        ``repro.gcn.cache``) carry over as-is, so serving starts without
        replanning or re-uploading. The engine must live on this
        service's mesh dims; pass ``params=`` to override what it
        carries. Feature handoff rides along: features the trainer
        registered with the process-wide store (``fit_sampled`` does so
        automatically) attach to the session, so store-backed requests
        serve them warm; pass ``features=`` to register/override
        explicitly."""
        if name in self.sessions:
            raise ValueError(f"session {name!r} already admitted")
        if engine.dims != self.dims:
            raise ValueError(
                f"engine mesh {engine.dims} != service mesh {self.dims}")
        if params is not None:
            engine.params = list(params)
        if engine.params is None:
            raise ValueError(
                "adopted engine has no params; train it first or pass "
                "params=")
        with obs.trace.span("serve_admit", session=name, adopted=True):
            self.sessions[name] = engine
            self._mode[name] = self._decide_mode(engine)
            self._bucket_base[name] = (engine._bucket_calls,
                                       engine._bucket_hits)
            self._attach_features(name, engine, features)
        return engine

    def evict(self, name: str) -> None:
        """Forget a session (pending requests for it are dropped; a
        never-admitted name is a no-op, so teardown paths can call this
        unconditionally). The shared caches keep its plan until byte
        pressure evicts it."""
        eng = self.sessions.pop(name, None)
        self._feat_handles.pop(name, None)
        self._mode.pop(name, None)
        if eng is not None:
            # retire the session's bucket counts so stats() history
            # survives eviction instead of vanishing with the session
            base_c, base_h = self._bucket_base.pop(name, (0, 0))
            self._c.bucket_calls_retired += eng._bucket_calls - base_c
            self._c.bucket_hits_retired += eng._bucket_hits - base_h
        self.queue = [r for r in self.queue if r.session != name]

    # ---------------- request queue ----------------

    def submit(self, name: str,
               feats: np.ndarray | None = None) -> ServeRequest:
        """Enqueue one feature-inference request; returns the request
        handle (``.out`` is filled when served). ``feats`` is a (V, F)
        per-request array, or ``None`` to serve the session's
        store-registered features (admitted with ``features=``) through
        the feature store's device-resident cache — the recurring
        hot-vertex workload the storage tier exists for."""
        eng = self.sessions[name]  # KeyError = not admitted, on purpose
        if feats is None:
            if self._feat_handles.get(name) is None:
                raise ValueError(
                    f"session {name!r} has no store-registered features; "
                    "admit with features= or pass a per-request array")
        else:
            feats = np.asarray(feats)
            if (feats.ndim != 2
                    or feats.shape[0] != eng.graph.num_vertices):
                raise ValueError(
                    f"request for {name!r} must be "
                    f"(V={eng.graph.num_vertices}, F); got {feats.shape}")
        req = ServeRequest(self._next_rid, name, feats,
                           t_submit=time.perf_counter())
        self._next_rid += 1
        self.queue.append(req)
        return req

    def _pop_batch(self) -> list[ServeRequest]:
        """Head-of-line batch: the oldest request plus up to
        ``max_batch - 1`` later requests that are compatible with it
        (same session, same feature shape; store-backed requests batch
        with store-backed requests — they share one gather). Order is
        preserved for the rest of the queue."""
        def shape(r):
            return None if r.feats is None else r.feats.shape

        head = self.queue[0]
        batch, rest = [head], []
        for r in self.queue[1:]:
            if (len(batch) < self.max_batch and r.session == head.session
                    and shape(r) == shape(head)):
                batch.append(r)
            else:
                rest.append(r)
        self.queue = rest
        return batch

    # ---------------- plan upload (the double buffer) ----------------

    def _upload(self, eng: GCNEngine) -> float:
        """Build + upload one session's plan arrays and fence with
        ``block_until_ready``; returns the wall seconds spent (0.0 when
        the session is already resident). Takes the engine object (not a
        name) so an in-flight background upload keeps a coherent target
        even if the session is evicted meanwhile. Deliberately does NOT
        touch the counters — only the main thread folds durations into
        ``_Counters`` (sync path inline, async path at the fence), so a
        prefetch thread and a concurrent sync upload never race on
        them."""
        if eng.plan_uploaded():
            return 0.0
        t0 = time.perf_counter()
        with obs.trace.span("serve_upload", graph=eng.graph_fp[:12]):
            jax.block_until_ready(eng.plan_arrays())
        dt = time.perf_counter() - t0
        obs.metrics.counter(
            "serve.upload_s", unit="s",
            help="wall seconds spent uploading serve-session plans"
        ).add(dt)
        return dt

    def _count_upload(self, seconds: float, *, was_async: bool) -> None:
        if seconds <= 0.0:
            return
        self._c.upload_s += seconds
        self._c.uploads += 1
        if was_async:
            self._c.uploads_async += 1

    def _start_prefetch(self, exclude: str) -> None:
        """Kick background uploads for the next up-to-``prefetch_depth``
        distinct full-plan sessions in the queue that are not resident
        (the 'filling' buffers). :class:`~repro.gcn.pipeline.
        SamplePipeline`-backed: ``prefetch_workers`` threads build +
        upload DIFFERENT sessions' plans concurrently (the single
        daemon this replaced serialized the plan builds — only the
        uploads overlapped), and the fence consumes results strictly
        in task order. Layer-major sessions have no full plan to
        upload and are skipped. At most one pipeline in flight."""
        if not self.async_upload or self._pf is not None:
            return
        seen: set[str] = set()
        tasks: list[tuple[str, GCNEngine]] = []
        for r in self.queue:
            n = r.session
            if n in seen or n == exclude:
                seen.add(n)
                continue
            seen.add(n)
            eng = self.sessions[n]
            if self._mode.get(n) == "layer-major" or eng.plan_uploaded():
                continue
            # capture the engine object: an in-flight upload keeps a
            # coherent target even if the session is evicted meanwhile
            tasks.append((n, eng))
            if len(tasks) >= self.prefetch_depth:
                break
        if not tasks:
            return

        def prep(task):
            # error-as-VALUE, never raised here: SamplePipeline.get
            # closes the whole pipeline when prepare raises, but an
            # upload failure must survive to the fence, which drops it
            # if the session was evicted meanwhile (moot) and re-raises
            # it otherwise
            _, eng = task
            t0 = time.perf_counter()
            secs, err = 0.0, None
            try:
                secs = self._upload(eng)
            except BaseException as e:
                err = e
            return t0, time.perf_counter(), secs, err

        self._pf = SamplePipeline(tasks, prep, depth=len(tasks),
                                  workers=self.prefetch_workers,
                                  name="gcn-serve-upload")
        self._pf_tasks = [n for n, _ in tasks]
        self._pf_next = 0

    def _close_pf(self) -> None:
        if self._pf is not None:
            self._pf.close()
        self._pf = None
        self._pf_tasks = []
        self._pf_next = 0

    def _fence(self, name: str | None = None) -> None:
        """Consume in-flight prefetches, strictly in pipeline order —
        a session's plan arrays must be fully resident before its
        consumer runs. ``name=None`` drains the whole pipeline;
        otherwise only a pipeline that still holds ``name`` blocks the
        caller, and consumption stops once ``name``'s upload is folded
        in. Overlap accounting: the upload wall time that intersected
        device-execution windows counts as hidden."""
        pending = (self._pf_tasks[self._pf_next:]
                   if self._pf is not None else [])
        if not pending or (name is not None and name not in pending):
            return
        while self._pf_next < len(self._pf_tasks):
            n = self._pf_tasks[self._pf_next]
            t0, t1, secs, err = self._pf.get(self._pf_next)
            self._pf_next += 1
            self._count_upload(secs, was_async=True)
            if err is not None and n in self.sessions:
                # still admitted: surface the failure (the fence runs
                # before popping, so the requests stay queued and
                # retryable); an evicted session's failure is moot
                self._close_pf()
                raise err
            overlap = sum(
                max(0.0, min(t1, e1) - max(t0, e0))
                for e0, e1 in self._c.exec_windows)
            # the worker's window [t0, t1] also spans claim/bookkeeping
            # overhead, but only ``secs`` of actual upload was hideable
            # — clamp so the reported fraction can never exceed 1.0
            self._c.upload_overlap_s += min(overlap, secs)
            self._c.exec_windows = [w for w in self._c.exec_windows
                                    if w[1] > t1]
            if n == name:
                break
        if self._pf_next >= len(self._pf_tasks):
            self._close_pf()

    # ---------------- execution ----------------

    def step(self) -> list[ServeRequest]:
        """One service tick: fence any prefetch for the head-of-line
        session (sync-upload it if it is not resident), pop its batch,
        start the NEXT session's upload in the background, execute the
        batch, complete its requests. Returns the completed requests."""
        if not self.queue:
            self._fence()
            return []
        ts = time.perf_counter()
        if not self._c.t_first:
            self._c.t_first = ts
        # fence BEFORE popping: a re-raised upload error must leave the
        # head-of-line requests queued (retryable), not silently dropped
        name = self.queue[0].session
        eng = self.sessions[name]
        mode = self._mode.get(name, "full")
        with obs.trace.span("serve_step", session=name,
                            mode=mode) as sp:
            self._fence(name)
            if mode == "full" and not eng.plan_uploaded():
                # sync path / first-touch / post-eviction upload
                self._count_upload(self._upload(eng), was_async=False)
            batch = self._pop_batch()
            sp.set(batch=len(batch))
            self._start_prefetch(exclude=name)
            if mode != "layer-major":
                if batch[0].feats is None:
                    # store-backed: one gather serves the whole batch;
                    # repeat steps against the same session hit
                    # device-resident blocks
                    xb = self._feat_handles[name].gather_all()
                    feats = np.stack([xb] * len(batch))
                else:
                    feats = np.stack([r.feats for r in batch])
            t0 = time.perf_counter()
            try:
                with obs.trace.span("execute", what="serve_batch",
                                    session=name, mode=mode):
                    if mode == "layer-major":
                        # chunked layer-major serving: the full-graph
                        # plan is never built; store-backed requests
                        # hand the handle straight through (gathered
                        # per chunk — no full-V materialization
                        # anywhere on this path)
                        out = np.stack([
                            eng.forward_layer_major(
                                self._feat_handles[name]
                                if r.feats is None else r.feats,
                                chunk_size=self.chunk_size)
                            for r in batch])
                    else:
                        out = eng.forward_batched(feats)
            except BaseException:
                # nothing completed: put the batch back at the head so
                # an execution error (bad feature width, transient OOM)
                # leaves the requests retryable/observable instead of
                # vanishing
                self.queue = batch + self.queue
                raise
            t1 = time.perf_counter()
            if self._pf is None:
                # nothing in flight: no future prefetch can overlap
                # windows that already closed, so don't accumulate them
                self._c.exec_windows.clear()
            self._c.exec_windows.append((t0, t1))
            self._c.exec_s += t1 - t0
            self._c.batches += 1
            for b, r in enumerate(batch):
                r.out = out[b]
                r.done = True
                r.t_done = t1
            self._c.requests += len(batch)
            self._c.busy_s += t1 - ts
            self._c.t_last = t1
        obs.metrics.counter(
            "serve.batches", unit="batches",
            help="service batches executed").add(1)
        obs.metrics.counter(
            "serve.requests", unit="requests",
            help="requests completed by the service").add(len(batch))
        return batch

    def run(self, max_steps: int = 100_000) -> list[ServeRequest]:
        """Tick until the queue drains; returns completed requests in
        completion order."""
        done: list[ServeRequest] = []
        for _ in range(max_steps):
            if not self.queue:
                break
            done.extend(self.step())
        self._fence()
        return done

    def infer(self, name: str, feats: np.ndarray) -> np.ndarray:
        """Convenience synchronous call: submit + run one request."""
        req = self.submit(name, feats)
        self.run()
        return req.out

    # ---------------- accounting ----------------

    def stats(self) -> dict:
        """Serving counters merged with the shared cache layers'.

        ``upload_overlap_fraction`` is the share of total plan-upload
        wall time that ran concurrently with device execution — the
        paper's latency-tolerance dividend (1.0 = every upload fully
        hidden; 0.0 = sync fallback; ``None`` until an upload was
        measured — ratio fields here report ``None``, never a silent
        0.0, when nothing ran).
        ``requests_per_sec`` is throughput over BUSY time (seconds spent
        inside ``step``), so idle gaps between ``run`` calls on a
        long-lived service don't dilute it; ``wall_s`` is the raw
        first-step-to-last-step span.

        Layer-major serving telemetry aggregates over the admitted
        layer-major sessions' :meth:`GCNEngine.inference_stats` (all
        plan-free): ``peak_feature_bytes`` is the worst per-session
        device-feature high-water mark, ``inference_overlap_fraction``
        pools chunk-prepare time hidden behind execution, and the
        chunk-bucket counters mirror the batch-bucket ones.
        """
        c = self._c
        wall = max(c.t_last - c.t_first, 0.0)
        bucket_calls = c.bucket_calls_retired + sum(
            e._bucket_calls - self._bucket_base[n][0]
            for n, e in self.sessions.items())
        bucket_hits = c.bucket_hits_retired + sum(
            e._bucket_hits - self._bucket_base[n][1]
            for n, e in self.sessions.items())
        lm_engines = [e for n, e in self.sessions.items()
                      if self._mode.get(n) == "layer-major"]
        lm = [e.inference_stats() for e in lm_engines]
        chunk_calls = sum(s["chunk_bucket_calls"] for s in lm)
        chunk_hits = sum(s["chunk_bucket_hits"] for s in lm)
        # pooled chunk-prepare overlap across layer-major sessions,
        # from the raw per-run seconds (hidden / total prepare);
        # None until a layer-major pipeline has actually run
        prep_s = sum((e._inference_stats or {}).get("prepare_s", 0.0)
                     for e in lm_engines)
        hidden_s = sum((e._inference_stats or {}).get("overlap_s", 0.0)
                       for e in lm_engines)
        ov = obs.overlap_fraction(hidden_s, prep_s, default=None)
        return {
            "admission": self.admission,
            "sessions_layer_major": sum(
                1 for m in self._mode.values() if m == "layer-major"),
            "peak_feature_bytes": max(
                (s["peak_feature_bytes"] for s in lm), default=0),
            "dense_feature_bytes": max(
                (s["dense_feature_bytes"] for s in lm), default=0),
            "inference_overlap_fraction": ov,
            "chunk_bucket_calls": chunk_calls,
            "chunk_bucket_hits": chunk_hits,
            "chunk_bucket_hit_rate": obs.ratio(
                chunk_hits, chunk_calls, default=None),
            "sessions": len(self.sessions),
            "queued": len(self.queue),
            # forward_batched power-of-two bucketing across all
            # sessions: the hit rate is the fraction of batched calls
            # that reused an already-compiled padded batch size
            "batch_bucket_calls": bucket_calls,
            "batch_bucket_hits": bucket_hits,
            "batch_bucket_hit_rate": obs.ratio(
                bucket_hits, bucket_calls, default=None),
            "requests": c.requests,
            "batches": c.batches,
            "mean_batch": c.requests / max(c.batches, 1),
            "wall_s": wall,
            "busy_s": c.busy_s,
            "exec_s": c.exec_s,
            "upload_s": c.upload_s,
            "uploads": c.uploads,
            "uploads_async": c.uploads_async,
            "upload_overlap_s": c.upload_overlap_s,
            "upload_overlap_fraction": obs.overlap_fraction(
                c.upload_overlap_s, c.upload_s, default=None),
            "requests_per_sec": obs.ratio(c.requests, c.busy_s),
            "async_upload": self.async_upload,
            "cache": cache.cache_stats(),
        }
