"""``GCNService`` — multi-graph GCN inference serving on one substrate.

The top layer of the session/cache/service split. Where
:class:`~repro.gcn.engine.GCNEngine` is one graph's session and
:mod:`repro.gcn.cache` is the process-wide mapping/compile store, the
service is the scheduler: it owns ONE mesh, admits many named graphs
(``service.admit(name, cfg, graph)``), queues feature-inference
requests across them, and drives execution in steps. It mirrors the
slot-pool design of ``repro.serve.engine.ServeEngine`` (the LM-side
substrate): ``submit`` enqueues, ``step`` admits-and-advances, ``run``
ticks until drained.

Two serving tricks, both straight from the paper's characterization
(Observation 2: MultiAccSys GCN execution is bandwidth-bound and
latency-tolerant):

  * **Per-step request batching** — compatible queued requests (same
    session, same feature shape) execute as one
    :meth:`GCNEngine.forward_batched` call: the batch folds into the
    feature axis of the exchange, so one relay replay moves B requests'
    payload per ppermute (deeper messages over the same link schedule —
    exactly the trade a latency-tolerant, bandwidth-bound system wants).
  * **Async double-buffered plan upload** — while the device executes
    session A's batch, a background thread builds and uploads the NEXT
    distinct session's plan arrays (host-side plan build +
    ``device_put``-equivalent ``jnp.asarray`` + ``block_until_ready``).
    At most one prefetch is in flight (the classic two buffers:
    executing + filling); the consumer *fences* on the prefetch thread
    before running that session, so results are bit-identical to the
    synchronous path (``async_upload=False`` falls back to inline
    uploads and is the reference behavior). The overlap won is reported
    by :meth:`stats` as ``upload_overlap_fraction``.

Because every session shares the byte-bounded caches in
``repro.gcn.cache``, admitting more graphs than the plan budget holds
simply evicts the least-recently-served one; re-admission replans
exactly once (see ``tests/test_gcn_cache.py``).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Sequence

import jax
import numpy as np

from repro.config import GCNConfig
from repro.core.graph import Graph
from repro.gcn import cache
from repro.gcn.engine import GCNEngine

__all__ = ["GCNService", "ServeRequest"]


@dataclass
class ServeRequest:
    """One feature-inference request against an admitted graph."""

    rid: int
    session: str
    # (V, F) global host features, or None for a store-backed request
    # (served from the session's registered features through the
    # process-wide feature store's device-resident cache)
    feats: np.ndarray | None
    out: np.ndarray | None = None  # (V, F_out) once done
    done: bool = False
    # timing (perf_counter seconds; t_done - t_submit = request latency)
    t_submit: float = 0.0
    t_done: float = 0.0


@dataclass
class _Prefetch:
    """One in-flight background upload (the 'filling' buffer)."""

    session: str
    thread: threading.Thread
    t_start: float
    t_end: float = 0.0
    seconds: float = 0.0  # upload wall time, folded into counters at the fence
    error: BaseException | None = None


@dataclass
class _Counters:
    requests: int = 0
    batches: int = 0
    busy_s: float = 0.0  # time inside step(): fence + upload + execute
    exec_s: float = 0.0
    upload_s: float = 0.0
    upload_overlap_s: float = 0.0
    uploads: int = 0
    uploads_async: int = 0
    t_first: float = 0.0
    t_last: float = 0.0
    exec_windows: list = field(default_factory=list)
    # bucket counts retired from evicted sessions, so stats() history
    # survives eviction (live sessions report current - admission base)
    bucket_calls_retired: int = 0
    bucket_hits_retired: int = 0


class GCNService:
    """Multi-graph serving frontend over shared GCN sessions.

    Typical use::

        svc = GCNService((4, 2), plan_budget_bytes=256 << 20)
        svc.admit("social", cfg_a, graph_a, layer_dims=[64, 16])
        svc.admit("web", cfg_b, graph_b, layer_dims=[32, 8])
        svc.submit("social", feats0)
        svc.submit("web", feats1)
        done = svc.run()          # list of completed ServeRequests
        print(svc.stats()["requests_per_sec"])

    ``max_batch`` caps how many compatible requests one step executes;
    ``async_upload=False`` selects the synchronous fallback (identical
    results, no upload/execute overlap). ``plan_budget_bytes``
    reconfigures the PROCESS-GLOBAL plan store (the cache layers are
    shared across all services/engines by design — that sharing is the
    point): the last setter wins, and shrinking can evict another
    service's plans. Omit it to keep the current budget.
    """

    def __init__(self, mesh_dims: Sequence[int], *,
                 axis_names: Sequence[str] | None = None,
                 max_batch: int = 8, async_upload: bool = True,
                 plan_budget_bytes: int | None = None):
        self.dims = tuple(int(d) for d in mesh_dims)
        self.axis_names = tuple(axis_names) if axis_names else None
        self.max_batch = int(max_batch)
        self.async_upload = bool(async_upload)
        if plan_budget_bytes is not None:
            cache.set_cache_budget(plan_bytes=int(plan_budget_bytes))
        self.sessions: dict[str, GCNEngine] = {}
        # per-session feature-store handle (None = no registered
        # features; submit() then requires a per-request array)
        self._feat_handles: dict[str, object] = {}
        self.queue: list[ServeRequest] = []
        self._next_rid = 0
        self._prefetch: _Prefetch | None = None
        self._c = _Counters()
        # per-session bucket-counter baseline at admission: an adopted
        # engine may arrive with pre-service counts (trainer use), and
        # this service should report only traffic it scheduled
        self._bucket_base: dict[str, tuple[int, int]] = {}

    # ---------------- admission ----------------

    def admit(self, name: str, cfg: GCNConfig, graph: Graph, *,
              layer_dims: Sequence[int] | None = None, params=None,
              seed: int = 0, features=None) -> GCNEngine:
        """Register graph ``graph`` under ``name`` as a servable session
        on the service's mesh. Either pass trained ``params`` or
        ``layer_dims`` (``[feat_in, hidden..., out]``) to initialize
        fresh ones from ``seed``. Admission is host-side bookkeeping
        only — the plan is built (or found in the shared cache) on first
        execution or prefetch.

        ``features`` (a global ``(V, F)`` array or an existing
        :class:`~repro.gcn.featurestore.FeatureHandle`) registers the
        graph's vertex features with the process-wide feature store, so
        ``submit(name)`` (no per-request array) serves them through the
        device-resident hot-vertex cache — repeated requests against
        the same hot vertices stop re-reading host memory."""
        if name in self.sessions:
            raise ValueError(f"session {name!r} already admitted")
        eng = GCNEngine.build(cfg, graph, self.dims,
                              axis_names=self.axis_names)
        if params is not None:
            eng.params = list(params)
        elif layer_dims is not None:
            eng.init_params(jax.random.PRNGKey(seed), list(layer_dims))
        self.sessions[name] = eng
        self._bucket_base[name] = (eng._bucket_calls, eng._bucket_hits)
        self._attach_features(name, eng, features)
        return eng

    def _attach_features(self, name: str, eng: GCNEngine,
                         features) -> None:
        """Resolve a session's store-backed feature source: an explicit
        array registers (content-hashed — identical re-registration
        keeps the warm tiers), a handle attaches as-is, and ``None``
        adopts whatever the process-wide store already holds for the
        graph (the train->serve handoff: the trainer registered them)."""
        from repro.gcn import featurestore

        store = featurestore.default_store()
        if features is None:
            self._feat_handles[name] = store.handle_for(eng.graph_fp)
        elif isinstance(features, featurestore.FeatureHandle):
            self._feat_handles[name] = features
        else:
            self._feat_handles[name] = store.register(
                eng.graph, features, graph_fp=eng.graph_fp)

    def session_features(self, name: str):
        """The session's store-registered
        :class:`~repro.gcn.featurestore.FeatureHandle` (None if it has
        none) — what a store-backed ``submit(name)`` serves; gather
        through it to reproduce those requests' inputs exactly."""
        return self._feat_handles.get(name)

    def adopt(self, name: str, engine: GCNEngine, *,
              params=None, features=None) -> GCNEngine:
        """Admit an EXISTING session object — the train->serve handoff.

        A :class:`~repro.gcn.train.GCNTrainer` leaves its trained params
        on its engine; adopting that engine serves them with ZERO
        rebuilt state: the plan, ELL layouts, uploaded device arrays and
        compiled steps the session already holds (all shared through
        ``repro.gcn.cache``) carry over as-is, so serving starts without
        replanning or re-uploading. The engine must live on this
        service's mesh dims; pass ``params=`` to override what it
        carries. Feature handoff rides along: features the trainer
        registered with the process-wide store (``fit_sampled`` does so
        automatically) attach to the session, so store-backed requests
        serve them warm; pass ``features=`` to register/override
        explicitly."""
        if name in self.sessions:
            raise ValueError(f"session {name!r} already admitted")
        if engine.dims != self.dims:
            raise ValueError(
                f"engine mesh {engine.dims} != service mesh {self.dims}")
        if params is not None:
            engine.params = list(params)
        if engine.params is None:
            raise ValueError(
                "adopted engine has no params; train it first or pass "
                "params=")
        self.sessions[name] = engine
        self._bucket_base[name] = (engine._bucket_calls,
                                   engine._bucket_hits)
        self._attach_features(name, engine, features)
        return engine

    def evict(self, name: str) -> None:
        """Forget a session (pending requests for it are dropped; a
        never-admitted name is a no-op, so teardown paths can call this
        unconditionally). The shared caches keep its plan until byte
        pressure evicts it."""
        eng = self.sessions.pop(name, None)
        self._feat_handles.pop(name, None)
        if eng is not None:
            # retire the session's bucket counts so stats() history
            # survives eviction instead of vanishing with the session
            base_c, base_h = self._bucket_base.pop(name, (0, 0))
            self._c.bucket_calls_retired += eng._bucket_calls - base_c
            self._c.bucket_hits_retired += eng._bucket_hits - base_h
        self.queue = [r for r in self.queue if r.session != name]

    # ---------------- request queue ----------------

    def submit(self, name: str,
               feats: np.ndarray | None = None) -> ServeRequest:
        """Enqueue one feature-inference request; returns the request
        handle (``.out`` is filled when served). ``feats`` is a (V, F)
        per-request array, or ``None`` to serve the session's
        store-registered features (admitted with ``features=``) through
        the feature store's device-resident cache — the recurring
        hot-vertex workload the storage tier exists for."""
        eng = self.sessions[name]  # KeyError = not admitted, on purpose
        if feats is None:
            if self._feat_handles.get(name) is None:
                raise ValueError(
                    f"session {name!r} has no store-registered features; "
                    "admit with features= or pass a per-request array")
        else:
            feats = np.asarray(feats)
            if (feats.ndim != 2
                    or feats.shape[0] != eng.graph.num_vertices):
                raise ValueError(
                    f"request for {name!r} must be "
                    f"(V={eng.graph.num_vertices}, F); got {feats.shape}")
        req = ServeRequest(self._next_rid, name, feats,
                           t_submit=time.perf_counter())
        self._next_rid += 1
        self.queue.append(req)
        return req

    def _pop_batch(self) -> list[ServeRequest]:
        """Head-of-line batch: the oldest request plus up to
        ``max_batch - 1`` later requests that are compatible with it
        (same session, same feature shape; store-backed requests batch
        with store-backed requests — they share one gather). Order is
        preserved for the rest of the queue."""
        def shape(r):
            return None if r.feats is None else r.feats.shape

        head = self.queue[0]
        batch, rest = [head], []
        for r in self.queue[1:]:
            if (len(batch) < self.max_batch and r.session == head.session
                    and shape(r) == shape(head)):
                batch.append(r)
            else:
                rest.append(r)
        self.queue = rest
        return batch

    # ---------------- plan upload (the double buffer) ----------------

    def _upload(self, eng: GCNEngine) -> float:
        """Build + upload one session's plan arrays and fence with
        ``block_until_ready``; returns the wall seconds spent (0.0 when
        the session is already resident). Takes the engine object (not a
        name) so an in-flight background upload keeps a coherent target
        even if the session is evicted meanwhile. Deliberately does NOT
        touch the counters — only the main thread folds durations into
        ``_Counters`` (sync path inline, async path at the fence), so a
        prefetch thread and a concurrent sync upload never race on
        them."""
        if eng.plan_uploaded():
            return 0.0
        t0 = time.perf_counter()
        jax.block_until_ready(eng.plan_arrays())
        return time.perf_counter() - t0

    def _count_upload(self, seconds: float, *, was_async: bool) -> None:
        if seconds <= 0.0:
            return
        self._c.upload_s += seconds
        self._c.uploads += 1
        if was_async:
            self._c.uploads_async += 1

    def _start_prefetch(self, exclude: str) -> None:
        """Kick the background upload for the next distinct session in
        the queue (the 'filling' buffer). At most one in flight."""
        if not self.async_upload or self._prefetch is not None:
            return
        target = next(
            (r.session for r in self.queue
             if r.session != exclude
             and not self.sessions[r.session].plan_uploaded()), None)
        if target is None:
            return
        eng = self.sessions[target]
        pf = _Prefetch(target, None, t_start=time.perf_counter())

        def work():
            try:
                pf.seconds = self._upload(eng)
            except BaseException as e:  # re-raised at the fence
                pf.error = e
            finally:
                pf.t_end = time.perf_counter()

        pf.thread = threading.Thread(
            target=work, name=f"gcn-serve-upload-{target}", daemon=True)
        pf.thread.start()
        self._prefetch = pf

    def _fence(self, name: str | None = None) -> None:
        """Join the in-flight prefetch (all of it — the plan arrays must
        be fully resident before any consumer runs). ``name=None``
        fences unconditionally; otherwise only a prefetch for ``name``
        blocks the caller. Overlap accounting: the prefetch wall time
        that intersected device-execution windows counts as hidden."""
        pf = self._prefetch
        if pf is None or (name is not None and pf.session != name):
            return
        pf.thread.join()
        self._prefetch = None
        self._count_upload(pf.seconds, was_async=True)
        if pf.error is not None:
            if pf.session not in self.sessions:
                pf.error = None  # evicted mid-upload: failure is moot
            else:
                raise pf.error
        lo, hi = pf.t_start, pf.t_end
        overlap = sum(
            max(0.0, min(hi, e1) - max(lo, e0))
            for e0, e1 in self._c.exec_windows)
        # the thread's lifetime [lo, hi] also spans spawn/bookkeeping
        # overhead, but only pf.seconds of actual upload was hideable —
        # clamp so the reported fraction can never exceed 1.0
        self._c.upload_overlap_s += min(overlap, pf.seconds)
        self._c.exec_windows = [w for w in self._c.exec_windows
                                if w[1] > hi]

    # ---------------- execution ----------------

    def step(self) -> list[ServeRequest]:
        """One service tick: fence any prefetch for the head-of-line
        session (sync-upload it if it is not resident), pop its batch,
        start the NEXT session's upload in the background, execute the
        batch, complete its requests. Returns the completed requests."""
        if not self.queue:
            self._fence()
            return []
        ts = time.perf_counter()
        if not self._c.t_first:
            self._c.t_first = ts
        # fence BEFORE popping: a re-raised upload error must leave the
        # head-of-line requests queued (retryable), not silently dropped
        name = self.queue[0].session
        eng = self.sessions[name]
        self._fence(name)
        if not eng.plan_uploaded():
            # sync path / first-touch / post-eviction upload
            self._count_upload(self._upload(eng), was_async=False)
        batch = self._pop_batch()
        self._start_prefetch(exclude=name)
        if batch[0].feats is None:
            # store-backed: one gather serves the whole batch; repeat
            # steps against the same session hit device-resident blocks
            xb = self._feat_handles[name].gather_all()
            feats = np.stack([xb] * len(batch))
        else:
            feats = np.stack([r.feats for r in batch])
        t0 = time.perf_counter()
        try:
            out = eng.forward_batched(feats)
        except BaseException:
            # nothing completed: put the batch back at the head so an
            # execution error (bad feature width, transient OOM) leaves
            # the requests retryable/observable instead of vanishing
            self.queue = batch + self.queue
            raise
        t1 = time.perf_counter()
        if self._prefetch is None:
            # nothing in flight: no future prefetch can overlap windows
            # that already closed, so don't accumulate them
            self._c.exec_windows.clear()
        self._c.exec_windows.append((t0, t1))
        self._c.exec_s += t1 - t0
        self._c.batches += 1
        for b, r in enumerate(batch):
            r.out = out[b]
            r.done = True
            r.t_done = t1
        self._c.requests += len(batch)
        self._c.busy_s += t1 - ts
        self._c.t_last = t1
        return batch

    def run(self, max_steps: int = 100_000) -> list[ServeRequest]:
        """Tick until the queue drains; returns completed requests in
        completion order."""
        done: list[ServeRequest] = []
        for _ in range(max_steps):
            if not self.queue:
                break
            done.extend(self.step())
        self._fence()
        return done

    def infer(self, name: str, feats: np.ndarray) -> np.ndarray:
        """Convenience synchronous call: submit + run one request."""
        req = self.submit(name, feats)
        self.run()
        return req.out

    # ---------------- accounting ----------------

    def stats(self) -> dict:
        """Serving counters merged with the shared cache layers'.

        ``upload_overlap_fraction`` is the share of total plan-upload
        wall time that ran concurrently with device execution — the
        paper's latency-tolerance dividend (1.0 = every upload fully
        hidden; 0.0 = sync fallback or nothing to hide).
        ``requests_per_sec`` is throughput over BUSY time (seconds spent
        inside ``step``), so idle gaps between ``run`` calls on a
        long-lived service don't dilute it; ``wall_s`` is the raw
        first-step-to-last-step span.
        """
        c = self._c
        wall = max(c.t_last - c.t_first, 0.0)
        bucket_calls = c.bucket_calls_retired + sum(
            e._bucket_calls - self._bucket_base[n][0]
            for n, e in self.sessions.items())
        bucket_hits = c.bucket_hits_retired + sum(
            e._bucket_hits - self._bucket_base[n][1]
            for n, e in self.sessions.items())
        return {
            "sessions": len(self.sessions),
            "queued": len(self.queue),
            # forward_batched power-of-two bucketing across all
            # sessions: the hit rate is the fraction of batched calls
            # that reused an already-compiled padded batch size
            "batch_bucket_calls": bucket_calls,
            "batch_bucket_hits": bucket_hits,
            "batch_bucket_hit_rate": (
                bucket_hits / bucket_calls if bucket_calls else 0.0),
            "requests": c.requests,
            "batches": c.batches,
            "mean_batch": c.requests / max(c.batches, 1),
            "wall_s": wall,
            "busy_s": c.busy_s,
            "exec_s": c.exec_s,
            "upload_s": c.upload_s,
            "uploads": c.uploads,
            "uploads_async": c.uploads_async,
            "upload_overlap_s": c.upload_overlap_s,
            "upload_overlap_fraction": (
                c.upload_overlap_s / c.upload_s if c.upload_s else 0.0),
            "requests_per_sec": c.requests / c.busy_s if c.busy_s else 0.0,
            "async_upload": self.async_upload,
            "cache": cache.cache_stats(),
        }
