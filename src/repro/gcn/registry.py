"""Pluggable message-passing-model registry for the GCN engine.

A *model* is the aggregation + combination semantics of one GNN flavour
(GCN / GIN / GraphSAGE / ...). The MultiGCN runtime keeps the executor
model-agnostic by pushing all aggregation semantics into per-edge
weights, so a model is fully described by three callables:

  * ``prepare(graph) -> (graph', edge_weights)`` — host-side: optionally
    rewrite the graph (e.g. add self loops) and emit float32 edge
    weights the planner bakes into the static schedule.
  * ``init_layer(key, fan_in, fan_out) -> dict`` — per-layer parameters.
  * ``combine(layer, agg, self_feats, last) -> array`` — the combination
    phase applied after the distributed exchange (and in the exact
    single-device reference, so the two stay comparable by definition).

Models are aggregation-BACKEND-agnostic by construction: because all
aggregation semantics live in the per-edge weights, the executor's
Compute step can run either as a COO scatter or through the Pallas
blocked-ELL kernel (``GCNConfig.agg_impl``) without the model noticing —
``combine`` always receives the same segment-summed ``agg`` tensor.

New aggregation semantics are a one-function-each addition:

    from repro.gcn import register_model, ModelSpec
    register_model("mean", prepare=..., init_layer=..., combine=...)

The three paper models are registered below from the builders in
:mod:`repro.core.gcn_models`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.core import gcn_models as gm
from repro.core.graph import Graph


@dataclass(frozen=True)
class ModelSpec:
    name: str
    prepare: Callable[[Graph], tuple[Graph, np.ndarray]]
    init_layer: Callable  # (key, fan_in, fan_out) -> dict
    combine: Callable  # (layer, agg, self_feats, last) -> array
    # registration generation: bumped on every (re-)registration so the
    # engine's caches can never serve a superseded model's results, even
    # through engines built before the re-registration
    gen: int = 0


_MODELS: dict[str, ModelSpec] = {}
_GEN = 0


def register_model(name: str, *, prepare, init_layer, combine,
                   overwrite: bool = False) -> ModelSpec:
    """Register aggregation semantics under ``name`` (see module doc)."""
    global _GEN
    if name in _MODELS:
        if not overwrite:
            raise ValueError(
                f"model {name!r} already registered (pass overwrite=True)")
        # drop superseded cache entries (correctness is guaranteed by the
        # generation stamp regardless; this frees the memory)
        from repro.gcn import engine as _engine

        _engine.invalidate_model(name)
    _GEN += 1
    spec = ModelSpec(name, prepare, init_layer, combine, gen=_GEN)
    _MODELS[name] = spec
    return spec


def get_model(name: str) -> ModelSpec:
    try:
        return _MODELS[name]
    except KeyError:
        raise KeyError(
            f"unknown message-passing model {name!r}; registered: "
            f"{registered_models()}") from None


def registered_models() -> list[str]:
    return sorted(_MODELS)


# the three paper models (Table 3 workloads)
register_model("gcn", prepare=gm.gcn_prepare, init_layer=gm.gcn_init_layer,
               combine=gm.gcn_combine)
register_model("gin", prepare=gm.gin_prepare, init_layer=gm.gin_init_layer,
               combine=gm.gin_combine)
register_model("sage", prepare=gm.sage_prepare, init_layer=gm.sage_init_layer,
               combine=gm.sage_combine)
