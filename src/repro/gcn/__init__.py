"""User-facing GCN session API.

``GCNEngine`` owns the mesh pair (jax ``Mesh`` + planner ``TorusMesh``),
the process-wide communication-plan cache, and the compiled exchange;
``register_model`` plugs new aggregation semantics into the shared
execution path. The low-level layers it composes are
``repro.core.plan`` (host-side mapping) and
``repro.core.message_passing`` (SPMD executor).
"""
from repro.gcn.engine import (
    GCNEngine,
    PlanKey,
    clear_plan_cache,
    graph_fingerprint,
    plan_cache_stats,
    resolve_agg_impl,
)
from repro.gcn.registry import (
    ModelSpec,
    get_model,
    register_model,
    registered_models,
)

__all__ = [
    "GCNEngine",
    "ModelSpec",
    "PlanKey",
    "clear_plan_cache",
    "get_model",
    "graph_fingerprint",
    "plan_cache_stats",
    "register_model",
    "registered_models",
    "resolve_agg_impl",
]
