"""User-facing GCN serving stack: session / cache / service.

``GCNEngine`` (session) owns the mesh pair (jax ``Mesh`` + planner
``TorusMesh``) and the compiled exchange for ONE graph;
``repro.gcn.cache`` owns every process-wide cache (plans, ELL layouts,
prepared graphs, compiled layer steps) with byte-bounded LRU eviction;
``GCNService`` schedules batched multi-graph inference over shared
sessions with async double-buffered plan upload. ``register_model``
plugs new aggregation semantics into the shared execution path. The
low-level layers underneath are ``repro.core.plan`` (host-side mapping)
and ``repro.core.message_passing`` (SPMD executor).
"""
from repro.gcn.cache import (
    PlanKey,
    cache_stats,
    graph_fingerprint,
    set_cache_budget,
)
from repro.gcn.engine import (
    GCNEngine,
    clear_plan_cache,
    plan_cache_stats,
    resolve_agg_impl,
)
from repro.gcn.registry import (
    ModelSpec,
    get_model,
    register_model,
    registered_models,
)
from repro.gcn.service import GCNService, ServeRequest

__all__ = [
    "GCNEngine",
    "GCNService",
    "ModelSpec",
    "PlanKey",
    "ServeRequest",
    "cache_stats",
    "clear_plan_cache",
    "get_model",
    "graph_fingerprint",
    "plan_cache_stats",
    "register_model",
    "registered_models",
    "resolve_agg_impl",
    "set_cache_budget",
]
