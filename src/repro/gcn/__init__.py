"""User-facing GCN stack: session / cache / service / trainer.

``GCNEngine`` (session) owns the mesh pair (jax ``Mesh`` + planner
``TorusMesh``) and the compiled exchange for ONE graph;
``repro.gcn.cache`` owns every process-wide cache (plans, ELL layouts,
prepared graphs, compiled layer steps) with byte-bounded LRU eviction;
``GCNService`` schedules batched multi-graph inference over shared
sessions with async double-buffered plan upload;
``repro.gcn.featurestore`` is the storage tier — a process-wide
``FeatureStore`` with a byte-budgeted, degree-ordered device cache
that every consumer gathers vertex features through;
``repro.gcn.history`` is its training-side sibling — a byte-budgeted
``HistoryStore`` of per-layer historical activations backing the
sampled trainer's control-variate (historical-aggregation) mode
(``fit_sampled(variance_reduction=True)``); ``GCNTrainer``
(``repro.gcn.train``) trains full-batch node classification THROUGH the
same exchange (its VJP is a reversed relay replay) and hands trained
params to serving via ``GCNService.adopt``; ``repro.gcn.pipeline``
overlaps the sampled trainer's whole host-side batch chain (sample ->
plan build -> feature gather -> upload) with device execution via a
bounded, order-preserving worker pool (``SamplePipeline``);
``repro.gcn.inference`` is the layer-major chunked serving path
(``forward_layer_major``) for graphs whose full plan exceeds the cache
budget — computed per layer in bounded 1-hop vertex chunks with
pipelined chunk preparation, bit-identical to full-graph forward, and
wired into ``GCNService`` admission (``admission="auto"`` routes
over-budget graphs to it). ``repro.gcn.obs`` is the cross-cutting
observability layer: one process-wide span ``Tracer`` (Chrome-trace
export of the sample -> plan -> gather -> upload -> execute chain) and
one typed ``MetricsRegistry`` every stage feeds — ``trace``,
``metrics`` and ``telemetry()`` here are its singletons.
``register_model`` plugs new aggregation semantics into the shared
execution path. The low-level layers underneath are ``repro.core.plan``
(host-side mapping) and ``repro.core.message_passing`` (SPMD executor).
"""
from repro.gcn.cache import (
    PlanKey,
    cache_stats,
    graph_fingerprint,
    set_cache_budget,
)
from repro.gcn.engine import (
    GCNEngine,
    clear_plan_cache,
    plan_cache_stats,
    resolve_agg_impl,
)
from repro.gcn.featurestore import (
    FeatureHandle,
    FeatureStore,
    default_store,
)
from repro.gcn.history import (
    HistoryStore,
    default_history,
)
from repro.gcn.inference import (
    ChunkSession,
    estimate_plan_bytes,
    forward_layer_major,
    plan_over_budget,
)
from repro.gcn.obs import (
    KNOWN_PHASES,
    TELEMETRY_SCHEMA_VERSION,
    MetricsRegistry,
    Tracer,
    metrics,
    overlap_fraction,
    telemetry,
    trace,
)
from repro.gcn.pipeline import SamplePipeline
from repro.gcn.registry import (
    ModelSpec,
    get_model,
    register_model,
    registered_models,
)
from repro.gcn.service import GCNService, ServeRequest
from repro.gcn.train import (
    BatchSession,
    FitReport,
    GCNTrainer,
    SampledFitReport,
    masked_cross_entropy,
    reference_loss_and_grad,
)

__all__ = [
    "BatchSession",
    "ChunkSession",
    "FeatureHandle",
    "FeatureStore",
    "FitReport",
    "GCNEngine",
    "GCNService",
    "GCNTrainer",
    "HistoryStore",
    "KNOWN_PHASES",
    "MetricsRegistry",
    "ModelSpec",
    "PlanKey",
    "SamplePipeline",
    "SampledFitReport",
    "ServeRequest",
    "TELEMETRY_SCHEMA_VERSION",
    "Tracer",
    "cache_stats",
    "clear_plan_cache",
    "default_history",
    "default_store",
    "estimate_plan_bytes",
    "forward_layer_major",
    "get_model",
    "graph_fingerprint",
    "masked_cross_entropy",
    "metrics",
    "overlap_fraction",
    "plan_cache_stats",
    "plan_over_budget",
    "reference_loss_and_grad",
    "register_model",
    "registered_models",
    "resolve_agg_impl",
    "set_cache_budget",
    "telemetry",
    "trace",
]
