"""Process-wide feature store: the storage tier under the GCN stack.

The paper's core observation (§III) is that large-graph GCN execution is
dominated by redundant movement of the same power-law-hot vertex
features — its multicast mechanism cuts 73 % of off-chip accesses by
exploiting exactly that reuse. This module is the repro's storage-side
analog: vertex features live behind a byte-budgeted, device-resident
hot-vertex cache instead of being handed around as dense ``(V, F)``
host arrays and re-sliced per batch.

Two device tiers over one host-backed column store:

  * **hot tier** — degree-ordered admission: at :meth:`FeatureStore.
    register` the vertex blocks (``block_vertices`` rows each) are
    ranked by total in-degree and the hottest blocks are *pinned*
    on device, in rank order, up to ``hot_fraction`` of the byte
    budget. Power-law graphs concentrate most feature reads in the
    top-ranked blocks, so the pins alone absorb the bulk of traffic
    (the paper's hub-reuse observation, applied to storage).
  * **cold tier** — a byte-bounded LRU (:class:`repro.gcn.cache.
    _LruStore`, the same machinery as the plan/ELL/prep/batch layers)
    over the remaining budget: a missed block is admitted on first
    touch and evicted least-recently-used.
  * **column store** — the host tier: features are held as per-block
    row chunks, so a miss gathers the touched blocks (or just the
    touched rows, when a block cannot fit the budget) instead of
    fancy-indexing one dense global array.

Keys are ``(graph fingerprint, vertex block)`` — two graphs' blocks can
never collide, and evicting a graph's *plan* releases its cached device
blocks too (the cache layer's eviction cascade calls
:meth:`FeatureStore.release_device`; the host column store survives, so
the graph simply re-warms through the cold tier).

The module-level default store is the process-wide instance the cache
layer budgets (``set_cache_budget(feature_bytes=...)``), reports
(``cache_stats()["features"]``) and clears (``clear_plan_cache()``);
standalone :class:`FeatureStore` instances are self-contained (own lock,
own budget) for tests and tooling.

Telemetry is row-honest: ``hit_rows`` / ``miss_rows`` count served rows
by tier, ``gathered_bytes`` counts exactly what was read from the host
tier (full blocks on admission, touched rows when admission is
impossible), and ``dense_bytes`` is the dense-slice baseline — what the
pre-store code path would have read from host for the same access
sequence. ``1 - gathered/dense`` is the measured feature-byte
reduction ``GCNEngine.stats`` reports next to ``agg_traffic_reduction``.
"""
from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.gcn import cache, obs

__all__ = ["FeatureHandle", "FeatureStore", "default_store"]

# process-wide gather ledger (repro.gcn.obs) — the registry-side view of
# the per-graph row-honest counters below; these are the numbers the
# PAPER_MAPPING ties to the paper's 73 % off-chip-access reduction
_HIT_ROWS = obs.metrics.counter(
    "feature.hit_rows", unit="rows",
    help="feature rows served from device-resident blocks")
_MISS_ROWS = obs.metrics.counter(
    "feature.miss_rows", unit="rows",
    help="feature rows that touched the host tier")
_GATHERED_BYTES = obs.metrics.counter(
    "feature.gathered_bytes", unit="bytes",
    help="bytes actually read from the host tier by gathers")
_DENSE_BYTES = obs.metrics.counter(
    "feature.dense_bytes", unit="bytes",
    help="dense-slice baseline bytes for the same gather sequence")
_FULL_GATHERS = obs.metrics.counter(
    "feature.full_gathers", unit="calls",
    help="gather_all calls (sampled training keeps this at zero)")


@dataclass(frozen=True, eq=False)
class FeatureHandle:
    """A registered graph's feature source — what ``forward`` /
    ``forward_batched`` / ``fit_sampled`` accept in place of a dense
    ``(V, F)`` array. Thin and immutable: all state lives in the
    store."""

    store: "FeatureStore"
    graph_fp: str
    num_vertices: int
    feat_dim: int
    block_vertices: int

    def gather(self, nodes) -> np.ndarray:
        """Rows for ``nodes`` (global ids) -> ``(len(nodes), F)`` f32,
        served from device-resident blocks where possible."""
        return self.store.gather(self.graph_fp, nodes)

    def gather_all(self) -> np.ndarray:
        """The full ``(V, F)`` table (full-graph inference/eval path —
        the sampled training path must never need this)."""
        return self.store.gather_all(self.graph_fp)

    def stats(self) -> dict:
        return self.store.graph_stats(self.graph_fp)


@dataclass
class _GraphFeatures:
    """One registration: host column store + degree ranking + pins."""

    graph_fp: str
    feat_fp: str  # content hash of the registered features (reuse check)
    num_vertices: int
    feat_dim: int
    block_vertices: int
    blocks: list  # host column store: per-block (<=bv, F) f32 chunks
    rank: np.ndarray  # block ids, hottest (highest in-degree mass) first
    rank_of: np.ndarray  # block id -> admission rank
    pinned: dict = field(default_factory=dict)  # block id -> device array
    # row-honest counters (per graph, so engine.stats can report them)
    hits: int = 0  # block accesses served device-resident
    misses: int = 0  # block accesses that touched the host tier
    hit_rows: int = 0
    miss_rows: int = 0
    gathered_bytes: int = 0  # bytes actually read from the host tier
    dense_bytes: int = 0  # dense-slice baseline for the same accesses
    full_gathers: int = 0  # gather_all calls (sampled training: zero)

    @property
    def num_blocks(self) -> int:
        return len(self.blocks)

    @property
    def rowbytes(self) -> int:
        return self.feat_dim * 4  # float32


def _check_budget(budget_bytes):
    """None (unbounded) or a non-negative byte count. A negative budget
    used to be accepted silently and behave like 0 in some paths while
    draining pins in others — reject it outright so the unset (None)
    and zero corners are the only special cases the tiers handle."""
    if budget_bytes is None:
        return None
    b = int(budget_bytes)
    if b < 0:
        raise ValueError(f"budget_bytes must be >= 0 or None: "
                         f"{budget_bytes}")
    return b


class FeatureStore:
    """Byte-budgeted vertex-feature cache: pinned hot tier + LRU cold
    tier over a host-backed column store. See the module docstring for
    the design; :func:`default_store` for the process-wide instance.

    Concurrency contract: every public method runs fully under
    ``self.lock`` (for the default store that is ``repro.gcn.cache.
    _LOCK``, shared with the six cache layers — reentrant, so the
    plan-eviction cascade may call :meth:`release_device` while holding
    it). The sampled pipeline's builder threads
    (``repro.gcn.pipeline``) call :meth:`gather` concurrently with the
    training thread and with budget shrinks: gathers are atomic
    (resident-check, host read, cold-tier admission and counter updates
    happen under one lock hold), so a concurrent eviction or
    ``set_budget`` shrink can never interleave mid-gather — the
    device-bytes invariant and the row counters stay coherent. Gather
    results are plain host arrays, immutable once returned, so a block
    evicted right after a gather never corrupts the batch that read
    it."""

    def __init__(self, *, budget_bytes: int | None = 64 << 20,
                 block_vertices: int = 64, hot_fraction: float = 0.5,
                 lock=None):
        self.lock = lock if lock is not None else threading.RLock()
        self.budget_bytes = _check_budget(budget_bytes)
        self.block_vertices = int(block_vertices)
        if self.block_vertices <= 0:
            raise ValueError("block_vertices must be positive")
        self.hot_fraction = float(hot_fraction)
        if not 0.0 <= self.hot_fraction <= 1.0:
            raise ValueError(
                f"hot_fraction must be in [0, 1]: {hot_fraction}")
        self._graphs: dict[str, _GraphFeatures] = {}
        # pin log in admission order (newest last): budget shrinks unpin
        # LIFO, so the hottest earliest-admitted blocks survive longest
        self._pin_log: list[tuple[str, int, int]] = []
        self._hot_bytes = 0
        self._cold = cache._LruStore("features-cold", self.lock,
                                     budget_bytes=None)
        self._set_cold_budget()

    # ---------------- registration ----------------

    def register(self, graph: Graph, feats, *, graph_fp: str | None = None,
                 block_vertices: int | None = None) -> FeatureHandle:
        """Register ``graph``'s vertex features; returns the handle the
        engine/trainer/service consume.

        The features are split into ``block_vertices``-row host chunks
        (the column store) and the blocks are ranked by total in-degree;
        the hottest blocks are pinned on device immediately, in rank
        order, until ``hot_fraction`` of the byte budget is spent.
        Re-registering identical content is a no-op returning an equal
        handle; changed content (or block shape) drops the old device
        blocks and replaces the column store."""
        feats = np.ascontiguousarray(np.asarray(feats, np.float32))
        if feats.ndim != 2 or feats.shape[0] != graph.num_vertices:
            raise ValueError(
                f"feats must be (V={graph.num_vertices}, F); "
                f"got {feats.shape}")
        fp = graph_fp if graph_fp is not None \
            else cache.graph_fingerprint(graph)
        bv = int(block_vertices) if block_vertices else self.block_vertices
        feat_fp = hashlib.sha1(feats.tobytes()).hexdigest()
        with self.lock:
            g = self._graphs.get(fp)
            if (g is not None and g.feat_fp == feat_fp
                    and g.block_vertices == bv):
                return self._handle(g)  # identical content: keep the warm tiers
            if g is not None:
                self._release_device_locked(fp)
            V, F = feats.shape
            blocks = [feats[lo:lo + bv] for lo in range(0, V, bv)]
            mass = np.add.reduceat(
                graph.in_degrees().astype(np.int64), np.arange(0, V, bv))
            rank = np.argsort(-mass, kind="stable").astype(np.int64)
            rank_of = np.empty_like(rank)
            rank_of[rank] = np.arange(rank.size)
            g = _GraphFeatures(fp, feat_fp, V, F, bv, blocks, rank, rank_of)
            self._graphs[fp] = g
            self._pin_hot(g)
            return self._handle(g)

    def _handle(self, g: _GraphFeatures) -> FeatureHandle:
        return FeatureHandle(self, g.graph_fp, g.num_vertices, g.feat_dim,
                             g.block_vertices)

    def handle_for(self, graph_fp: str) -> FeatureHandle | None:
        """The handle for an already-registered graph, else None."""
        with self.lock:
            g = self._graphs.get(graph_fp)
            return self._handle(g) if g is not None else None

    def _hot_cap(self) -> int | None:
        if self.budget_bytes is None:
            return None  # unbounded: pin everything
        return int(self.budget_bytes * self.hot_fraction)

    def _pin_hot(self, g: _GraphFeatures) -> None:
        """Degree-ordered admission: pin blocks hottest-first while the
        hot tier's share of the budget holds them."""
        cap = self._hot_cap()
        for blk in g.rank:
            blk = int(blk)
            nb = g.blocks[blk].nbytes
            if cap is not None and self._hot_bytes + nb > cap:
                break  # rank order: everything colder is at most as hot
            g.pinned[blk] = jnp.asarray(g.blocks[blk])
            self._pin_log.append((g.graph_fp, blk, nb))
            self._hot_bytes += nb
        self._set_cold_budget()
        # new pins squeeze the cold tier: evict immediately so the
        # device-bytes invariant holds across registrations too
        self._cold._shrink()
        if (self._cold.budget_bytes is not None
                and self._cold.total_bytes > self._cold.budget_bytes):
            self._cold.drop(lambda k: True)

    # ---------------- budget ----------------

    def _set_cold_budget(self) -> None:
        self._cold.budget_bytes = (
            None if self.budget_bytes is None
            else max(self.budget_bytes - self._hot_bytes, 0))

    def set_budget(self, budget_bytes: int | None) -> None:
        """Reconfigure the device byte budget (None = unbounded) and
        shrink immediately: pins are released newest-first until the hot
        tier fits, then the cold LRU evicts down to the remainder. The
        invariant ``device_bytes <= budget`` holds on return."""
        with self.lock:
            budget_bytes = _check_budget(budget_bytes)
            self.budget_bytes = budget_bytes
            if budget_bytes is not None:
                while self._pin_log and self._hot_bytes > budget_bytes:
                    fp, blk, nb = self._pin_log.pop()
                    g = self._graphs.get(fp)
                    if g is not None:
                        g.pinned.pop(blk, None)
                    self._hot_bytes -= nb
            self._set_cold_budget()
            self._cold._shrink()
            # _shrink keeps >=1 entry even over budget (right for plans,
            # wrong here): a stranded block must go for the invariant
            if (self._cold.budget_bytes is not None
                    and self._cold.total_bytes > self._cold.budget_bytes):
                self._cold.drop(lambda k: True)

    @property
    def device_bytes(self) -> int:
        """Total device-resident feature bytes (hot pins + cold LRU) —
        never exceeds ``budget_bytes``."""
        with self.lock:
            return self._hot_bytes + self._cold.total_bytes

    # ---------------- the gather path ----------------

    def gather(self, graph_fp: str, nodes) -> np.ndarray:
        """Assemble rows for ``nodes`` (global vertex ids): pinned and
        cold-resident blocks serve as hits; absent blocks gather from
        the host column store (admitting the block to the cold tier
        when it fits the remaining budget)."""
        nodes = np.asarray(nodes, np.int64)
        tr = obs.trace
        sp = (tr.span("feature_gather", rows=int(nodes.size))
              if tr.enabled else obs.NULL_SPAN)
        with sp, self.lock:
            g = self._graphs.get(graph_fp)
            if g is None:
                raise KeyError(f"graph {graph_fp!r} is not registered")
            if nodes.size == 0:
                return np.empty((0, g.feat_dim), np.float32)
            if nodes.min() < 0 or nodes.max() >= g.num_vertices:
                raise ValueError(
                    f"node ids out of range [0, {g.num_vertices})")
            hr0, mr0 = g.hit_rows, g.miss_rows
            gb0, db0 = g.gathered_bytes, g.dense_bytes
            out = np.empty((nodes.size, g.feat_dim), np.float32)
            blk_of = nodes // g.block_vertices
            for blk in np.unique(blk_of):
                blk = int(blk)
                sel = blk_of == blk
                local = nodes[sel] - blk * g.block_vertices
                rows = int(sel.sum())
                g.dense_bytes += rows * g.rowbytes
                dev = self._resident_block(g, blk)
                if dev is not None:
                    g.hits += 1
                    g.hit_rows += rows
                    out[sel] = np.asarray(dev)[local]
                    continue
                g.misses += 1
                g.miss_rows += rows
                host = g.blocks[blk]
                out[sel] = host[local]
                self._admit_cold(g, blk, host, touched_rows=rows)
            # deltas read under the lock (per-graph fields are shared)
            dhr, dmr = g.hit_rows - hr0, g.miss_rows - mr0
            dgb, ddb = g.gathered_bytes - gb0, g.dense_bytes - db0
            sp.set(hit_rows=dhr, miss_rows=dmr)
        _HIT_ROWS.add(dhr)
        _MISS_ROWS.add(dmr)
        _GATHERED_BYTES.add(dgb)
        _DENSE_BYTES.add(ddb)
        return out

    def gather_all(self, graph_fp: str) -> np.ndarray:
        """The full ``(V, F)`` table (counts every block access) — the
        full-graph inference/eval path. Sampled training never calls
        this; ``stats()['full_gathers']`` pins that."""
        with self.lock:
            g = self._graphs.get(graph_fp)
            if g is None:
                raise KeyError(f"graph {graph_fp!r} is not registered")
            g.full_gathers += 1
            _FULL_GATHERS.add(1)
            return self.gather(graph_fp, np.arange(g.num_vertices))

    def _resident_block(self, g: _GraphFeatures, blk: int):
        dev = g.pinned.get(blk)
        if dev is not None:
            return dev
        key = (g.graph_fp, blk)
        if self._cold.peek(key):
            # present: this get can only hit (lock held, no eviction race)
            return self._cold.get(key, lambda: None)
        return None

    def _admit_cold(self, g: _GraphFeatures, blk: int, host: np.ndarray,
                    *, touched_rows: int) -> None:
        """Miss path: admit the block to the cold LRU when it can fit
        (reading the whole block from host), else serve the touched
        rows straight from host. ``gathered_bytes`` counts exactly what
        the host tier was asked for."""
        nb = host.nbytes
        cb = self._cold.budget_bytes
        if cb is None or nb <= cb:
            self._cold.get((g.graph_fp, blk), lambda: jnp.asarray(host),
                           nbytes=lambda _: nb)
            g.gathered_bytes += nb
        else:
            g.gathered_bytes += touched_rows * g.rowbytes

    # ---------------- release / clearing ----------------

    def _release_device_locked(self, graph_fp: str) -> int:
        g = self._graphs.get(graph_fp)
        dropped = 0
        if g is not None and g.pinned:
            for blk in list(g.pinned):
                g.pinned.pop(blk)
                dropped += 1
            kept = []
            for fp, blk, nb in self._pin_log:
                if fp == graph_fp:
                    self._hot_bytes -= nb
                else:
                    kept.append((fp, blk, nb))
            self._pin_log = kept
        dropped += self._cold.drop(lambda k: k[0] == graph_fp)
        self._set_cold_budget()
        return dropped

    def release_device(self, graph_fp: str) -> int:
        """Drop the graph's device-resident blocks (pins + cold entries)
        but KEEP its host column store — the plan-eviction cascade: an
        evicted graph stops holding device bytes, yet its features stay
        gatherable and re-warm through the cold tier on next touch.
        Returns the number of blocks dropped."""
        with self.lock:
            return self._release_device_locked(graph_fp)

    def drop(self, graph_fp: str) -> None:
        """Forget a registration entirely (device blocks AND the host
        column store); outstanding handles go stale."""
        with self.lock:
            self._release_device_locked(graph_fp)
            self._graphs.pop(graph_fp, None)

    def clear(self) -> None:
        """Drop every registration, device block and counter — the
        store's slice of ``repro.gcn.cache.clear_all``."""
        with self.lock:
            self._graphs.clear()
            self._pin_log.clear()
            self._hot_bytes = 0
            self._cold.clear()
            self._set_cold_budget()

    # ---------------- telemetry ----------------

    def graph_stats(self, graph_fp: str) -> dict:
        """Row-honest counters for ONE graph (zeros when unregistered):
        what ``GCNEngine.stats`` folds in as the measured feature-byte
        reduction."""
        with self.lock:
            g = self._graphs.get(graph_fp)
            if g is None:
                return {"registered": False, "blocks": 0, "pinned": 0,
                        "hits": 0, "misses": 0, "hit_rows": 0,
                        "miss_rows": 0, "gathered_bytes": 0,
                        "dense_bytes": 0, "hit_rate": 0.0,
                        "full_gathers": 0, "pinned_ranks": []}
            rows = g.hit_rows + g.miss_rows
            return {
                "registered": True,
                "blocks": g.num_blocks,
                "pinned": len(g.pinned),
                "hits": g.hits, "misses": g.misses,
                "hit_rows": g.hit_rows, "miss_rows": g.miss_rows,
                "gathered_bytes": g.gathered_bytes,
                "dense_bytes": g.dense_bytes,
                "hit_rate": obs.ratio(g.hit_rows, rows),
                "full_gathers": g.full_gathers,
                # admission-rank telemetry: the ranks of the pinned
                # blocks (degree-ordered admission => a prefix 0..k-1)
                "pinned_ranks": sorted(
                    int(g.rank_of[b]) for b in g.pinned),
            }

    def layer_stats(self) -> dict:
        """The ``features`` layer of ``cache_stats()``: the common
        per-layer schema (entries/bytes/budget/hits/misses/evictions)
        plus the store's row/byte telemetry and per-graph admission
        ranks."""
        with self.lock:
            gs = list(self._graphs.values())
            hit_rows = sum(g.hit_rows for g in gs)
            miss_rows = sum(g.miss_rows for g in gs)
            pinned_entries = sum(len(g.pinned) for g in gs)
            return {
                "entries": pinned_entries + len(self._cold._d),
                "bytes": self._hot_bytes + self._cold.total_bytes,
                "budget_bytes": self.budget_bytes,
                "hits": sum(g.hits for g in gs),
                "misses": sum(g.misses for g in gs),
                "evictions": self._cold.evictions,
                "graphs": len(gs),
                "pinned_entries": pinned_entries,
                "pinned_bytes": self._hot_bytes,
                "hit_rows": hit_rows,
                "miss_rows": miss_rows,
                "hit_rate": obs.ratio(hit_rows, hit_rows + miss_rows),
                "gathered_bytes": sum(g.gathered_bytes for g in gs),
                "dense_bytes": sum(g.dense_bytes for g in gs),
                "admission": {g.graph_fp[:12]: {
                    "blocks": g.num_blocks,
                    "pinned_ranks": sorted(
                        int(g.rank_of[b]) for b in g.pinned),
                } for g in gs},
            }


# ---------------------------------------------------------------------------
# The process-wide instance (what repro.gcn.cache budgets/clears/reports)
# ---------------------------------------------------------------------------

# shares the cache module's lock so budget changes, plan-eviction
# cascades and stats snapshots stay mutually coherent with the other
# five layers
_DEFAULT = FeatureStore(lock=cache._LOCK)


def default_store() -> FeatureStore:
    """The process-wide store: ``set_cache_budget(feature_bytes=...)``
    budgets it, ``cache_stats()['features']`` reports it,
    ``clear_plan_cache()`` clears it, and plan eviction releases its
    device blocks per graph."""
    return _DEFAULT
