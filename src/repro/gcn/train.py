"""Distributed full-batch GCN training through the multicast exchange.

The inference stack (plan -> relay replay -> aggregation kernel) is
reused UNCHANGED for training: the exchange executor is linear per
feature column, so its VJP is itself a reversed relay replay (every
``ppermute`` transposes to the inverse ring permutation, every masked
deposit to a gather, and the pallas ELL kernel carries an explicit
transpose kernel — see ``repro.core.message_passing`` and
``repro.kernels.spmm.ops``). ``jax.grad`` therefore composes straight
through ``engine.exchange_fn`` for both aggregation backends, and the
backward pass inherits the paper's bandwidth-bound, latency-tolerant
communication profile — the same observation MG-GCN (multi-GPU
full-batch training) and Demirci et al. (distributed-memory GCN
training) make for GPU/CPU clusters.

Layering (mirrors the serving split):

  * :func:`masked_cross_entropy` / :func:`forward_layers` — the loss and
    the uncompiled whole-network forward over sharded tensors;
  * ``GCNEngine.loss_and_grad`` (session layer, defined here as
    :func:`loss_and_grad`) — one jitted ``value_and_grad`` through the
    exchange, cached in the shared compiled-step store;
  * :class:`GCNTrainer` — owns labels/mask (sharded lazily: the
    sampled path must never build the full-batch plan), the AdamW
    state (``repro.train.optimizer``, reused from the LM substrate),
    and the epoch loop; ``fit`` returns a :class:`FitReport` with
    per-epoch wall times and the MEASURED exchange bytes per step
    (forward + backward ppermute payload, counted from the traced
    jaxpr);
  * ``GCNTrainer.fit_sampled`` — neighbor-sampled mini-batch training
    (``repro.core.sampling``): per seed set, a bounded-fanout sampled
    subgraph gets its OWN relay plan (``build_plan`` on the induced
    subgraph, capacities power-of-two padded via ``pad_plan_pow2`` so
    same-bucket batches share one jitted step), cached by subgraph
    fingerprint in the byte-bounded ``batch`` layer of
    ``repro.gcn.cache`` — the step that trains graphs whose full-batch
    plan would not fit the budget (cf. MG-GCN / Demirci et al., whose
    scale hinges on exactly this bounded per-batch working set);
  * ``GCNService.adopt`` — the train->serve handoff: the trainer's
    session object is admitted as-is, so the plan, ELL layouts, device
    arrays and compiled steps it already holds serve without
    replanning or re-uploading.

Gradient reductions need no hand-written psum: parameters enter the
loss replicated while activations are sharded, so the partial-derivative
sum across the torus mesh axes is exactly the transpose of that
broadcast, inserted by jit/GSPMD when it partitions the
``value_and_grad`` computation.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gcn_models as gm
from repro.core import message_passing as mp
from repro.core import sampling
from repro.core.partition import make_partition
from repro.core.plan import build_plan, pad_plan_pow2
from repro.gcn import cache, history as historylib, obs
from repro.gcn.pipeline import SamplePipeline
from repro.train import optimizer as optlib

__all__ = ["BatchSession", "FitReport", "GCNTrainer", "SampledFitReport",
           "build_cv_loss_grad", "build_cv_train_step",
           "forward_layers_cv", "masked_cross_entropy",
           "reference_loss_and_grad"]


# ---------------------------------------------------------------------------
# Loss + whole-network forward (uncompiled builders; the engine jits them)
# ---------------------------------------------------------------------------


def masked_cross_entropy(logits, labels, mask):
    """Masked softmax cross-entropy, mean over the masked vertices.

    ``logits``: (..., Vp, C); ``labels``: (..., Vp) int32 (padding slots
    may carry any valid class id); ``mask``: (..., Vp) float (0 for SPMD
    padding and unlabeled vertices). The mean is over the GLOBAL masked
    count, so the distributed value matches the single-node reference
    up to fp32 summation order."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def forward_layers(engine, impl: str):
    """Uncompiled whole-network forward ``(pdev, params, x) -> logits``
    over pre-sharded ``(*dims, Vp, F)`` features — the same exchange +
    combine composition as ``engine.forward``, kept as one traceable
    callable so ``jax.value_and_grad`` differentiates the full network
    in a single jit (one compiled object per training workload instead
    of one per layer)."""
    exchange = engine.exchange_fn(impl)
    nd = len(engine.dims)
    combine = engine.model_spec.combine

    def fwd(pdev, params, x):
        for li, layer in enumerate(params):
            accs = exchange(pdev, x)  # (*dims, R, slots, F)
            agg = accs.reshape(accs.shape[:nd] + (-1, accs.shape[-1]))
            x = combine(layer, agg, x, last=li == len(params) - 1)
        return x

    return fwd


def build_loss_grad(engine, impl: str):
    """``(pdev, params, x, labels, mask) -> (loss, grads)`` — jitted
    ``value_and_grad`` of the masked cross-entropy through the
    exchange. Cached process-wide by the engine (shared step store)."""
    fwd = forward_layers(engine, impl)

    def loss_fn(params, pdev, x, labels, mask):
        return masked_cross_entropy(fwd(pdev, params, x), labels, mask)

    vg = jax.value_and_grad(loss_fn)
    return jax.jit(lambda pdev, params, x, labels, mask:
                   vg(params, pdev, x, labels, mask))


def forward_layers_cv(engine, impl: str):
    """Control-variate whole-network forward ``(pdev, params, x, corrs)
    -> (logits, hiddens)``: each layer's aggregation is the sampled
    exchange PLUS a constant per-layer correction table ``corrs[l]``
    (``(*dims, Vp, F_l)``) — the historical aggregation over exactly
    the parent edges the sampled subgraph dropped (VR-GCN; the DGL
    ``gcn_cv_sc`` rule ``h = h*subg_norm + agg_history*norm``, with the
    norms already folded into the edge weights both terms carry).

    The exchange is linear, so the correction composes OUTSIDE it
    (:func:`repro.core.message_passing.scatter_rows_sharded`): the
    custom_vjp exchange story is untouched, ``jax.grad`` flows only
    through the sampled term on both agg backends, and when every
    correction row is exactly zero (full fanout drops no edges into
    any loss-relevant vertex) this forward is bit-identical to
    :func:`forward_layers`.

    ``hiddens`` are the freshly computed hidden activations
    ``(h_1 .. h_{L-1})`` — layer ``l``'s input — which the trainer
    writes back to the history store after the optimizer step."""
    exchange = engine.exchange_fn(impl)
    nd = len(engine.dims)
    combine = engine.model_spec.combine

    def fwd(pdev, params, x, corrs):
        hiddens = []
        for li, layer in enumerate(params):
            accs = exchange(pdev, x)  # (*dims, R, slots, F)
            agg = accs.reshape(accs.shape[:nd] + (-1, accs.shape[-1]))
            agg = agg + corrs[li]
            x = combine(layer, agg, x, last=li == len(params) - 1)
            if li < len(params) - 1:
                hiddens.append(x)
        return x, tuple(hiddens)

    return fwd


def build_cv_loss_grad(engine, impl: str):
    """``(pdev, params, x, corrs, labels, mask) -> (loss, grads)`` for
    the control-variate forward. ``corrs`` is differentiation-inert (a
    plain input, never a differentiated argument), so the traced
    backward carries exactly the plain step's ppermute payload."""
    fwd = forward_layers_cv(engine, impl)

    def loss_fn(params, pdev, x, corrs, labels, mask):
        logits, _ = fwd(pdev, params, x, corrs)
        return masked_cross_entropy(logits, labels, mask)

    vg = jax.value_and_grad(loss_fn)
    return jax.jit(lambda pdev, params, x, corrs, labels, mask:
                   vg(params, pdev, x, corrs, labels, mask))


def build_cv_train_step(engine, impl: str, opt_cfg: optlib.AdamWConfig):
    """One control-variate training step: CV loss + grads (through the
    sampled exchange only) + AdamW update, returning the hidden
    activations as a fourth output for history write-back:
    ``(pdev, params, opt_state, x, corrs, labels, mask) ->
    (params, opt_state, metrics, hiddens)``. The hiddens come from the
    same forward the gradient used (pre-update params — VR-GCN's h̄ is
    the last *computed* activation, not a recompute under new
    params)."""
    fwd = forward_layers_cv(engine, impl)

    def step(pdev, params, opt_state, x, corrs, labels, mask):
        def loss_fn(p):
            logits, hiddens = fwd(pdev, p, x, corrs)
            return masked_cross_entropy(logits, labels, mask), hiddens

        (loss, hiddens), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, metrics = optlib.apply_updates(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics}, hiddens

    return jax.jit(step, donate_argnums=_donation_argnums())


def _cv_layer_dims(params) -> list[int]:
    """Per-layer aggregation input widths — the history feature dims.
    Every registered model's layer dict carries ``w: (fan_in,
    fan_out)`` (GCN/GIN/SAGE all do); a model without one cannot size
    its correction tables, which is a hard error, not a guess."""
    try:
        return [int(layer["w"].shape[0]) for layer in params]
    except (KeyError, TypeError, AttributeError, IndexError) as e:
        raise ValueError(
            "variance_reduction needs per-layer input widths: every "
            "layer dict must carry 'w' of shape (fan_in, fan_out)") from e


def _donation_argnums() -> tuple[int, ...]:
    """Argnums of the train step's donated buffers: params and opt
    state, both replaced wholesale every step, so XLA may update them
    in place (halving peak params+moments residency — the open ROADMAP
    item from PR 4). Donation changes buffer aliasing only, never
    numerics (the bit-identical double-``fit`` test pins that), but it
    is only implemented on gpu/tpu — cpu ignores the flag with a
    warning per compile, so resolve per backend instead of spamming the
    CI logs."""
    return (1, 2) if jax.default_backend() in ("gpu", "tpu") else ()


def build_train_step(engine, impl: str, opt_cfg: optlib.AdamWConfig):
    """One training step: loss + grads through the exchange, then the
    AdamW update (``repro.train.optimizer``) — all inside one jit, so
    the optimizer math is fused with the backward pass. Params and opt
    state are DONATED on backends that support it (see
    :func:`_donation_argnums`): callers must treat the passed-in trees
    as consumed and keep only the returned ones (the ``fit`` /
    ``fit_sampled`` loops already do)."""
    fwd = forward_layers(engine, impl)

    def step(pdev, params, opt_state, x, labels, mask):
        def loss_fn(p):
            return masked_cross_entropy(fwd(pdev, p, x), labels, mask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, metrics = optlib.apply_updates(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics}

    return jax.jit(step, donate_argnums=_donation_argnums())


def _train_exchange_bytes(engine, params, impl: str, *,
                          cv: bool = False) -> int:
    """ppermute payload bytes of one training step on ``engine``'s plan
    (forward relay replays + their transposed backward replays),
    counted from the traced ``value_and_grad`` jaxpr with abstract
    inputs — works identically for full-batch sessions and sampled
    batch sessions. ``cv=True`` traces the control-variate step
    instead; its payload MUST equal the plain step's on the same
    session (the history term adds no exchange — pinned by test), so
    the bench's fanout-2-CV vs fanout-8-plain comparison isolates the
    fanout effect."""
    from repro.gcn import engine as _engine

    pdev = engine.plan_arrays(impl)
    Vp = engine.plan.part.vertices_per_node()
    F = engine._default_feat_dim(params)
    x_abs = jax.ShapeDtypeStruct(engine.dims + (Vp, F), jnp.float32)
    lb_abs = jax.ShapeDtypeStruct(engine.dims + (Vp,), jnp.int32)
    mk_abs = jax.ShapeDtypeStruct(engine.dims + (Vp,), jnp.float32)
    if cv:
        corrs_abs = tuple(
            jax.ShapeDtypeStruct(engine.dims + (Vp, d), jnp.float32)
            for d in _cv_layer_dims(params))
        fn = build_cv_loss_grad(engine, impl)
        jaxpr = jax.make_jaxpr(
            lambda pd, p, xx, cc, lb, mk: fn(pd, p, xx, cc, lb, mk))(
            pdev, params, x_abs, corrs_abs, lb_abs, mk_abs)
    else:
        fn = build_loss_grad(engine, impl)
        jaxpr = jax.make_jaxpr(
            lambda pd, p, xx, lb, mk: fn(pd, p, xx, lb, mk))(
            pdev, params, x_abs, lb_abs, mk_abs)
    return _engine._ppermute_payload_bytes(jaxpr.jaxpr, 1)


# ---------------------------------------------------------------------------
# Input sharding
# ---------------------------------------------------------------------------


def shard_training_inputs(engine, labels: np.ndarray,
                          mask: np.ndarray | None):
    """Host (V,) labels / optional mask -> device-layout ``(*dims, Vp)``
    trees on the engine's partition. The mask defaults to
    all-labeled; SPMD padding slots are always masked out (``fill=0``),
    and padded labels are written as class 0 so the gather in the loss
    stays in bounds."""
    V = engine.graph.num_vertices
    labels = np.asarray(labels)
    if labels.shape != (V,):
        raise ValueError(f"labels must be (V={V},); got {labels.shape}")
    if mask is None:
        mask = np.ones(V, np.float32)
    mask = np.asarray(mask, np.float32)
    if mask.shape != (V,):
        raise ValueError(f"mask must be (V={V},); got {mask.shape}")
    plan = engine.plan
    labels_sh = jnp.asarray(
        mp.shard_node_values(plan, labels.astype(np.int32)))
    mask_sh = jnp.asarray(mp.shard_node_values(plan, mask, fill=0))
    return labels_sh, mask_sh


# ---------------------------------------------------------------------------
# Trainer
# ---------------------------------------------------------------------------


@dataclass
class FitReport:
    """What one ``fit`` run did: per-epoch metrics, mean epoch wall
    time, and the measured exchange payload of one training step
    (forward + backward ppermute bytes from the traced jaxpr — the
    quantity the bench suite records into ``BENCH_gcn.json``)."""

    history: list = field(default_factory=list)
    epochs: int = 0
    epoch_s: float = 0.0  # mean epoch wall time (after warmup compile)
    compile_s: float = 0.0  # first-epoch wall (includes the jit compile)
    exchange_bytes_per_step: int = 0
    params: list | None = None

    @property
    def loss_first(self) -> float:
        return self.history[0]["loss"] if self.history else float("nan")

    @property
    def loss_last(self) -> float:
        return self.history[-1]["loss"] if self.history else float("nan")


@dataclass
class SampledFitReport(FitReport):
    """:class:`FitReport` plus the sampled-pipeline accounting the
    ``--suite train-sampled`` bench records: batch-plan cache traffic
    (recurring seed sets must HIT — a regression in subgraph
    fingerprinting shows up here), the power-of-two vertex buckets the
    batches landed in, and how many train-step compiles the whole run
    actually paid (bucketing exists to keep this near the bucket
    count, not the batch count)."""

    batch_size: int = 0
    fanouts: tuple = ()
    batches_per_epoch: int = 0
    batch_plan_hits: int = 0
    batch_plan_misses: int = 0
    vertex_buckets: list = field(default_factory=list)
    train_step_compiles: int = 0
    # feature-store telemetry for THIS run (per-graph counter deltas):
    # rows served device-resident vs gathered from the host column
    # store, and the dense-slice baseline the pre-store path would
    # have read (the bench asserts gathered < dense)
    feature_hit_rate: float = 0.0
    feature_bytes_gathered: int = 0
    feature_bytes_dense: int = 0
    # sampling-pipeline telemetry (``repro.gcn.pipeline``): zeros for
    # the serial path. ``batch_fingerprints`` is the consumed batch
    # order — the bit-identity tests compare it between serial and
    # pipelined runs
    pipeline_depth: int = 0
    pipeline_workers: int = 0
    pipeline_overlap_fraction: float = 0.0
    pipeline_overlap_s: float = 0.0
    pipeline_prepare_s: float = 0.0
    pipeline_wait_s: float = 0.0
    pipeline_queue_occupancy: float = 0.0
    batch_fingerprints: list = field(default_factory=list)
    # control-variate (historical-aggregation) telemetry: zeros/False
    # for plain sampled runs. The byte story the train-cv bench gates:
    # CV at fanout 2 must move strictly fewer exchange bytes per step
    # than plain sampling at fanout 8 at matched accuracy
    variance_reduction: bool = False
    history_bytes: int = 0  # store-resident history bytes after the fit
    history_write_rows: int = 0
    history_read_rows: int = 0
    history_fallback_rows: int = 0
    history_evictions: int = 0

    @property
    def batch_plan_hit_rate(self) -> float:
        calls = self.batch_plan_hits + self.batch_plan_misses
        return self.batch_plan_hits / calls if calls else 0.0


@dataclass
class BatchSession:
    """One cached sampled-batch execution context: the (sorted) global
    node set, the seed set its loss covers, and a
    :class:`~repro.gcn.engine.GCNEngine` session over the batch's
    padded relay plan (built once per subgraph fingerprint, held in the
    byte-bounded ``batch`` cache layer together with its device
    uploads and shared compiled steps)."""

    nodes: np.ndarray  # (S,) int64 sorted global ids; local i == nodes[i]
    seeds: np.ndarray  # (B,) int64 sorted global ids, subset of nodes
    engine: object  # GCNEngine.from_plan session (padded Vpad vertices)
    # lazily attached control-variate payload (_CVBatchData): the
    # batch's missing-edge arrays + exact layer-0 correction. Pure
    # content (a function of batch + parent CSR + feature content), so
    # concurrent builders may both compute it — last assignment wins,
    # values identical. Not counted in the cache entry's nbytes: the
    # payload is bounded by the batch's own edge count.
    cv: object = None

    @property
    def num_padded_vertices(self) -> int:
        return self.engine.graph.num_vertices


@dataclass
class _CVBatchData:
    """Step-independent control-variate inputs of one batch session:
    the parent edges the induced subgraph dropped (dst in the batch,
    src outside — :func:`repro.core.sampling.missing_in_edges`),
    grouped by unique source for history gathers, plus the layer-0
    correction, which is EXACT (layer-0 history is the input features
    themselves) and therefore safe to precompute on pipeline workers.
    Corrections for layers >= 1 read the mutable history store and are
    computed on the training thread per step."""

    feat_fp: str | None  # feature-content identity corr0 was built for
    dst_local: np.ndarray  # (M,) int64 into the batch's local ids
    src_glob: np.ndarray  # (M,) int64 parent ids outside the batch
    w: np.ndarray  # (M,) f32 prepared-graph edge weights
    usrc: np.ndarray  # unique src_glob (history gather set)
    inv: np.ndarray  # src_glob = usrc[inv]
    corr0: object  # (*dims, Vp, F0) sharded exact layer-0 correction


class GCNTrainer:
    """Full-batch node-classification trainer over one
    :class:`~repro.gcn.engine.GCNEngine` session.

    Typical use::

        eng = GCNEngine.build(cfg, graph, (4, 2))
        trainer = GCNTrainer(eng, labels, train_mask)
        report = trainer.fit(feats, epochs=50,
                             layer_dims=[F, 16, num_classes])
        svc.adopt("social", eng)        # serve the trained params

    ``labels`` is a global ``(V,)`` integer array; ``train_mask`` an
    optional ``(V,)`` 0/1 array selecting the labeled vertices (SPMD
    padding is always excluded). The optimizer is the LM substrate's
    AdamW (``repro.train.optimizer``); pass ``opt=`` to override the
    schedule. Two identical ``fit`` runs are bit-identical (the loop is
    one deterministic jitted step; see ``tests/test_gcn_train.py``).
    """

    def __init__(self, engine, labels, train_mask=None, *,
                 opt: optlib.AdamWConfig | None = None,
                 agg_impl: str | None = None):
        self.engine = engine
        self.impl = engine._impl(agg_impl)
        V = engine.graph.num_vertices
        self.labels = np.asarray(labels)
        if self.labels.shape != (V,):
            raise ValueError(
                f"labels must be (V={V},); got {self.labels.shape}")
        self.train_mask = (None if train_mask is None
                           else np.asarray(train_mask, np.float32))
        if self.train_mask is not None and self.train_mask.shape != (V,):
            raise ValueError(
                f"mask must be (V={V},); got {self.train_mask.shape}")
        # full-batch label/mask sharding is LAZY: it needs the parent
        # plan, and a purely sampled trainer must never build the
        # full-batch plan (that plan not fitting is the reason to
        # sample — see fit_sampled)
        self._labels_sh = None
        self._mask_sh = None
        # sampled-pipeline memos: one NeighborSampler per (fanouts,
        # seed) and the destination-CSR view of the PARENT prepared
        # graph (subgraph edge weights are induced from it, so degree
        # normalization uses parent degrees — full-fanout batches stay
        # exactly parity with full-batch training)
        self._samplers: dict[tuple, sampling.NeighborSampler] = {}
        self._prep_csr = None
        self._prep_csr_lock = threading.Lock()
        # full-batch GCN defaults: no warmup (one graph, not a stream),
        # no weight decay (2-layer nets underfit already), flat-ish lr
        self.opt = opt if opt is not None else optlib.AdamWConfig(
            lr=1e-2, weight_decay=0.0, warmup_steps=0,
            total_steps=10_000, grad_clip=1.0)
        self.opt_state: optlib.AdamState | None = None
        # exchange-byte measurement memo: the trace is a full re-trace
        # of the value_and_grad network, so pay it once per feat width
        self._exch_bytes: dict[tuple, int] = {}

    @property
    def labels_sh(self):
        """Device-layout ``(*dims, Vp)`` labels on the PARENT plan's
        partition (lazy — touching this builds the full-batch plan)."""
        if self._labels_sh is None:
            self._labels_sh, self._mask_sh = shard_training_inputs(
                self.engine, self.labels, self.train_mask)
        return self._labels_sh

    @property
    def mask_sh(self):
        _ = self.labels_sh
        return self._mask_sh

    # ---------------- the epoch loop ----------------

    def fit(self, feats, *, epochs: int = 30, params=None,
            layer_dims: Sequence[int] | None = None, seed: int = 0,
            log_every: int = 0, reset_opt: bool = False,
            eval_every: int = 0) -> FitReport:
        """Train for ``epochs`` full-batch steps; returns a
        :class:`FitReport` and stores the trained params on the engine
        (``engine.params``), ready for ``GCNService.adopt``.

        ``eval_every > 0`` runs the admission-aware :meth:`evaluate`
        every N epochs (and on the last), recording ``eval_loss`` /
        ``eval_accuracy`` in the history.

        ``feats`` is a global ``(V, F)`` host array, a pre-sharded
        ``(*dims, Vp, F)`` device array, or a
        :class:`~repro.gcn.featurestore.FeatureHandle` (rows served
        through the process-wide store). Params come from (in order)
        ``params=``, the engine's stored params, or a fresh
        ``engine.init_params(PRNGKey(seed), layer_dims)``. Optimizer
        state persists across ``fit`` calls (warm restarts) unless
        ``reset_opt=True``."""
        eng = self.engine
        if params is None and eng.params is None:
            if layer_dims is None:
                raise ValueError(
                    "no params: pass params=, call engine.init_params(), "
                    "or pass layer_dims=[feat_in, hidden..., classes]")
            eng.init_params(jax.random.PRNGKey(seed), list(layer_dims))
        params = eng._resolve_params(params)
        x, _ = eng._shard_input(feats)
        step = eng._compiled_train_step(self.opt, self.impl)
        pdev = eng.plan_arrays(self.impl)
        if self.opt_state is None or reset_opt:
            self.opt_state = optlib.init(params)
        history, epoch_walls = [], []
        compile_s = 0.0
        for ep in range(epochs):
            t0 = time.perf_counter()
            params, self.opt_state, metrics = step(
                pdev, params, self.opt_state, x, self.labels_sh,
                self.mask_sh)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if ep == 0:
                compile_s = dt  # first epoch pays the jit compile
            else:
                epoch_walls.append(dt)
            rec = {"epoch": ep, "epoch_s": dt,
                   **{k: float(v) for k, v in metrics.items()}}
            if eval_every and (ep % eval_every == 0 or ep == epochs - 1):
                rec.update({f"eval_{k}": v for k, v
                            in self.evaluate(feats, params).items()})
            history.append(rec)
            if log_every and (ep % log_every == 0 or ep == epochs - 1):
                print(f"[gcn-train] epoch={ep} loss={rec['loss']:.4f} "
                      f"gnorm={rec['grad_norm']:.3f} ({dt * 1e3:.1f}ms)")
        eng.params = params
        return FitReport(
            history=history, epochs=epochs,
            epoch_s=float(np.mean(epoch_walls)) if epoch_walls else compile_s,
            compile_s=compile_s,
            exchange_bytes_per_step=self.measured_exchange_bytes(params),
            params=params)

    # ---------------- neighbor-sampled mini-batch training ----------------

    def _sampler(self, fanouts, seed: int) -> sampling.NeighborSampler:
        key = (tuple(fanouts), int(seed))
        if key not in self._samplers:
            self._samplers[key] = sampling.NeighborSampler(
                self.engine.graph, fanouts, seed=seed)
        return self._samplers[key]

    def _prepared_csr(self):
        """Destination-CSR of the parent PREPARED graph (self loops +
        model edge weights), built once per trainer: batch subgraphs
        are induced from it, so every induced edge carries the weight
        the parent normalization gave it. Lock-guarded: pipelined fits
        call this from builder threads (``repro.gcn.pipeline``)."""
        with self._prep_csr_lock:
            if self._prep_csr is None:
                g2, w = self.engine.prepared_graph()
                self._prep_csr = sampling.csr_in_with_values(g2, w)
            return self._prep_csr

    def _sampled_batch(self, sampler: sampling.NeighborSampler,
                       seeds) -> sampling.SampledBatch:
        """Memoized ``sampler.sample`` for the training loop: the
        sample is per-seed-set deterministic, so with fixed seed sets
        (the default) every epoch would otherwise redo the whole
        host-side neighbor expansion just to recompute an identical
        cache key. The memo lives on the sampler and is thread-safe
        (``NeighborSampler.sample_memoized``) — pipelined fits hit it
        from builder threads."""
        return sampler.sample_memoized(seeds, induce_subgraph=False)

    def _batch_session(self, batch: sampling.SampledBatch) -> BatchSession:
        """The cached per-batch execution context: subgraph fingerprint
        -> (padded plan + sub-session) through the byte-bounded
        ``batch`` cache layer. A recurring seed set re-samples (cheap,
        deterministic) but never re-plans, re-uploads or recompiles."""
        from repro.gcn.engine import GCNEngine

        eng = self.engine
        # the key folds in the PARENT's graph fingerprint: the batch
        # fingerprint hashes (V, nodes, seeds) but not the parent's
        # edges, and the batch store is process-wide — without the
        # parent fp, two trainers on different graphs with coinciding
        # node sets would share (wrong) plans
        key = dataclasses.replace(
            eng.plan_key.plan_identity(),
            graph_fp=f"batch:{eng.graph_fp}:{batch.fingerprint()}")

        def build():
            indptr, src, w = self._prepared_csr()
            S = batch.num_nodes
            vpad = 1 if S <= 1 else 1 << (S - 1).bit_length()
            with obs.trace.span("plan_build", scope="batch", nodes=S,
                                vpad=vpad):
                sub_g2, sub_w = sampling.induce_in_edges(
                    indptr, src, w, batch.nodes, num_vertices=vpad,
                    name=f"{eng.graph.name}#batch")
                part = make_partition(eng.cfg, eng.torus.num_nodes,
                                      num_vertices=vpad)
                plan = build_plan(
                    eng.cfg, sub_g2, eng.torus, part, edge_weights=sub_w,
                    bidir=eng.bidir)
            with obs.trace.span("pad_plan", vpad=vpad):
                plan = pad_plan_pow2(plan)
            sub = GCNEngine.from_plan(
                eng.cfg, plan, eng.dims, graph_fp=key.graph_fp,
                axis_names=eng.axis_names, name=sub_g2.name)
            return BatchSession(nodes=batch.nodes, seeds=batch.seeds,
                                engine=sub)

        def nbytes(bs):
            return (cache._plan_nbytes(bs.engine.plan)
                    + bs.nodes.nbytes + bs.seeds.nbytes)

        return cache.get_batch(key, build, nbytes=nbytes)

    def _feature_handle(self, feats):
        """Resolve the sampled path's feature source to a store handle:
        a :class:`~repro.gcn.featurestore.FeatureHandle` passes through
        (validated against this trainer's graph); a dense ``(V, F)``
        host array is registered with the process-wide store once
        (content-hashed — re-fitting the same features re-uses the warm
        tiers). Either way the training loop gathers per-batch rows
        through the store and never fancy-indexes a full-``V`` array."""
        from repro.gcn import featurestore

        eng = self.engine
        V = eng.graph.num_vertices
        if isinstance(feats, featurestore.FeatureHandle):
            if feats.graph_fp != eng.graph_fp:
                raise ValueError(
                    "feature handle belongs to a different graph "
                    f"({feats.graph_fp[:12]} != {eng.graph_fp[:12]})")
            return feats
        feats = np.asarray(feats, np.float32)
        if feats.ndim != 2 or feats.shape[0] != V:
            raise ValueError(
                f"fit_sampled needs global (V={V}, F) host features or "
                f"a FeatureHandle; got {feats.shape}")
        return featurestore.default_store().register(
            eng.graph, feats, graph_fp=eng.graph_fp)

    def _batch_inputs(self, bs: BatchSession, handle):
        """Parent-global features/labels/mask -> the batch session's
        sharded device layout. Features come through the store's gather
        (device-resident hot blocks hit; absent rows come off the host
        column store) — the sampled path touches only the batch's
        seed-closure rows, never a full-``V`` feature array. The loss
        mask covers the SEED vertices only (carrying the parent mask's
        weights); padding vertices and non-seed neighbors contribute
        activations, never loss terms."""
        sub = bs.engine
        vpad = sub.graph.num_vertices
        S = bs.nodes.size
        xb = np.zeros((vpad, handle.feat_dim), np.float32)
        xb[:S] = handle.gather(bs.nodes)
        lb = np.zeros(vpad, np.int32)
        lb[:S] = self.labels[bs.nodes]
        mk = np.zeros(vpad, np.float32)
        seed_local = np.searchsorted(bs.nodes, bs.seeds)
        mk[seed_local] = (1.0 if self.train_mask is None
                          else self.train_mask[bs.seeds])
        with obs.trace.span("upload", what="batch_inputs", rows=S,
                            vpad=vpad):
            x, _ = sub._shard_input(xb)
            lb_sh, mk_sh = shard_training_inputs(sub, lb, mk)
        return x, lb_sh, mk_sh

    # ---------------- control-variate (historical aggregation) ----------------

    @staticmethod
    def _feat_fp(handle) -> str | None:
        """Content identity of the handle's registered features (the
        CV payload caches per feature content: a re-fit with different
        features on the same graph must rebuild corr0)."""
        store = handle.store
        with store.lock:
            g = store._graphs.get(handle.graph_fp)
            return None if g is None else g.feat_fp

    def _cv_batch_data(self, bs: BatchSession, handle) -> _CVBatchData:
        """The batch's step-independent CV inputs (lazily attached to
        the cached session): missing-edge arrays from the prepared
        parent CSR and the EXACT layer-0 correction (layer-0 history is
        the input features, which are constant — so this whole build is
        pure in (batch, parent graph, feature content) and safe on
        pipeline workers)."""
        ffp = self._feat_fp(handle)
        cv = bs.cv
        if cv is not None and cv.feat_fp == ffp:
            return cv
        indptr, src, w = self._prepared_csr()
        dst_local, src_glob, mw = sampling.missing_in_edges(
            indptr, src, w, bs.nodes)
        mw = np.asarray(mw, np.float32)
        usrc, inv = np.unique(src_glob, return_inverse=True)
        S = bs.nodes.size
        corr_rows = np.zeros((S, handle.feat_dim), np.float32)
        if usrc.size:
            feats = handle.gather(usrc)
            np.add.at(corr_rows, dst_local, mw[:, None] * feats[inv])
        with obs.trace.span("upload", what="cv_corr0", rows=S,
                            missing_edges=int(dst_local.size)):
            corr0 = jnp.asarray(
                mp.scatter_rows_sharded(bs.engine.plan, corr_rows))
        cv = _CVBatchData(feat_fp=ffp, dst_local=dst_local,
                          src_glob=src_glob, w=mw, usrc=usrc, inv=inv,
                          corr0=corr0)
        bs.cv = cv  # benign race: concurrent builds are identical
        return cv

    def _cv_corrections(self, bs: BatchSession, cv_dims, hist) -> tuple:
        """Per-layer correction tables for one step. Layer 0 is the
        precomputed exact term; layers >= 1 aggregate the CURRENT
        history rows over the missing edges — read on the training
        thread, in consumption order, which is what keeps the pipelined
        CV trajectory bit-identical to serial. An absent entry (never
        written, evicted, or width-mismatched) contributes zero: the
        estimate falls back to the plain sampled term, it never goes
        stale-wrong."""
        cv = bs.cv
        S = bs.nodes.size
        fp = self.engine.graph_fp
        with obs.trace.span("history_agg", rows=int(cv.usrc.size),
                            layers=len(cv_dims) - 1):
            corrs = [cv.corr0]
            for l in range(1, len(cv_dims)):
                Fl = cv_dims[l]
                rows = np.zeros((S, Fl), np.float32)
                if cv.usrc.size:
                    got = hist.read(fp, l, cv.usrc)
                    if got is not None and got[0].shape[1] == Fl:
                        np.add.at(rows, cv.dst_local,
                                  cv.w[:, None] * got[0][cv.inv])
                corrs.append(jnp.asarray(
                    mp.scatter_rows_sharded(bs.engine.plan, rows)))
        return tuple(corrs)

    def _cv_write_back(self, bs: BatchSession, hiddens, hist) -> int:
        """Post-step write-back: the step's freshly computed hidden
        activations for the batch's vertices become the history the
        NEXT steps' corrections read. Rows written are exactly
        ``bs.nodes`` per hidden layer (pinned by test)."""
        S = bs.nodes.size
        fp = self.engine.graph_fp
        written = 0
        with obs.trace.span("history_write", rows=S,
                            layers=len(hiddens)):
            for l, h in enumerate(hiddens, start=1):
                rows = bs.engine.unshard(np.asarray(h))[:S]
                written += hist.write(fp, l, bs.nodes, rows)
        return written

    def fit_sampled(self, feats, *, epochs: int = 10, batch_size: int = 64,
                    fanouts: Sequence[int] = (8, 8), params=None,
                    layer_dims: Sequence[int] | None = None, seed: int = 0,
                    reshuffle_each_epoch: bool = False, log_every: int = 0,
                    reset_opt: bool = False, agg_impl: str | None = None,
                    pipeline_depth: int = 0,
                    pipeline_workers: int = 2,
                    variance_reduction: bool = False,
                    eval_every: int = 0) -> SampledFitReport:
        """Neighbor-sampled mini-batch training: each step optimizes the
        masked CE over one seed set of ``batch_size`` labeled vertices,
        computed on that batch's sampled subgraph with its OWN (cached,
        padded) relay plan — the per-step working set is bounded by the
        sample, not by |V|, so graphs whose full-batch plan exceeds the
        plan budget still train (the full-batch plan is never built).

        ``fanouts`` bounds the in-neighbor expansion per layer
        (``-1`` = full; with full fanout and one batch covering every
        labeled vertex, loss/gradients match :meth:`fit` to fp32
        tolerance). Subgraph vertex counts are bucketed to powers of
        two and every plan capacity is power-of-two padded
        (``pad_plan_pow2``), so same-bucket batches reuse one jitted
        train step instead of recompiling per batch — the exact analog
        of ``forward_batched``'s request bucketing. By default the seed
        sets are fixed across epochs (``reshuffle_each_epoch=False``),
        which makes every epoch after the first a pure batch-plan cache
        hit; the report carries the hit/miss counts the bench asserts
        on. Determinism matches :meth:`fit`: same inputs, same seeds,
        bit-identical parameters.

        ``feats`` is a global ``(V, F)`` host array (registered with
        the process-wide feature store on entry) or a
        :class:`~repro.gcn.featurestore.FeatureHandle`; either way each
        batch's rows are gathered through the store's device-resident
        cache — the training loop never materializes a full-``V``
        feature array, and the report carries the measured
        ``feature_hit_rate`` / ``feature_bytes_gathered`` against the
        dense-slice baseline.

        ``pipeline_depth > 0`` overlaps the whole host-side per-batch
        chain (sample -> plan build + pow2 pad -> feature gather ->
        device upload) with device execution: ``pipeline_workers``
        builder threads prepare up to ``pipeline_depth`` batches ahead
        while the training thread consumes them strictly in batch order
        (``repro.gcn.pipeline.SamplePipeline``). Every prepared value
        is a pure function of its seed set, and the params/opt-state
        chain never leaves the training thread, so the pipelined
        trajectory is **bit-identical** to ``pipeline_depth=0`` —
        losses, params and batch order (pinned by
        ``tests/test_gcn_pipeline.py``). The report carries the overlap
        accounting (``pipeline_overlap_fraction`` et al.), also
        surfaced via ``engine.stats()``.

        ``variance_reduction=True`` turns on historical-aggregation
        (control-variate) sampling: each layer's aggregation becomes
        the sampled-edge sum over live activations PLUS the
        dropped-edge sum over stale per-layer historical activations
        h-bar (exact input features for layer 0; a byte-budgeted
        :class:`~repro.gcn.history.HistoryStore` for layers >= 1,
        refreshed after every optimizer step from that step's own
        forward). The history term is a constant w.r.t. the
        parameters, so gradients — and the cross-device exchange they
        ride on — flow only through the sampled term: the per-step
        exchange payload is identical to the plain path at the same
        fanout, which is what lets tiny fanouts (e.g. ``(2, 2)``)
        match large-fanout accuracy at a fraction of the bytes.
        Missing or evicted history rows contribute zero (graceful
        fallback toward plain sampling), and at full fanout the
        dropped-edge set is empty, so the trajectory is bit-identical
        to ``variance_reduction=False``. Budget via
        ``cache.set_cache_budget(history_bytes=...)``.

        ``eval_every > 0`` runs the admission-aware :meth:`evaluate`
        every N epochs (and on the last), recording ``eval_loss`` /
        ``eval_accuracy`` in the history. The eval path inherits the
        sampled path's scaling guarantee: on a graph whose full plan
        exceeds the plan budget, evaluation goes layer-major and the
        full-batch plan is STILL never built."""
        eng = self.engine
        if eng.bidir:
            raise ValueError(
                "fit_sampled supports unidirectional plans only")
        impl = eng._impl(agg_impl) if agg_impl is not None else self.impl
        V = eng.graph.num_vertices
        handle = self._feature_handle(feats)
        if params is None and eng.params is None:
            if layer_dims is None:
                raise ValueError(
                    "no params: pass params=, call engine.init_params(), "
                    "or pass layer_dims=[feat_in, hidden..., classes]")
            eng.init_params(jax.random.PRNGKey(seed), list(layer_dims))
        params = eng._resolve_params(params)
        train_nodes = (np.arange(V) if self.train_mask is None
                       else np.flatnonzero(self.train_mask > 0))
        if train_nodes.size == 0:
            raise ValueError("no labeled vertices to sample seeds from")
        sampler = self._sampler(fanouts, seed)
        hist = cv_dims = None
        if variance_reduction:
            # historical-aggregation control variate: per layer the
            # aggregation becomes (sampled edges over live activations)
            # + (dropped edges over stale history h-bar); the history
            # term is a constant w.r.t. params, so gradients flow only
            # through the sampled exchange
            cv_dims = _cv_layer_dims(params)
            hist = historylib.default_history()
            hist.ensure_height(eng.graph_fp, V)
        if self.opt_state is None or reset_opt:
            self.opt_state = optlib.init(params)
        c0 = cache.cache_stats()
        f0 = handle.stats()
        history, epoch_walls = [], []
        compile_s = 0.0
        buckets: set[int] = set()
        big_bs = None  # largest-bucket session: the byte-accounting rep
        fingerprints: list[str] = []

        # epoch seed sets are precomputed for the WHOLE run: they are a
        # pure function of (sampler seed, epoch), so serial and
        # pipelined runs see identical task lists — the first link in
        # the bit-identity chain
        epoch_seed_sets = [
            sampler.epoch_batches(train_nodes, batch_size,
                                  epoch=ep if reshuffle_each_epoch else 0)
            for ep in range(epochs)]
        tasks = [seeds for sets in epoch_seed_sets for seeds in sets]
        n_batches = len(epoch_seed_sets[0]) if epoch_seed_sets else 0

        def prepare(seeds):
            """The whole host-side per-batch chain — sample, plan build
            (+ pow2 pad), compiled-step lookup, feature gather, device
            upload. Pure in ``seeds`` (every cache is content-keyed and
            first-commit-wins), so it runs identically on the training
            thread (serial) or a builder thread (pipelined)."""
            with obs.trace.span("batch_prepare", seeds=int(seeds.size)):
                batch = self._sampled_batch(sampler, seeds)
                bs = self._batch_session(batch)
                if variance_reduction:
                    step = bs.engine._compiled_cv_train_step(self.opt, impl)
                    # the step-independent CV pieces (missing-edge
                    # structure + exact layer-0 correction from the
                    # feature store) are pure in the seed set, so
                    # builder threads pre-gather them here; the
                    # history rows for layers >= 1 are read on the
                    # training thread, in consumption order
                    self._cv_batch_data(bs, handle)
                else:
                    step = bs.engine._compiled_train_step(self.opt, impl)
                pdev = bs.engine.plan_arrays(impl)
                x, lb_sh, mk_sh = self._batch_inputs(bs, handle)
                return bs, batch.fingerprint(), step, pdev, x, lb_sh, mk_sh

        pipe = None
        if pipeline_depth > 0 and tasks:
            # pre-warm the one lazily-built shared input of prepare()
            # on the training thread, then let the builders loose
            self._prepared_csr()
            pipe = SamplePipeline(tasks, prepare, depth=pipeline_depth,
                                  workers=pipeline_workers)
        ti = 0
        try:
            for ep in range(epochs):
                t0 = time.perf_counter()
                seed_sets = epoch_seed_sets[ep]
                loss_sum = weight = 0.0
                for seeds in seed_sets:
                    if pipe is not None:
                        bs, fp, step, pdev, x, lb_sh, mk_sh = pipe.get(ti)
                    else:
                        bs, fp, step, pdev, x, lb_sh, mk_sh = prepare(
                            tasks[ti])
                    ti += 1
                    fingerprints.append(fp)
                    # the span covers the host-side sync on the loss
                    # too — that is when the device work is truly done
                    if variance_reduction:
                        corrs = self._cv_corrections(bs, cv_dims, hist)
                        with obs.trace.span("execute", what="train_step",
                                            epoch=ep, batch=ti - 1):
                            (params, self.opt_state, metrics,
                             hiddens) = step(pdev, params, self.opt_state,
                                             x, corrs, lb_sh, mk_sh)
                            loss = float(metrics["loss"])
                        # refresh h-bar AFTER the optimizer step with
                        # the activations the step itself computed
                        self._cv_write_back(bs, hiddens, hist)
                    else:
                        with obs.trace.span("execute", what="train_step",
                                            epoch=ep, batch=ti - 1):
                            params, self.opt_state, metrics = step(
                                pdev, params, self.opt_state, x, lb_sh,
                                mk_sh)
                            loss = float(metrics["loss"])
                    w = float(seeds.size)
                    loss_sum += loss * w
                    weight += w
                    buckets.add(bs.num_padded_vertices)
                    if (big_bs is None
                            or bs.num_padded_vertices
                            > big_bs.num_padded_vertices):
                        big_bs = bs
                dt = time.perf_counter() - t0
                if ep == 0:
                    compile_s = dt  # 1st epoch pays plan builds+compiles
                else:
                    epoch_walls.append(dt)
                rec = {"epoch": ep, "epoch_s": dt,
                       "batches": len(seed_sets),
                       "loss": loss_sum / max(weight, 1.0)}
                if eval_every and (ep % eval_every == 0
                                   or ep == epochs - 1):
                    with obs.trace.span("evaluate", epoch=ep):
                        rec.update({f"eval_{k}": v for k, v in
                                    self.evaluate(handle, params).items()})
                history.append(rec)
                if log_every and (ep % log_every == 0 or ep == epochs - 1):
                    print(f"[gcn-train-sampled] epoch={ep} "
                          f"loss={rec['loss']:.4f} ({len(seed_sets)} "
                          f"batches, {dt * 1e3:.1f}ms)")
        finally:
            if pipe is not None:
                pipe.close()
        pstats = pipe.stats() if pipe is not None else None
        eng._pipeline_stats = {
            "pipeline_depth": pstats["depth"] if pstats else 0,
            "pipeline_overlap_fraction": (
                pstats["overlap_fraction"] if pstats else 0.0),
            "pipeline_queue_occupancy": (
                pstats["queue_occupancy_mean"] if pstats else 0.0),
        }
        eng.params = params
        c1 = cache.cache_stats()
        f1 = handle.stats()
        frows = ((f1["hit_rows"] - f0["hit_rows"])
                 + (f1["miss_rows"] - f0["miss_rows"]))
        # measured on the LARGEST bucket's session: the remainder batch
        # is systematically the runt, and the bench baseline should
        # reflect the dominant per-step payload
        xbytes = (_train_exchange_bytes(big_bs.engine, params, impl,
                                        cv=variance_reduction)
                  if big_bs is not None else 0)
        steps = len(fingerprints)
        obs.metrics.counter(
            "train.steps", unit="steps",
            help="sampled train steps executed").add(steps)
        obs.metrics.counter(
            "train.exchange_bytes", unit="bytes",
            help="link bytes moved by sampled train-step exchanges "
                 "(per-step payload x steps)").add(xbytes * steps)
        obs.metrics.gauge(
            "train.exchange_bytes_per_step", unit="bytes",
            help="per-step exchange payload of the last sampled fit"
        ).set(xbytes)
        return SampledFitReport(
            history=history, epochs=epochs,
            epoch_s=float(np.mean(epoch_walls)) if epoch_walls else compile_s,
            compile_s=compile_s,
            exchange_bytes_per_step=xbytes,
            params=params,
            batch_size=int(batch_size), fanouts=tuple(sampler.fanouts),
            batches_per_epoch=n_batches,
            batch_plan_hits=c1["batch"]["hits"] - c0["batch"]["hits"],
            batch_plan_misses=c1["batch"]["misses"] - c0["batch"]["misses"],
            vertex_buckets=sorted(buckets),
            train_step_compiles=c1["step"]["misses"] - c0["step"]["misses"],
            feature_hit_rate=obs.ratio(
                f1["hit_rows"] - f0["hit_rows"], frows),
            feature_bytes_gathered=(
                f1["gathered_bytes"] - f0["gathered_bytes"]),
            feature_bytes_dense=f1["dense_bytes"] - f0["dense_bytes"],
            pipeline_depth=pstats["depth"] if pstats else 0,
            pipeline_workers=pstats["workers"] if pstats else 0,
            pipeline_overlap_fraction=(
                pstats["overlap_fraction"] if pstats else 0.0),
            pipeline_overlap_s=pstats["overlap_s"] if pstats else 0.0,
            pipeline_prepare_s=pstats["prepare_s"] if pstats else 0.0,
            pipeline_wait_s=pstats["wait_s"] if pstats else 0.0,
            pipeline_queue_occupancy=(
                pstats["queue_occupancy_mean"] if pstats else 0.0),
            variance_reduction=variance_reduction,
            history_bytes=(c1["history"]["bytes"]
                           if variance_reduction else 0),
            history_write_rows=(c1["history"]["write_rows"]
                                - c0["history"]["write_rows"]),
            history_read_rows=(c1["history"]["read_rows"]
                               - c0["history"]["read_rows"]),
            history_fallback_rows=(c1["history"]["fallback_rows"]
                                   - c0["history"]["fallback_rows"]),
            history_evictions=(c1["history"]["evictions"]
                               - c0["history"]["evictions"]),
            batch_fingerprints=fingerprints)

    def sampled_loss_and_grad(self, feats, seeds, *,
                              fanouts: Sequence[int], seed: int = 0,
                              params=None, agg_impl: str | None = None,
                              variance_reduction: bool = False):
        """``(loss, grads)`` of ONE sampled batch — the masked CE over
        the seed vertices on the batch's padded subgraph plan. The
        parity anchor: with full fanout (``-1`` per layer, depth >= the
        network depth) and ``seeds`` = every labeled vertex, this
        matches :meth:`engine.loss_and_grad` on the full graph to fp32
        tolerance on either aggregation backend.

        ``variance_reduction=True`` adds the historical-aggregation
        correction per layer (see :meth:`fit_sampled`); at full fanout
        the dropped-edge set is empty and the result is bit-identical
        to the plain path — the CV parity anchor."""
        eng = self.engine
        impl = eng._impl(agg_impl) if agg_impl is not None else self.impl
        params = eng._resolve_params(params)
        handle = self._feature_handle(feats)
        bs = self._batch_session(
            self._sampled_batch(self._sampler(fanouts, seed), seeds))
        x, lb_sh, mk_sh = self._batch_inputs(bs, handle)
        if variance_reduction:
            fn = bs.engine._compiled_cv_loss_grad(impl)
            self._cv_batch_data(bs, handle)
            hist = historylib.default_history()
            hist.ensure_height(eng.graph_fp, eng.graph.num_vertices)
            corrs = self._cv_corrections(bs, _cv_layer_dims(params), hist)
            return fn(bs.engine.plan_arrays(impl), params, x, corrs,
                      lb_sh, mk_sh)
        fn = bs.engine._compiled_loss_grad(impl)
        return fn(bs.engine.plan_arrays(impl), params, x, lb_sh, mk_sh)

    def evaluate(self, feats, params=None, *, mode: str = "auto",
                 chunk_size: int = 128) -> dict:
        """Loss + accuracy of the CURRENT params over the masked
        vertices. Admission-aware like :class:`~repro.gcn.service.
        GCNService`: ``mode="auto"`` (default) runs the full-graph
        forward only when the session's plan fits the plan budget (or
        is already built); otherwise eval routes through
        :func:`repro.gcn.inference.forward_layer_major` in
        ``chunk_size`` node-chunks, so train-time evaluation of an
        over-budget graph never builds the full-graph plan (the same
        guarantee PR 5 pinned for the training step). ``mode="full"``
        / ``"layer-major"`` force either path; outputs are
        bit-identical between them."""
        from repro.gcn import inference

        if mode not in ("auto", "full", "layer-major"):
            raise ValueError(f"mode must be auto|full|layer-major: {mode}")
        eng = self.engine
        if (mode == "layer-major"
                or (mode == "auto" and inference.plan_over_budget(eng))):
            logits = eng.forward_layer_major(feats, params,
                                             chunk_size=chunk_size)
        else:
            logits = eng.forward(feats, params)
        mask = (np.ones(eng.graph.num_vertices, np.float32)
                if self.train_mask is None else self.train_mask)
        loss = float(masked_cross_entropy(
            jnp.asarray(logits), jnp.asarray(self.labels.astype(np.int32)),
            jnp.asarray(mask)))
        pred = np.argmax(logits, axis=-1)
        sel = mask > 0
        acc = float(np.mean(pred[sel] == self.labels[sel]))
        return {"loss": loss, "accuracy": acc}

    # ---------------- accounting ----------------

    def measured_exchange_bytes(self, params=None) -> int:
        """ppermute payload bytes of ONE full-batch training step,
        measured from the traced ``value_and_grad`` jaxpr — counts the
        forward relay replays AND their transposed (backward) replays,
        per layer. The repo-level evidence that the backward pass is
        the same bandwidth-bound exchange the paper characterizes (the
        bench suite records this as ``exchange_bytes_per_step``; the
        sampled pipeline reports the same quantity for one batch plan).
        Memoized per (backend, feature width, param structure): the
        measurement is a fresh trace of the whole backward graph, so
        repeated ``fit`` calls on one trainer pay it once."""
        eng = self.engine
        params = eng._resolve_params(params)
        F = eng._default_feat_dim(params)
        key = (self.impl, F, jax.tree.structure(params))
        if key not in self._exch_bytes:
            self._exch_bytes[key] = _train_exchange_bytes(
                eng, params, self.impl)
        return self._exch_bytes[key]


# ---------------------------------------------------------------------------
# Single-node oracle
# ---------------------------------------------------------------------------


def reference_loss_and_grad(engine, feats, labels, mask=None, params=None):
    """Single-device dense-adjacency oracle for ``loss_and_grad``: the
    same prepared graph / combine / masked cross-entropy, aggregated by
    a plain COO segment-sum on one device
    (:func:`repro.core.gcn_models.reference_loop`) and differentiated
    with ``jax.value_and_grad`` — the parity target for the distributed
    gradients (fp32 tolerance; both aggregation backends)."""
    g2, w = engine.prepared_graph()
    params = engine._resolve_params(params)
    combine = engine.model_spec.combine
    V = engine.graph.num_vertices
    if mask is None:
        mask = np.ones(V, np.float32)
    lj = jnp.asarray(np.asarray(labels).astype(np.int32))
    mj = jnp.asarray(np.asarray(mask, np.float32))
    xj = jnp.asarray(feats)

    def loss_fn(p):
        logits = gm.reference_loop(g2, w, combine, p, xj)
        return masked_cross_entropy(logits, lj, mj)

    return jax.value_and_grad(loss_fn)(params)
