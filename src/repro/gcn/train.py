"""Distributed full-batch GCN training through the multicast exchange.

The inference stack (plan -> relay replay -> aggregation kernel) is
reused UNCHANGED for training: the exchange executor is linear per
feature column, so its VJP is itself a reversed relay replay (every
``ppermute`` transposes to the inverse ring permutation, every masked
deposit to a gather, and the pallas ELL kernel carries an explicit
transpose kernel — see ``repro.core.message_passing`` and
``repro.kernels.spmm.ops``). ``jax.grad`` therefore composes straight
through ``engine.exchange_fn`` for both aggregation backends, and the
backward pass inherits the paper's bandwidth-bound, latency-tolerant
communication profile — the same observation MG-GCN (multi-GPU
full-batch training) and Demirci et al. (distributed-memory GCN
training) make for GPU/CPU clusters.

Layering (mirrors the serving split):

  * :func:`masked_cross_entropy` / :func:`forward_layers` — the loss and
    the uncompiled whole-network forward over sharded tensors;
  * ``GCNEngine.loss_and_grad`` (session layer, defined here as
    :func:`loss_and_grad`) — one jitted ``value_and_grad`` through the
    exchange, cached in the shared compiled-step store;
  * :class:`GCNTrainer` — owns sharded labels/mask, the AdamW state
    (``repro.train.optimizer``, reused from the LM substrate), and the
    epoch loop; ``fit`` returns a :class:`FitReport` with per-epoch
    wall times and the MEASURED exchange bytes per step (forward +
    backward ppermute payload, counted from the traced jaxpr);
  * ``GCNService.adopt`` — the train->serve handoff: the trainer's
    session object is admitted as-is, so the plan, ELL layouts, device
    arrays and compiled steps it already holds serve without
    replanning or re-uploading.

Gradient reductions need no hand-written psum: parameters enter the
loss replicated while activations are sharded, so the partial-derivative
sum across the torus mesh axes is exactly the transpose of that
broadcast, inserted by jit/GSPMD when it partitions the
``value_and_grad`` computation.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gcn_models as gm
from repro.core import message_passing as mp
from repro.train import optimizer as optlib

__all__ = ["FitReport", "GCNTrainer", "masked_cross_entropy",
           "reference_loss_and_grad"]


# ---------------------------------------------------------------------------
# Loss + whole-network forward (uncompiled builders; the engine jits them)
# ---------------------------------------------------------------------------


def masked_cross_entropy(logits, labels, mask):
    """Masked softmax cross-entropy, mean over the masked vertices.

    ``logits``: (..., Vp, C); ``labels``: (..., Vp) int32 (padding slots
    may carry any valid class id); ``mask``: (..., Vp) float (0 for SPMD
    padding and unlabeled vertices). The mean is over the GLOBAL masked
    count, so the distributed value matches the single-node reference
    up to fp32 summation order."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def forward_layers(engine, impl: str):
    """Uncompiled whole-network forward ``(pdev, params, x) -> logits``
    over pre-sharded ``(*dims, Vp, F)`` features — the same exchange +
    combine composition as ``engine.forward``, kept as one traceable
    callable so ``jax.value_and_grad`` differentiates the full network
    in a single jit (one compiled object per training workload instead
    of one per layer)."""
    exchange = engine.exchange_fn(impl)
    nd = len(engine.dims)
    combine = engine.model_spec.combine

    def fwd(pdev, params, x):
        for li, layer in enumerate(params):
            accs = exchange(pdev, x)  # (*dims, R, slots, F)
            agg = accs.reshape(accs.shape[:nd] + (-1, accs.shape[-1]))
            x = combine(layer, agg, x, last=li == len(params) - 1)
        return x

    return fwd


def build_loss_grad(engine, impl: str):
    """``(pdev, params, x, labels, mask) -> (loss, grads)`` — jitted
    ``value_and_grad`` of the masked cross-entropy through the
    exchange. Cached process-wide by the engine (shared step store)."""
    fwd = forward_layers(engine, impl)

    def loss_fn(params, pdev, x, labels, mask):
        return masked_cross_entropy(fwd(pdev, params, x), labels, mask)

    vg = jax.value_and_grad(loss_fn)
    return jax.jit(lambda pdev, params, x, labels, mask:
                   vg(params, pdev, x, labels, mask))


def build_train_step(engine, impl: str, opt_cfg: optlib.AdamWConfig):
    """One full-batch training step: loss + grads through the exchange,
    then the AdamW update (``repro.train.optimizer``) — all inside one
    jit, so the optimizer math is fused with the backward pass."""
    fwd = forward_layers(engine, impl)

    def step(pdev, params, opt_state, x, labels, mask):
        def loss_fn(p):
            return masked_cross_entropy(fwd(pdev, p, x), labels, mask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, metrics = optlib.apply_updates(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics}

    return jax.jit(step)


# ---------------------------------------------------------------------------
# Input sharding
# ---------------------------------------------------------------------------


def shard_training_inputs(engine, labels: np.ndarray,
                          mask: np.ndarray | None):
    """Host (V,) labels / optional mask -> device-layout ``(*dims, Vp)``
    trees on the engine's partition. The mask defaults to
    all-labeled; SPMD padding slots are always masked out (``fill=0``),
    and padded labels are written as class 0 so the gather in the loss
    stays in bounds."""
    V = engine.graph.num_vertices
    labels = np.asarray(labels)
    if labels.shape != (V,):
        raise ValueError(f"labels must be (V={V},); got {labels.shape}")
    if mask is None:
        mask = np.ones(V, np.float32)
    mask = np.asarray(mask, np.float32)
    if mask.shape != (V,):
        raise ValueError(f"mask must be (V={V},); got {mask.shape}")
    plan = engine.plan
    labels_sh = jnp.asarray(
        mp.shard_node_values(plan, labels.astype(np.int32)))
    mask_sh = jnp.asarray(mp.shard_node_values(plan, mask, fill=0))
    return labels_sh, mask_sh


# ---------------------------------------------------------------------------
# Trainer
# ---------------------------------------------------------------------------


@dataclass
class FitReport:
    """What one ``fit`` run did: per-epoch metrics, mean epoch wall
    time, and the measured exchange payload of one training step
    (forward + backward ppermute bytes from the traced jaxpr — the
    quantity the bench suite records into ``BENCH_gcn.json``)."""

    history: list = field(default_factory=list)
    epochs: int = 0
    epoch_s: float = 0.0  # mean epoch wall time (after warmup compile)
    compile_s: float = 0.0  # first-epoch wall (includes the jit compile)
    exchange_bytes_per_step: int = 0
    params: list | None = None

    @property
    def loss_first(self) -> float:
        return self.history[0]["loss"] if self.history else float("nan")

    @property
    def loss_last(self) -> float:
        return self.history[-1]["loss"] if self.history else float("nan")


class GCNTrainer:
    """Full-batch node-classification trainer over one
    :class:`~repro.gcn.engine.GCNEngine` session.

    Typical use::

        eng = GCNEngine.build(cfg, graph, (4, 2))
        trainer = GCNTrainer(eng, labels, train_mask)
        report = trainer.fit(feats, epochs=50,
                             layer_dims=[F, 16, num_classes])
        svc.adopt("social", eng)        # serve the trained params

    ``labels`` is a global ``(V,)`` integer array; ``train_mask`` an
    optional ``(V,)`` 0/1 array selecting the labeled vertices (SPMD
    padding is always excluded). The optimizer is the LM substrate's
    AdamW (``repro.train.optimizer``); pass ``opt=`` to override the
    schedule. Two identical ``fit`` runs are bit-identical (the loop is
    one deterministic jitted step; see ``tests/test_gcn_train.py``).
    """

    def __init__(self, engine, labels, train_mask=None, *,
                 opt: optlib.AdamWConfig | None = None,
                 agg_impl: str | None = None):
        self.engine = engine
        self.impl = engine._impl(agg_impl)
        self.labels = np.asarray(labels)
        self.train_mask = (None if train_mask is None
                           else np.asarray(train_mask, np.float32))
        self.labels_sh, self.mask_sh = shard_training_inputs(
            engine, self.labels, self.train_mask)
        # full-batch GCN defaults: no warmup (one graph, not a stream),
        # no weight decay (2-layer nets underfit already), flat-ish lr
        self.opt = opt if opt is not None else optlib.AdamWConfig(
            lr=1e-2, weight_decay=0.0, warmup_steps=0,
            total_steps=10_000, grad_clip=1.0)
        self.opt_state: optlib.AdamState | None = None
        # exchange-byte measurement memo: the trace is a full re-trace
        # of the value_and_grad network, so pay it once per feat width
        self._exch_bytes: dict[tuple, int] = {}

    # ---------------- the epoch loop ----------------

    def fit(self, feats, *, epochs: int = 30, params=None,
            layer_dims: Sequence[int] | None = None, seed: int = 0,
            log_every: int = 0, reset_opt: bool = False) -> FitReport:
        """Train for ``epochs`` full-batch steps; returns a
        :class:`FitReport` and stores the trained params on the engine
        (``engine.params``), ready for ``GCNService.adopt``.

        ``feats`` is a global ``(V, F)`` host array or a pre-sharded
        ``(*dims, Vp, F)`` device array. Params come from (in order)
        ``params=``, the engine's stored params, or a fresh
        ``engine.init_params(PRNGKey(seed), layer_dims)``. Optimizer
        state persists across ``fit`` calls (warm restarts) unless
        ``reset_opt=True``."""
        eng = self.engine
        if params is None and eng.params is None:
            if layer_dims is None:
                raise ValueError(
                    "no params: pass params=, call engine.init_params(), "
                    "or pass layer_dims=[feat_in, hidden..., classes]")
            eng.init_params(jax.random.PRNGKey(seed), list(layer_dims))
        params = eng._resolve_params(params)
        x, _ = eng._shard_input(feats)
        step = eng._compiled_train_step(self.opt, self.impl)
        pdev = eng.plan_arrays(self.impl)
        if self.opt_state is None or reset_opt:
            self.opt_state = optlib.init(params)
        history, epoch_walls = [], []
        compile_s = 0.0
        for ep in range(epochs):
            t0 = time.perf_counter()
            params, self.opt_state, metrics = step(
                pdev, params, self.opt_state, x, self.labels_sh,
                self.mask_sh)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if ep == 0:
                compile_s = dt  # first epoch pays the jit compile
            else:
                epoch_walls.append(dt)
            rec = {"epoch": ep, "epoch_s": dt,
                   **{k: float(v) for k, v in metrics.items()}}
            history.append(rec)
            if log_every and (ep % log_every == 0 or ep == epochs - 1):
                print(f"[gcn-train] epoch={ep} loss={rec['loss']:.4f} "
                      f"gnorm={rec['grad_norm']:.3f} ({dt * 1e3:.1f}ms)")
        eng.params = params
        return FitReport(
            history=history, epochs=epochs,
            epoch_s=float(np.mean(epoch_walls)) if epoch_walls else compile_s,
            compile_s=compile_s,
            exchange_bytes_per_step=self.measured_exchange_bytes(params),
            params=params)

    def evaluate(self, feats, params=None) -> dict:
        """Loss + accuracy of the CURRENT params over the masked
        vertices (host-side, via ``engine.forward``)."""
        eng = self.engine
        logits = eng.forward(np.asarray(feats), params)
        mask = (np.ones(eng.graph.num_vertices, np.float32)
                if self.train_mask is None else self.train_mask)
        loss = float(masked_cross_entropy(
            jnp.asarray(logits), jnp.asarray(self.labels.astype(np.int32)),
            jnp.asarray(mask)))
        pred = np.argmax(logits, axis=-1)
        sel = mask > 0
        acc = float(np.mean(pred[sel] == self.labels[sel]))
        return {"loss": loss, "accuracy": acc}

    # ---------------- accounting ----------------

    def measured_exchange_bytes(self, params=None) -> int:
        """ppermute payload bytes of ONE training step, measured from
        the traced ``value_and_grad`` jaxpr — counts the forward relay
        replays AND their transposed (backward) replays, per layer. The
        repo-level evidence that the backward pass is the same
        bandwidth-bound exchange the paper characterizes (the bench
        suite records this as ``exchange_bytes_per_step``). Memoized
        per (backend, feature width, param structure): the measurement
        is a fresh trace of the whole backward graph, so repeated
        ``fit`` calls on one trainer pay it once."""
        from repro.gcn import engine as _engine

        eng = self.engine
        params = eng._resolve_params(params)
        F = eng._default_feat_dim(params)
        key = (self.impl, F, jax.tree.structure(params))
        if key not in self._exch_bytes:
            pdev = eng.plan_arrays(self.impl)
            Vp = eng.plan.part.vertices_per_node()
            x_abs = jax.ShapeDtypeStruct(eng.dims + (Vp, F), jnp.float32)
            fn = build_loss_grad(eng, self.impl)
            jaxpr = jax.make_jaxpr(
                lambda pd, p, xx, lb, mk: fn(pd, p, xx, lb, mk))(
                pdev, params, x_abs, self.labels_sh, self.mask_sh)
            self._exch_bytes[key] = _engine._ppermute_payload_bytes(
                jaxpr.jaxpr, 1)
        return self._exch_bytes[key]


# ---------------------------------------------------------------------------
# Single-node oracle
# ---------------------------------------------------------------------------


def reference_loss_and_grad(engine, feats, labels, mask=None, params=None):
    """Single-device dense-adjacency oracle for ``loss_and_grad``: the
    same prepared graph / combine / masked cross-entropy, aggregated by
    a plain COO segment-sum on one device
    (:func:`repro.core.gcn_models.reference_loop`) and differentiated
    with ``jax.value_and_grad`` — the parity target for the distributed
    gradients (fp32 tolerance; both aggregation backends)."""
    g2, w = engine.prepared_graph()
    params = engine._resolve_params(params)
    combine = engine.model_spec.combine
    V = engine.graph.num_vertices
    if mask is None:
        mask = np.ones(V, np.float32)
    lj = jnp.asarray(np.asarray(labels).astype(np.int32))
    mj = jnp.asarray(np.asarray(mask, np.float32))
    xj = jnp.asarray(feats)

    def loss_fn(p):
        logits = gm.reference_loop(g2, w, combine, p, xj)
        return masked_cross_entropy(logits, lj, mj)

    return jax.value_and_grad(loss_fn)(params)
