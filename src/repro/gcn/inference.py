"""Layer-major chunked inference — serve graphs that don't fit the mesh.

Full-graph :meth:`~repro.gcn.engine.GCNEngine.forward` needs the whole
relay plan and a full ``(V, F)`` device feature table resident at once,
so a graph whose plan exceeds ``set_cache_budget(plan_bytes=...)`` (or
whose features exceed the device) can be *trained* (PR 5's sampled
mini-batches) but not *served*. This module closes that gap with the
layer-major schedule DGL's ``GraphSAGE.inference`` and MG-GCN use:
compute layer ``l`` for ALL vertices in bounded node-chunks, materialize
``h_l`` on the host, then move to layer ``l+1`` — the device working set
is bounded by a chunk's 1-hop neighborhood instead of the k-hop closure
(or the full graph), which is exactly the paper's latency-tolerant,
bandwidth-bound regime (Observations 1-2).

How a chunk executes (all machinery reused from the sampled trainer):

  * the vertex range ``[lo, hi)`` plus its in-neighbors in the PREPARED
    graph (self loops + model edge weights) form the chunk's node set —
    **layer-independent**, so one sub-plan serves every layer;
  * :func:`~repro.core.sampling.induce_in_edges` keeps every prepared
    in-edge of the chunk's vertices (their sources are in the node set
    by construction), the vertex count is padded to a power of two and
    the plan is :func:`~repro.core.plan.pad_plan_pow2`-padded, so
    same-bucket chunks share ONE compiled step;
  * the sub-session is cached in the byte-bounded ``batch`` layer of
    :mod:`repro.gcn.cache` under a ``"chunk:"``-namespaced key (see
    that module's key-layout notes), so repeated inference over the
    same graph never re-plans;
  * layer inputs are gathered per chunk — ``h_0`` through the
    process-wide :class:`~repro.gcn.featurestore.FeatureStore` for
    store-handle inputs (never ``gather_all``; ad-hoc dense arrays
    row-index directly), ``h_{l-1}`` from the previous layer's
    materialized host buffer — and chunk outputs scatter back into
    ``h_l``.

**Exact parity.** Chunk results are bit-identical to full-graph
``forward``, not merely close: a destination vertex's fp32 aggregation
order is the plan's per-``(round, node)`` edge emission order, which
:func:`~repro.core.plan.build_plan` derives from a stable sort keyed on
source ids — and the induced subgraph's local ids are ascending in the
global ids, so every destination sums the SAME contributions in the
SAME order as the full plan. The combine is row-wise. Parity across
models x backends x chunk sizes is pinned by
``tests/test_gcn_inference.py``.

**Pipelined chunk preparation.** Chunk ``c+k``'s host-side work
(sub-plan build + pad, feature gather, device upload) runs on
:class:`~repro.gcn.pipeline.SamplePipeline` workers while the device
executes chunk ``c``. One pipeline per LAYER (layer ``l+1``'s prepare
reads ``h_l``, which must be complete), each consumed strictly in-order
— results are bit-identical to the serial path by the same purity
argument as ``fit_sampled``. The overlap won is reported as
``inference_overlap_fraction``, the device-resident feature high-water
mark as ``peak_feature_bytes``, both via ``engine.stats()``.

Admission: :func:`plan_over_budget` is the ``admission="auto"`` test
:class:`~repro.gcn.service.GCNService` uses — a *provable lower bound*
on the full plan's bytes against the plan-store budget, so over-budget
graphs route to layer-major WITHOUT ever building the full plan.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading

import jax
import numpy as np

from repro.core import sampling
from repro.core.partition import make_partition
from repro.core.plan import build_plan, pad_plan_pow2
from repro.gcn import cache, obs
from repro.gcn.pipeline import SamplePipeline

__all__ = ["ChunkSession", "estimate_plan_bytes", "forward_layer_major",
           "plan_over_budget"]

# bytes per prepared edge the full plan provably carries: the COO
# aggregation arrays alone hold one (edge_repl int32, edge_slot int32,
# edge_w float32) triple per edge — relay/deposit structures only add
# to it, so 12 * |prepared edges| is a LOWER bound on plan bytes
_BYTES_PER_EDGE_LB = 12


@dataclasses.dataclass(frozen=True)
class ChunkSession:
    """One chunk's cached execution context: the vertex range it owns,
    its 1-hop node set, the positions of the owned vertices inside that
    set (the scatter map back into ``h_l``), and the sub-engine over
    the padded induced plan. Layer-independent — cached once per
    (graph, chunking) in the ``batch`` layer and reused by every
    layer of every ``forward_layer_major`` call."""

    lo: int
    hi: int
    nodes: np.ndarray      # sorted global ids, chunk ∪ in-neighbors
    out_local: np.ndarray  # nodes[out_local[i]] == lo + i
    engine: object         # GCNEngine over the padded induced plan

    @property
    def num_padded_vertices(self) -> int:
        return self.engine.graph.num_vertices


class _PeakMeter:
    """Device-resident feature-byte high-water mark across pipeline
    workers and the consumer (chunk inputs charge at upload, outputs at
    execution; both release once the chunk's rows are on the host)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.live = 0
        self.peak = 0

    def add(self, n: int) -> None:
        with self._lock:
            self.live += int(n)
            self.peak = max(self.peak, self.live)

    def sub(self, n: int) -> None:
        with self._lock:
            self.live -= int(n)


# ---------------------------------------------------------------------------
# Admission estimate
# ---------------------------------------------------------------------------


def estimate_plan_bytes(engine) -> int:
    """Provable LOWER bound on the engine's full-plan host bytes,
    computed from the graph alone (no prepare, no plan build): the
    plan's COO aggregation arrays carry >= one 12-byte
    ``(edge_repl, edge_slot, edge_w)`` triple per prepared edge, and
    every registered model's prepare only ADDS edges (self loops) to
    the input graph's. Being a lower bound makes the ``admission=
    "auto"`` decision sound: estimate > budget means the real plan
    *definitely* cannot fit."""
    g = engine.graph
    return _BYTES_PER_EDGE_LB * (g.num_edges + g.num_vertices)


def plan_over_budget(engine) -> bool:
    """True when the engine's full plan provably cannot fit the
    process-wide plan-store budget (and is not already resident — a
    cached plan serves for free regardless of how the budget moved).
    This never builds or prepares anything: it is the
    ``admission="auto"`` test, safe to call on over-budget graphs."""
    if engine.plan_cached:
        return False
    budget = cache._PLANS.budget_bytes
    if budget is None:
        return False
    return estimate_plan_bytes(engine) > budget


# ---------------------------------------------------------------------------
# Chunk construction
# ---------------------------------------------------------------------------


def _prepared_csr(engine):
    """Destination-CSR of the parent PREPARED graph, memoized on the
    engine (the chunk analog of ``GCNTrainer._prepared_csr``; the
    service path has no trainer to hang it on). Assignment is atomic
    and the build is pure, so a worker race at worst duplicates it."""
    csr = getattr(engine, "_infer_csr", None)
    if csr is None:
        g2, w = engine.prepared_graph()
        csr = sampling.csr_in_with_values(g2, w)
        engine._infer_csr = csr
    return csr


def _chunk_nodes(indptr, src, lo: int, hi: int) -> np.ndarray:
    """The chunk's 1-hop node set: its own vertices plus every prepared
    in-neighbor (CSR rows ``lo..hi-1`` are contiguous, so one slice).
    Sorted global ids — ascending local ids therefore map to ascending
    global ids, the ordering fact the bit-parity argument rests on."""
    own = np.arange(lo, hi, dtype=np.int64)
    nbrs = np.asarray(src[indptr[lo]:indptr[hi]], np.int64)
    return np.union1d(own, nbrs)


def _chunk_session(engine, lo: int, hi: int,
                   nodes: np.ndarray) -> ChunkSession:
    """Cached chunk context through the byte-bounded ``batch`` layer.
    The key namespaces the graph-fp slot as ``"chunk:{parent}:{fp}"``
    — the parent fingerprint keeps coinciding node sets on different
    graphs apart, the ``chunk:`` prefix keeps chunk sub-plans and the
    trainer's ``batch:`` sub-plans apart (collision regression in
    tests/test_gcn_inference.py)."""
    from repro.gcn.engine import GCNEngine

    h = hashlib.sha1()
    h.update(np.int64(engine.graph.num_vertices).tobytes())
    h.update(np.int64(lo).tobytes())
    h.update(np.int64(hi).tobytes())
    h.update(np.ascontiguousarray(nodes).tobytes())
    key = dataclasses.replace(
        engine.plan_key.plan_identity(),
        graph_fp=f"chunk:{engine.graph_fp}:{h.hexdigest()}")

    def build():
        indptr, src, w = _prepared_csr(engine)
        S = nodes.size
        vpad = 1 if S <= 1 else 1 << (S - 1).bit_length()
        with obs.trace.span("plan_build", scope="chunk", nodes=S,
                            vpad=vpad):
            sub_g2, sub_w = sampling.induce_in_edges(
                indptr, src, w, nodes, num_vertices=vpad,
                name=f"{engine.graph.name}#chunk")
            part = make_partition(engine.cfg, engine.torus.num_nodes,
                                  num_vertices=vpad)
            plan = build_plan(
                engine.cfg, sub_g2, engine.torus, part, edge_weights=sub_w,
                bidir=engine.bidir)
        with obs.trace.span("pad_plan", vpad=vpad):
            plan = pad_plan_pow2(plan)
        sub = GCNEngine.from_plan(
            engine.cfg, plan, engine.dims, graph_fp=key.graph_fp,
            axis_names=engine.axis_names, name=sub_g2.name)
        out_local = np.searchsorted(nodes, np.arange(lo, hi)) \
            .astype(np.int64)
        return ChunkSession(lo=lo, hi=hi, nodes=nodes,
                            out_local=out_local, engine=sub)

    def nbytes(cs):
        return (cache._plan_nbytes(cs.engine.plan)
                + cs.nodes.nbytes + cs.out_local.nbytes)

    return cache.get_batch(key, build, nbytes=nbytes)


class _DenseSource:
    """Per-chunk row gather over a caller-owned host array — the
    ``h_0`` source for a dense per-request input. Deliberately NOT
    routed through the feature store: registering per-request content
    under the graph's fingerprint would REPLACE the session's
    registered features (the store is content-keyed per graph), so
    ad-hoc arrays index directly and only store handles hit the store."""

    def __init__(self, arr: np.ndarray):
        self.arr = arr
        self.feat_dim = int(arr.shape[1])

    def gather(self, nodes) -> np.ndarray:
        return self.arr[nodes]


def _h0_source(engine, feats):
    """Resolve the ``h_0`` source: a
    :class:`~repro.gcn.featurestore.FeatureHandle` passes through
    (validated) and layer 0 gathers per chunk through the store's
    device-resident cache — never ``gather_all`` (``full_gathers``
    stays 0); a dense ``(V, F)`` host array is row-indexed directly."""
    from repro.gcn import featurestore

    V = engine.graph.num_vertices
    if isinstance(feats, featurestore.FeatureHandle):
        if feats.num_vertices != V:
            raise ValueError(
                f"feature handle covers V={feats.num_vertices}, "
                f"engine graph has V={V}")
        if feats.graph_fp != engine.graph_fp:
            raise ValueError(
                "feature handle is registered for a different graph "
                f"({feats.graph_fp[:12]} != {engine.graph_fp[:12]})")
        return feats
    feats = np.asarray(feats, np.float32)
    if feats.ndim != 2 or feats.shape[0] != V:
        raise ValueError(
            f"forward_layer_major needs global (V={V}, F) host features "
            f"or a FeatureHandle; got {getattr(feats, 'shape', None)}")
    return _DenseSource(feats)


# ---------------------------------------------------------------------------
# The layer-major schedule
# ---------------------------------------------------------------------------


def forward_layer_major(engine, feats, params=None, *,
                        agg_impl: str | None = None,
                        chunk_size: int = 128,
                        pipeline_depth: int = 2,
                        pipeline_workers: int = 2) -> np.ndarray:
    """Whole-network inference, layer-major over vertex chunks; returns
    the global ``(V, F_out)`` host array, bit-identical to
    ``engine.forward(feats, params)`` — without ever building the
    full-graph plan or holding a full ``(V, F)`` device table.

    ``feats`` is a global ``(V, F)`` host array (row-indexed per
    chunk) or a :class:`~repro.gcn.featurestore.FeatureHandle`
    (gathered per chunk through the store's device-resident cache).
    ``chunk_size`` bounds the
    vertices a chunk OWNS (its device working set is the chunk's 1-hop
    node set, padded to a power of two — same-bucket chunks share one
    compiled step). ``pipeline_depth > 0`` prepares up to that many
    chunks ahead on ``pipeline_workers`` threads while the device
    executes (0 = serial; identical results either way).

    Telemetry lands on ``engine.stats()``: ``peak_feature_bytes`` (the
    device feature high-water mark) vs ``dense_feature_bytes`` (what
    full-graph forward would allocate), ``inference_overlap_fraction``
    (prepare time hidden behind execution) and the chunk-bucket hit
    rate."""
    if engine.bidir:
        raise ValueError(
            "forward_layer_major supports unidirectional plans only "
            "(pad_plan_pow2 constraint, same as fit_sampled)")
    impl = engine._impl(agg_impl)
    params = engine._resolve_params(params)
    handle = _h0_source(engine, feats)
    V = engine.graph.num_vertices
    chunk = max(1, min(int(chunk_size), V))
    indptr, src, _ = _prepared_csr(engine)
    ranges = [(lo, min(lo + chunk, V)) for lo in range(0, V, chunk)]
    node_sets = [_chunk_nodes(indptr, src, lo, hi) for lo, hi in ranges]

    b0 = cache.cache_stats()["batch"]
    meter = _PeakMeter()
    pipe_stats: list[dict] = []
    widths = [handle.feat_dim]
    h: np.ndarray | None = None  # materialized h_{l-1} (None = h_0)

    for li, layer in enumerate(params):
        last = li == len(params) - 1
        h_prev = h

        def prepare(ci, h_prev=h_prev):
            """One chunk's host-side chain — cached sub-plan lookup (or
            build + pow2 pad), compiled-step lookup, per-chunk gather,
            device upload. Pure in ``ci`` for a fixed layer: ``h_prev``
            is complete and read-only once this layer's pipeline
            starts, and every cache is content-keyed."""
            with obs.trace.span("chunk_prepare", chunk=ci, layer=li):
                cs = _chunk_session(engine, *ranges[ci], node_sets[ci])
                sub = cs.engine
                S = cs.nodes.size
                F = handle.feat_dim if h_prev is None else h_prev.shape[1]
                xb = np.zeros((sub.graph.num_vertices, F), np.float32)
                if h_prev is None:
                    xb[:S] = handle.gather(cs.nodes)
                else:
                    xb[:S] = h_prev[cs.nodes]
                step = sub._compiled_layer_step(impl)
                pdev = sub.plan_arrays(impl)
                with obs.trace.span("upload", what="chunk_input",
                                    rows=S):
                    x, _ = sub._shard_input(xb)
                    jax.block_until_ready(x)
                nb = int(x.nbytes)
                meter.add(nb)
                return cs, step, pdev, x, nb

        pipe = None
        if pipeline_depth > 0 and len(ranges) > 1:
            pipe = SamplePipeline(list(range(len(ranges))), prepare,
                                  depth=pipeline_depth,
                                  workers=pipeline_workers)
        h_next: np.ndarray | None = None
        try:
            for ci in range(len(ranges)):
                cs, step, pdev, x, nb = (pipe.get(ci) if pipe is not None
                                         else prepare(ci))
                bucket = (impl, cs.num_padded_vertices, int(x.shape[-1]))
                engine._chunk_calls += 1
                if bucket in engine._chunk_buckets:
                    engine._chunk_hits += 1
                else:
                    engine._chunk_buckets.add(bucket)
                with obs.trace.span("chunk_execute", chunk=ci, layer=li):
                    y = step(pdev, x, layer, last=last)
                    ynb = int(y.nbytes)
                    meter.add(ynb)
                    out = cs.engine.unshard(np.asarray(y))  # (vpad, F_out)
                meter.sub(nb + ynb)
                if h_next is None:
                    h_next = np.empty((V, out.shape[-1]), out.dtype)
                h_next[cs.lo:cs.hi] = out[cs.out_local]
        finally:
            if pipe is not None:
                pipe.close()
        if pipe is not None:
            pipe_stats.append(pipe.stats())
        h = h_next
        widths.append(int(h.shape[1]))

    b1 = cache.cache_stats()["batch"]
    obs.metrics.counter(
        "inference.chunks", unit="chunks",
        help="layer-major chunk steps executed (chunks x layers)"
    ).add(len(ranges) * len(params))
    prep_s = sum(p["prepare_s"] for p in pipe_stats)
    hidden_s = sum(p["overlap_s"] for p in pipe_stats)
    # what full-graph forward would hold on device at its widest layer
    # step: the sharded padded input table PLUS that step's output
    # table (the meter charges chunks the same way; the full plan's
    # own arrays come on top of this and are not counted for either)
    dense = (engine.part.vertices_per_node() * engine.torus.num_nodes * 4
             * max(widths[i] + widths[i + 1] for i in range(len(params))))
    engine._inference_stats = {
        "chunks": len(ranges),
        "chunk_size": chunk,
        "layers": len(params),
        "peak_feature_bytes": meter.peak,
        "dense_feature_bytes": int(dense),
        "overlap_fraction": obs.overlap_fraction(hidden_s, prep_s),
        "overlap_s": hidden_s,
        "prepare_s": prep_s,
        "pipeline_depth": pipeline_depth if pipe_stats else 0,
        "chunk_plan_hits": b1["hits"] - b0["hits"],
        "chunk_plan_misses": b1["misses"] - b0["misses"],
    }
    return h
