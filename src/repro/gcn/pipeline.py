"""Bounded producer/consumer pipeline for the sampled training chain.

The paper's Observation 2 — MultiAccSys GCN execution is bandwidth-bound
and latency-tolerant — is a license to hide host-side latency behind
device execution. PR 3 cashed part of it in (the service's async plan
*uploads* overlap execution); this module extends the overlap across the
WHOLE per-batch chain of ``GCNTrainer.fit_sampled``: while the device
executes batch ``t``, a pool of worker threads samples batch ``t+k``,
builds + ``pad_plan_pow2``-pads its relay plan, pre-gathers its feature
blocks through the process-wide :class:`~repro.gcn.featurestore.
FeatureStore`, and uploads the device arrays — the producer/consumer
split DGL's decoupled distributed samplers and MG-GCN's pipelined
multi-GPU execution use, in-process.

Correctness contract (pinned by ``tests/test_gcn_pipeline.py``):

  * **deterministic order** — tasks are indexed; :meth:`SamplePipeline.
    get` delivers results strictly in index order no matter how workers
    finish, so the pipelined epoch consumes batches in exactly the
    serial order. Because every prepare step is a pure function of its
    task (per-seed-set rng, content-addressed caches whose hits/misses
    change cost but never values), the pipelined trajectory is
    **bit-identical** to ``pipeline_depth=0`` — the same fixed point
    the PR-3 async-upload fence established, across the whole chain;
  * **bounded look-ahead** — at most ``depth`` tasks are claimed beyond
    the consumer's position (claimed = in-flight building or ready in
    the reorder buffer), so the pipeline's working set — plan bytes,
    feature blocks, device uploads — is bounded by ``depth`` batches,
    not by the epoch;
  * **fail-fast drain** — a worker exception is captured into the
    failed task's slot and re-raised on the consuming thread the moment
    it reaches that index (consumption is in-order, so that is within
    one step of the failure surfacing). ``close`` — which ``get`` runs
    before re-raising, and the trainer runs in a ``finally`` — stops
    claiming, wakes every waiter, joins all workers and clears the
    buffer: no orphan threads, no half-consumed queue
    (``threading.enumerate()`` delta is pinned by test).

Telemetry: :meth:`SamplePipeline.stats` reports how much prepare wall
time was hidden behind the consumer (``overlap_fraction``; the consumer
reports its blocked time via the ``get`` timer) and the mean reorder-
buffer occupancy at consume time (``queue_occupancy_mean``) —
``GCNEngine.stats`` surfaces both after a pipelined fit.
"""
from __future__ import annotations

import threading
import time

from repro.gcn import obs

__all__ = ["SamplePipeline"]

# thread-name prefix, so tests can pin the no-orphan-threads contract
# without racing unrelated daemon threads
THREAD_PREFIX = "gcn-pipe"


class SamplePipeline:
    """Run ``prepare(task)`` for an indexed task list on a worker pool,
    delivering results strictly in task order with at most ``depth``
    tasks claimed beyond the consumer.

    ``prepare`` must be safe to call from worker threads and pure in
    its task (same task -> same value): duplicate or discarded work may
    happen near ``close``, never wrong values. Typical use::

        pipe = SamplePipeline(tasks, prepare, depth=2, workers=2)
        try:
            for i in range(len(tasks)):
                item = pipe.get(i)   # in order; re-raises worker errors
                ...consume item...
        finally:
            pipe.close()
    """

    def __init__(self, tasks, prepare, *, depth: int = 2,
                 workers: int = 2, name: str = THREAD_PREFIX):
        self.tasks = list(tasks)
        self.prepare = prepare
        self.depth = max(int(depth), 1)
        self.workers = max(int(workers), 1)
        self._cv = threading.Condition()
        # reorder buffer: index -> (value, error); bounded by depth
        self._ready: dict[int, tuple] = {}
        self._next_claim = 0
        self._next_consume = 0
        self._closed = False
        # telemetry (all mutated under the condition's lock)
        self._prepare_s = 0.0  # sum of per-task prepare wall time
        self._wait_s = 0.0  # consumer time blocked inside get()
        self._prepared = 0
        self._occ_sum = 0
        self._gets = 0
        # test-injectable barrier: called by close() after the closed
        # flag is set but before workers are joined / the buffer is
        # dropped (None outside the race regression tests)
        self._drain_barrier = None
        self._threads = [
            threading.Thread(target=self._work, name=f"{name}-{i}",
                             daemon=True)
            for i in range(self.workers)
        ]
        for t in self._threads:
            t.start()

    # ---------------- worker side ----------------

    def _claimable(self) -> bool:
        # bounded look-ahead: claimed-but-unconsumed (building + ready)
        # may never exceed depth, so the pipeline's working set is
        # depth batches, not the epoch
        return (self._next_claim < len(self.tasks)
                and self._next_claim - self._next_consume < self.depth)

    def _work(self) -> None:
        while True:
            with self._cv:
                while not self._closed and not self._claimable():
                    self._cv.wait()
                if self._closed:
                    return
                i = self._next_claim
                self._next_claim += 1
            t0 = time.perf_counter()
            # the exception exits THROUGH the span (its record carries
            # error=True — a failing worker never leaves an open span)
            # and is captured here for the in-order re-raise in get()
            try:
                with obs.trace.span("pipe_prepare", task=i):
                    val, err = self.prepare(self.tasks[i]), None
            except BaseException as e:  # re-raised on the consumer
                val, err = None, e
            dt = time.perf_counter() - t0
            obs.metrics.counter(
                "pipeline.prepare_s", unit="s",
                help="worker seconds spent preparing pipeline tasks"
            ).add(dt)
            obs.metrics.counter(
                "pipeline.prepared", unit="tasks",
                help="pipeline tasks prepared by worker threads").add(1)
            with obs.trace.span("pipe_commit", task=i), self._cv:
                self._prepare_s += dt
                self._prepared += 1
                if self._closed:
                    return  # drained: the result is discarded
                self._ready[i] = (val, err)
                self._cv.notify_all()

    # ---------------- consumer side ----------------

    def get(self, index: int):
        """Block until task ``index`` (which must be the next unconsumed
        index) is prepared; return its value or re-raise the worker's
        exception after draining the pipeline. The time spent blocked
        here is the NON-hidden part of prepare latency (see
        :meth:`stats`)."""
        with obs.trace.span("pipe_get", task=index), self._cv:
            if index != self._next_consume:
                raise ValueError(
                    f"out-of-order get: index {index}, expected "
                    f"{self._next_consume}")
            closed_at_entry = self._closed
            self._occ_sum += len(self._ready)
            self._gets += 1
            t0 = time.perf_counter()
            while index not in self._ready and not self._closed:
                self._cv.wait()
            dt = time.perf_counter() - t0
            self._wait_s += dt
            # Buffer BEFORE the closed flag: a result already committed
            # for this index survives a concurrently-arriving close()
            # (e.g. the trainer's ``finally`` racing the last get) —
            # close() only drops the buffer after workers are joined, so
            # a waiter woken by close's notify still finds its value.
            entry = self._ready.pop(index, None)
            if entry is None:
                raise RuntimeError(
                    "pipeline is closed" if closed_at_entry
                    else "pipeline closed while waiting")
            val, err = entry
            self._next_consume += 1
            self._cv.notify_all()  # a claim slot opened
        obs.metrics.counter(
            "pipeline.wait_s", unit="s",
            help="consumer seconds blocked waiting on pipeline results"
        ).add(dt)
        if err is not None:
            self.close()
            raise err
        return val

    def close(self) -> None:
        """Stop claiming, wake every waiter, join all workers, drop the
        buffer. Idempotent; safe to call from ``finally`` and after a
        ``get`` re-raised. A worker mid-prepare finishes its current
        task (its result is discarded) and exits."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._drain_barrier is not None:
            # test hook: hold the close here — flag set, buffer intact —
            # so the get()-vs-close() race window is deterministic
            self._drain_barrier()
        for t in self._threads:
            if t is not threading.current_thread():
                t.join()
        with self._cv:
            self._ready.clear()

    # ---------------- telemetry ----------------

    def stats(self) -> dict:
        """Overlap accounting: of ``prepare_s`` total worker seconds,
        the part the consumer did NOT spend blocked in :meth:`get` was
        hidden behind consumer execution — ``overlap_fraction`` is that
        hidden share (0.0 = fully serial, 1.0 = every prepare fully
        hidden). ``queue_occupancy_mean`` is the mean number of ready
        (prepared, unconsumed) batches observed at each ``get`` — how
        far ahead the producers actually ran within the ``depth``
        bound."""
        with self._cv:
            hidden = max(self._prepare_s - self._wait_s, 0.0)
            return {
                "depth": self.depth,
                "workers": self.workers,
                "tasks": len(self.tasks),
                "prepared": self._prepared,
                "prepare_s": self._prepare_s,
                "wait_s": self._wait_s,
                "overlap_s": hidden,
                "overlap_fraction": obs.overlap_fraction(
                    hidden, self._prepare_s),
                "queue_occupancy_mean": obs.ratio(
                    self._occ_sum, self._gets),
            }
