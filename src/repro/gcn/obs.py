"""Unified observability for the GCN stack: spans + typed metrics.

The paper's core claims are quantitative (32 % fewer transmissions,
73 % fewer off-chip accesses, latency hidden behind bandwidth —
Observations 1-2), and before this module the repo's evidence for them
was scattered across seven ad-hoc ``stats()`` dicts with no common
schema and no per-event timeline. This module is the cross-cutting
layer both gaps close through:

  * :class:`Tracer` — span-based tracing (``with trace.span(
    "plan_build", batch=fp):``) into a bounded ring buffer, with
    begin/end timestamps, thread attribution and free-form attrs.
    :meth:`Tracer.export` writes Chrome ``trace_event`` JSON loadable
    in ``chrome://tracing`` / Perfetto — one track per thread, so the
    sampling pipeline's prepare work on ``gcn-pipe`` workers shows as
    bars actually overlapping the training thread's ``execute`` bars.
  * :class:`MetricsRegistry` — typed counters/gauges/histograms with
    declared units and help text. The module-level :data:`metrics`
    registry is the single PROCESS-WIDE accumulation point the
    instrumented stages feed (feature hit/miss rows, exchange bytes,
    pipeline prepare/wait seconds, uploads, ...); per-object
    ``stats()`` dicts stay as per-session views, and
    :func:`telemetry` / ``GCNEngine.telemetry()`` snapshot the
    registry with a schema version for the bench records.

Design constraints (pinned by ``tests/test_gcn_obs.py``):

  * **observe, never synchronize** — an enabled span reads a clock and
    appends one tuple to a ``deque`` (GIL-atomic); it takes no lock on
    the hot path and never blocks another thread, so pipelined
    trajectories stay bit-identical with tracing on.
  * **near-zero overhead when disabled** — ``trace.enabled`` is a
    plain attribute; hot call sites guard on it and the disabled
    ``span()`` returns one shared no-op singleton (no per-call
    allocation, asserted by a tracemalloc smoke check).
  * **deterministic tests** — the clock is injectable
    (``Tracer(clock=...)``).

The shared :func:`overlap_fraction` / :func:`ratio` helpers replace the
hand-rolled fraction computations in ``pipeline.py`` / ``inference.py``
/ ``service.py``; surfaces that cannot distinguish "measured zero" from
"never ran" pass ``default=None`` so unmeasured telemetry reads as
``None``, not ``0.0``.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque

__all__ = [
    "KNOWN_PHASES",
    "MetricsRegistry",
    "TELEMETRY_SCHEMA_VERSION",
    "Tracer",
    "metrics",
    "overlap_fraction",
    "ratio",
    "telemetry",
    "trace",
]

#: bumped whenever the shape of :func:`telemetry` snapshots changes;
#: ``benchmarks/run.py`` asserts the embedded snapshot carries it
TELEMETRY_SCHEMA_VERSION = 1

#: every span name the instrumented stages emit — ``tools/
#: check_trace.py`` rejects exported traces with names outside this set
#: (a misspelled phase would otherwise silently fork the timeline)
KNOWN_PHASES = frozenset({
    "sample",          # NeighborSampler.sample
    "plan_build",      # build_plan (full / batch / chunk)
    "pad_plan",        # pad_plan_pow2
    "ell_build",       # blocked-ELL layout build
    "feature_gather",  # FeatureStore.gather
    "upload",          # device upload (plan arrays / batch inputs)
    "execute",         # compiled-step execution
    "evaluate",        # train-time evaluation
    "batch_prepare",   # the sampled trainer's whole per-batch chain
    "pipe_prepare",    # SamplePipeline worker prepare
    "pipe_commit",     # SamplePipeline result commit
    "pipe_get",        # SamplePipeline consumer get/wait
    "serve_admit",     # GCNService.admit / adopt
    "serve_step",      # one GCNService tick
    "serve_upload",    # service plan upload (sync or prefetch)
    "chunk_prepare",   # layer-major chunk prepare
    "chunk_execute",   # layer-major chunk execute
    "history_agg",     # CV correction build (history read + upload)
    "history_write",   # CV activation write-back after the step
})


# ---------------------------------------------------------------------------
# Shared fraction helpers (the one place overlap/hit-rate math lives)
# ---------------------------------------------------------------------------


def ratio(num, den, *, default=0.0):
    """``num / den`` with an explicit empty-denominator policy:
    ``default=0.0`` keeps legacy surfaces bit-identical, ``default=
    None`` makes "never measured" distinguishable from a measured
    zero (the silent-zero fix on ``engine.stats()`` /
    ``inference_stats()``)."""
    return num / den if den else default


def overlap_fraction(hidden_s: float, total_s: float, *, default=0.0):
    """Share of ``total_s`` wall seconds that was hidden behind
    concurrent execution — the ONE definition behind
    ``SamplePipeline.stats()['overlap_fraction']``,
    ``inference_overlap_fraction`` and the service's
    ``upload_overlap_fraction`` (they previously hand-rolled the same
    expression three times). ``default`` is returned when nothing was
    measured (``total_s == 0``)."""
    return ratio(hidden_s, total_s, default=default)


# ---------------------------------------------------------------------------
# Typed metrics registry
# ---------------------------------------------------------------------------


class _Instrument:
    """Common identity of a declared metric: name + unit + help."""

    kind = "instrument"

    def __init__(self, name: str, unit: str, help: str,
                 lock: threading.Lock):
        self.name = name
        self.unit = unit
        self.help = help
        self._lock = lock

    def describe(self) -> dict:
        return {"type": self.kind, "unit": self.unit, "help": self.help}


class Counter(_Instrument):
    """Monotonic process-cumulative count (rows, bytes, calls,
    seconds-of-work). Never decremented, never reset by per-object
    ``clear()`` paths — the Prometheus-style ledger."""

    kind = "counter"

    def __init__(self, name, unit, help, lock):
        super().__init__(name, unit, help, lock)
        self._value = 0

    def add(self, n=1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value

    def _snapshot(self) -> dict:
        return {**self.describe(), "value": self._value}

    def _reset(self) -> None:
        self._value = 0


class Gauge(_Instrument):
    """Last-observed value (queue depth, bytes-per-step, fractions)."""

    kind = "gauge"

    def __init__(self, name, unit, help, lock):
        super().__init__(name, unit, help, lock)
        self._value = None

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self):
        return self._value

    def _snapshot(self) -> dict:
        return {**self.describe(), "value": self._value}

    def _reset(self) -> None:
        self._value = None


class Histogram(_Instrument):
    """Streaming summary (count/sum/min/max) of an observed
    distribution — per-phase span durations land here."""

    kind = "histogram"

    def __init__(self, name, unit, help, lock):
        super().__init__(name, unit, help, lock)
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None

    def observe(self, v) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)

    def _snapshot(self) -> dict:
        return {**self.describe(), "count": self.count,
                "sum": self.sum, "min": self.min, "max": self.max,
                "mean": self.sum / self.count if self.count else None}

    def _reset(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None


class MetricsRegistry:
    """Thread-safe, typed metric store. Declaration is idempotent —
    ``counter(name, ...)`` returns the existing instrument when the
    name is already declared with the same type and unit, and raises
    on a conflicting redeclaration (two call sites silently feeding
    one name with different meanings is exactly the scattered-counter
    failure mode this registry replaces)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, _Instrument] = {}

    def _declare(self, cls, name: str, unit: str, help: str):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is not None:
                if inst.kind != cls.kind or inst.unit != unit:
                    raise ValueError(
                        f"metric {name!r} already declared as "
                        f"{inst.kind}[{inst.unit!r}]; cannot redeclare "
                        f"as {cls.kind}[{unit!r}]")
                return inst
            inst = cls(name, unit, help, self._lock)
            self._instruments[name] = inst
            return inst

    def counter(self, name: str, *, unit: str = "",
                help: str = "") -> Counter:
        return self._declare(Counter, name, unit, help)

    def gauge(self, name: str, *, unit: str = "", help: str = "") -> Gauge:
        return self._declare(Gauge, name, unit, help)

    def histogram(self, name: str, *, unit: str = "",
                  help: str = "") -> Histogram:
        return self._declare(Histogram, name, unit, help)

    def get(self, name: str) -> _Instrument | None:
        with self._lock:
            return self._instruments.get(name)

    def value(self, name: str, default=0):
        """Convenience: a counter/gauge's current value (``default``
        when the metric was never declared)."""
        inst = self.get(name)
        return default if inst is None else inst.value

    def snapshot(self) -> dict:
        """Schema-versioned dict of every declared metric — what
        ``engine.telemetry()`` returns and the bench records embed."""
        with self._lock:
            return {
                "schema_version": TELEMETRY_SCHEMA_VERSION,
                "metrics": {n: inst._snapshot()
                            for n, inst in sorted(
                                self._instruments.items())},
            }

    def reset(self) -> None:
        """Zero every value, keep every declaration (tests diff known
        workloads against a clean ledger)."""
        with self._lock:
            for inst in self._instruments.values():
                inst._reset()


# ---------------------------------------------------------------------------
# Span tracer
# ---------------------------------------------------------------------------


class _NullSpan:
    """The disabled-path singleton: entering/exiting allocates
    nothing, so guarded hot paths pay one attribute read."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records (name, t0, t1, tid, thread, attrs, ok)
    into its tracer's ring buffer on exit — also when the body raised,
    so a failing pipeline worker still closes its spans (the record
    carries ``error=True``)."""

    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict | None):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs):
        """Attach attrs discovered after the span opened (batch sizes,
        byte counts)."""
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)
        return self

    def __enter__(self):
        self._t0 = self._tracer.clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        self._tracer._record(self.name, self._t0, self._tracer.clock(),
                             self.attrs, exc_type is None)
        return False


class Tracer:
    """Process-wide span recorder with a bounded ring buffer and Chrome
    ``trace_event`` export.

    ``enabled`` is a plain attribute — hot paths guard on it and pay
    nothing else while tracing is off. When on, a span costs two clock
    reads and one ``deque.append`` (GIL-atomic; no lock, no waiting:
    spans observe, never synchronize). The buffer keeps the most
    recent ``capacity`` spans. ``clock`` is injectable for
    deterministic tests; ``registry`` (optional) additionally folds
    every recorded span into a per-phase duration histogram
    (``span_s.<name>``), which is how traced bench runs get per-phase
    breakdowns into their telemetry snapshot."""

    def __init__(self, *, enabled: bool = False, capacity: int = 65536,
                 clock=time.perf_counter,
                 registry: MetricsRegistry | None = None):
        self.enabled = bool(enabled)
        self.clock = clock
        self.registry = registry
        self._buf: deque = deque(maxlen=int(capacity))
        self._epoch = clock()

    # ---------------- recording ----------------

    def span(self, name: str, **attrs):
        """Context manager timing one ``name`` phase; kwargs become the
        span's attrs. Returns the shared no-op singleton while
        disabled."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, attrs or None)

    def _record(self, name, t0, t1, attrs, ok) -> None:
        if not self.enabled:
            return  # disabled mid-span: drop silently
        t = threading.current_thread()
        self._buf.append((name, t0, t1, t.ident, t.name, attrs, ok))
        reg = self.registry
        if reg is not None:
            reg.histogram(f"span_s.{name}", unit="s",
                          help=f"wall seconds of {name!r} spans") \
                .observe(t1 - t0)

    # ---------------- control ----------------

    def configure(self, *, enabled: bool | None = None,
                  capacity: int | None = None, clock=None) -> "Tracer":
        """Reconfigure in place (launchers flip ``enabled`` on
        ``--trace-out``). Changing ``capacity`` re-bounds the buffer,
        keeping the newest spans; changing ``clock`` re-anchors the
        export epoch."""
        if capacity is not None:
            self._buf = deque(self._buf, maxlen=int(capacity))
        if clock is not None:
            self.clock = clock
            self._epoch = clock()
        if enabled is not None:
            self.enabled = bool(enabled)
        return self

    def clear(self) -> None:
        self._buf.clear()

    def events(self) -> list[dict]:
        """Snapshot of the buffered spans, oldest first (test/debug
        surface; ``export`` is the interchange format)."""
        return [{"name": n, "t0": t0, "t1": t1, "tid": tid,
                 "thread": tname, "attrs": attrs, "ok": ok}
                for n, t0, t1, tid, tname, attrs, ok in list(self._buf)]

    # ---------------- Chrome trace export ----------------

    def _us(self, t: float) -> float:
        return (t - self._epoch) * 1e6

    def export(self, path: str) -> int:
        """Write the buffered spans as Chrome ``trace_event`` JSON
        (``{"traceEvents": [...]}``, balanced B/E duration events, one
        track per thread via ``thread_name`` metadata). Returns the
        number of spans exported. Loadable in ``chrome://tracing`` or
        https://ui.perfetto.dev; validated by ``tools/check_trace.py``.

        Spans are buffered at completion time, so per-thread nesting is
        reconstructed here: within one thread, context-manager
        discipline guarantees proper nesting, and a start-ascending /
        longest-first sweep with an explicit stack re-emits the
        balanced B/E order."""
        spans = list(self._buf)
        pid = os.getpid()
        events: list[dict] = [{
            "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "ts": 0.0, "args": {"name": "repro-gcn"},
        }]
        by_tid: dict[int, list] = {}
        for rec in spans:
            by_tid.setdefault(rec[3], []).append(rec)
        for tid, recs in sorted(by_tid.items()):
            events.append({
                "ph": "M", "name": "thread_name", "pid": pid,
                "tid": tid, "ts": 0.0,
                "args": {"name": recs[-1][4]},
            })
            recs.sort(key=lambda r: (r[1], -r[2]))
            stack: list = []

            def emit_end(r):
                events.append({"ph": "E", "name": r[0], "pid": pid,
                               "tid": tid, "ts": self._us(r[2])})

            for r in recs:
                while stack and r[1] >= stack[-1][2]:
                    emit_end(stack.pop())
                ev = {"ph": "B", "name": r[0], "cat": "gcn", "pid": pid,
                      "tid": tid, "ts": self._us(r[1])}
                args = _json_safe(r[5]) if r[5] else {}
                if not r[6]:
                    args["error"] = True
                if args:
                    ev["args"] = args
                events.append(ev)
                stack.append(r)
            while stack:
                emit_end(stack.pop())
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"},
                      f)
        return len(spans)


def _json_safe(attrs: dict) -> dict:
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (bool, int, float, str)) or v is None:
            out[str(k)] = v
        else:
            out[str(k)] = str(v)
    return out


# ---------------------------------------------------------------------------
# Process-wide singletons
# ---------------------------------------------------------------------------

#: the single typed registry every instrumented stage feeds
metrics = MetricsRegistry()

#: the process-wide tracer (disabled until a launcher's ``--trace-out``
#: or a test enables it); spans feed ``span_s.*`` histograms in
#: :data:`metrics` while enabled
trace = Tracer(registry=metrics)


def telemetry() -> dict:
    """Schema-versioned snapshot of the process-wide registry — the
    payload ``GCNEngine.telemetry()`` returns and every bench record
    embeds under its ``"telemetry"`` key."""
    return metrics.snapshot()
