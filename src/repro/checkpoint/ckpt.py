"""Checkpointing: atomic npz + manifest, async save thread, and
reshard-on-load (elastic scaling: a checkpoint written on one mesh can be
restored onto a different device count/mesh — shardings are reapplied at
load time from the target mesh's spec tree).

Layout:
  <dir>/step_<n>/arrays.npz     flat {path -> np.ndarray}
  <dir>/step_<n>/manifest.json  {step, treedef paths, dtypes, meta}
  <dir>/LATEST                  text file with the newest complete step

Writes are atomic (tmp dir + rename) so a preemption mid-save never
corrupts the latest pointer — restart-safe by construction.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str | Path, step: int, tree, meta: dict | None = None):
    """Synchronous atomic save."""
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f".tmp_step_{step}"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten_with_paths(tree)
    arrays = {}
    dtypes = {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        dtypes[k] = str(a.dtype)
        if a.dtype.kind not in "fiub":  # bf16/fp8 (kind 'V'): npz-unsupported
            a = a.astype(np.float32)  # bf16 -> f32 is exact
        arrays[k] = a
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "dtypes": dtypes,  # ORIGINAL dtypes (restore casts back)
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "meta": meta or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    (ckpt_dir / "LATEST.tmp").write_text(str(step))
    os.replace(ckpt_dir / "LATEST.tmp", ckpt_dir / "LATEST")


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (one in flight)."""

    def __init__(self, ckpt_dir: str | Path):
        self.ckpt_dir = Path(ckpt_dir)
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree, meta: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree, meta),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str | Path) -> int | None:
    p = Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore(ckpt_dir: str | Path, like, step: int | None = None,
            mesh=None, specs=None):
    """Restore into the structure of ``like``.

    ``mesh``+``specs`` (same pytree structure as ``like``) reshard the
    loaded arrays onto the *current* mesh — the elastic-scaling path: the
    saved mesh shape is irrelevant, only logical shapes must match.
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    z = np.load(ckpt_dir / f"step_{step}" / "arrays.npz")
    flat_like = _flatten_with_paths(like)
    out_flat = {}
    for k, leaf in flat_like.items():
        arr = z[k]
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        out_flat[k] = arr
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = list(_flatten_with_paths(like).keys())
    restored = treedef.unflatten([out_flat[p] for p in paths])
    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding

        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            restored, specs,
            is_leaf=lambda x: isinstance(x, np.ndarray))
    return restored, step
