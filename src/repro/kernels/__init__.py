"""Pallas TPU kernels for the compute hot-spots, each with a pure-jnp
oracle (``ref.py``) and a jit'd platform-dispatching wrapper (``ops.py``).

* flash_attention — the LM prefill/train attention hot-spot
* spmm            — GCN aggregation as blocked indicator matmuls (MXU)
* matmul          — fused combination matmul (bias + activation)
"""
from . import flash_attention, matmul, spmm

__all__ = ["flash_attention", "matmul", "spmm"]
