"""Pure-jnp oracle for the aggregation SpMM."""
from __future__ import annotations

import jax.numpy as jnp


def spmm_coo_ref(replica, edge_repl, edge_slot, edge_w, num_slots: int):
    """Weighted COO segment-sum: acc[slot] += w * replica[row]."""
    msgs = replica[edge_repl] * edge_w[:, None].astype(replica.dtype)
    acc = jnp.zeros((num_slots, replica.shape[-1]), replica.dtype)
    return acc.at[edge_slot].add(msgs)


def spmm_ell_ref(seg, messages, block_slots: int):
    """Blocked-ELL oracle matching kernel.spmm_ell."""
    nb, Eb, F = messages.shape
    acc = jnp.zeros((nb, block_slots, F), messages.dtype)
    b_idx = jnp.repeat(jnp.arange(nb), Eb)
    s_idx = seg.reshape(-1)
    valid = s_idx >= 0
    acc = acc.at[b_idx, jnp.maximum(s_idx, 0)].add(
        jnp.where(valid[:, None], messages.reshape(-1, F), 0))
    return acc
