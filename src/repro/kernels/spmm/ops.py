"""Jit'd wrapper + host-side ELL layout builders for the aggregation SpMM.

This module is the seam between the host-side planner and the Pallas
kernel: :func:`build_ell_layout` re-packs a COO edge list into the
blocked-ELL form the kernel consumes, and :func:`build_ell_layout_rounds`
does the same for a whole ``CommPlan`` worth of per-(round, node) edge
lists with one common shape (SPMD requires identical shapes per shard).

ELL layout invariants (relied on by ``kernel.spmm_ell`` and by the
executor in ``repro.core.message_passing``):

  * **slot blocking** — destination slots are grouped into blocks of
    ``block_slots``; block ``b`` owns slots ``[b*block_slots, (b+1)*
    block_slots)`` and ``seg`` holds the *within-block* slot index.
  * **slot padding** — unused entries carry ``seg == -1`` (matches no
    slot in the kernel's iota compare) AND ``weight == 0`` (contributes
    nothing even where the gather is materialized), so padding is
    doubly neutralized.
  * **replica ordering** — ``rows`` indexes the replica buffer in the
    planner's allocation order; padded entries point at row 0, which
    always exists (``replica_rows >= 1``) and is masked by the zero
    weight.
  * **edge alignment** — every block row is padded to a common width
    ``Eb`` that is a multiple of ``edge_align``, so the kernel's edge
    grid divides evenly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.spmm import kernel as _k
from repro.kernels.spmm import ref as _ref

AGG_IMPLS = ("jnp", "pallas")


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_impl(impl: str = "auto") -> str:
    """Resolve an aggregation-backend request to a concrete impl.

    ``"auto"`` picks the Pallas kernel on TPU and the portable jnp
    scatter-add elsewhere (mirroring how ``repro.nn.attention`` treats
    its ``impl`` axis: auto = portable default, explicit ``"pallas"``
    forces the kernel — in interpret mode off-TPU, so tests exercise
    the identical code path).
    """
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl not in AGG_IMPLS:
        raise ValueError(
            f"unknown aggregation impl {impl!r}; expected 'auto', "
            f"or one of {AGG_IMPLS}")
    return impl


def ell_width(counts_max: int, edge_align: int) -> int:
    """Common padded block-row width for a max per-block edge count."""
    return max(edge_align, -(-int(max(counts_max, 1)) // edge_align)
               * edge_align)


def build_ell_layout(edge_repl: np.ndarray, edge_slot: np.ndarray,
                     edge_w: np.ndarray, num_slots: int,
                     block_slots: int = 128, edge_align: int = 512,
                     width: int | None = None):
    """Host-side: sort COO edges by slot block and pad per block.

    Returns (seg (nb, Eb), gather_rows (nb, Eb), weights (nb, Eb)) where
    seg is the within-block slot index (-1 pad). ``width`` forces a
    common Eb across independently-built layouts (the batched builder
    below uses it so every (round, node) shard has one static shape)."""
    nb = max(1, -(-num_slots // block_slots))
    blk = edge_slot // block_slots
    order = np.argsort(blk, kind="stable")
    counts = np.bincount(blk, minlength=nb)
    Eb = width or ell_width(int(counts.max(initial=1)), edge_align)
    seg = np.full((nb, Eb), -1, np.int32)
    rows = np.zeros((nb, Eb), np.int32)
    w = np.zeros((nb, Eb), np.float32)
    starts = np.zeros(nb + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    for b in range(nb):
        sel = order[starts[b]:starts[b + 1]]
        seg[b, :sel.size] = edge_slot[sel] - b * block_slots
        rows[b, :sel.size] = edge_repl[sel]
        w[b, :sel.size] = edge_w[sel]
    return seg, rows, w


def ell_layout_shape(edge_slot: np.ndarray, edge_w: np.ndarray,
                     num_slots: int, block_slots: int = 128,
                     edge_align: int = 512) -> tuple[int, int]:
    """``(nb, Eb)`` the batched layout below would produce, computed
    WITHOUT materializing any layout arrays (one vectorized bincount).
    Lets byte accounting size the ELL encoding cheaply."""
    R, N, _ = edge_slot.shape
    nb = max(1, -(-num_slots // block_slots))
    valid = edge_w != 0.0
    cmax = 1
    if valid.any():
        shard = np.broadcast_to(
            np.arange(R * N).reshape(R, N, 1), edge_slot.shape)
        key = shard[valid] * nb + edge_slot[valid] // block_slots
        cmax = int(np.bincount(key).max())
    return nb, ell_width(cmax, edge_align)


def build_ell_layout_rounds(edge_repl: np.ndarray, edge_slot: np.ndarray,
                            edge_w: np.ndarray, num_slots: int,
                            block_slots: int = 128, edge_align: int = 512):
    """Batched :func:`build_ell_layout` over ``(R, N, E)`` plan arrays.

    Zero-weight COO entries are the planner's padding and are dropped
    before layout, then every (round, node) shard is padded back to ONE
    common ``(nb, Eb)`` shape (max over shards, aligned — see
    :func:`ell_layout_shape`) so the arrays can ride the same
    ``(R, *mesh_dims, ...)`` sharding as the rest of the plan. Returns
    ``(seg, rows, w)`` each shaped ``(R, N, nb, Eb)``.
    """
    R, N, _ = edge_repl.shape
    nb, Eb = ell_layout_shape(edge_slot, edge_w, num_slots, block_slots,
                              edge_align)
    seg = np.full((R, N, nb, Eb), -1, np.int32)
    rows = np.zeros((R, N, nb, Eb), np.int32)
    w = np.zeros((R, N, nb, Eb), np.float32)
    for r in range(R):
        for n in range(N):
            sel = np.flatnonzero(edge_w[r, n] != 0.0)
            seg[r, n], rows[r, n], w[r, n] = build_ell_layout(
                edge_repl[r, n][sel], edge_slot[r, n][sel],
                edge_w[r, n][sel], num_slots, block_slots, edge_align,
                width=Eb)
    return seg, rows, w


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _spmm_ell_diff(seg, msgs, block_slots, interpret):
    """:func:`kernel.spmm_ell` with a transposition rule.

    ``pallas_call`` has no built-in transpose, but the ELL spmm is
    LINEAR in ``msgs`` (``acc = Ind @ msgs`` for the 0/1 indicator
    matrix the kernel builds from ``seg``), so its VJP is the transposed
    indicator matmul ``Ind.T @ d_acc`` — :func:`kernel.spmm_ell_t`,
    itself a Pallas MXU kernel. This is what lets ``jax.grad``
    differentiate straight through the exchange executor's Compute step
    on the pallas backend (the training subsystem's backward pass)."""
    return _k.spmm_ell(seg, msgs, block_slots=block_slots,
                       interpret=interpret)


def _spmm_ell_fwd(seg, msgs, block_slots, interpret):
    # the only residual is the (integer, non-differentiated) layout
    return _spmm_ell_diff(seg, msgs, block_slots, interpret), seg


def _spmm_ell_bwd(block_slots, interpret, seg, d_acc):
    d_msgs = _k.spmm_ell_t(seg, d_acc, block_slots=block_slots,
                           interpret=interpret)
    return None, d_msgs  # seg is integer-valued: no cotangent


_spmm_ell_diff.defvjp(_spmm_ell_fwd, _spmm_ell_bwd)


@functools.partial(jax.jit, static_argnames=("num_slots", "block_slots",
                                             "impl"))
def aggregate(replica, seg, rows, weights, *, num_slots: int,
              block_slots: int = 128, impl: str = "auto"):
    """replica: (R, F). Returns (num_slots, F) aggregated accumulators.

    Differentiable in ``replica`` (and ``weights``): the gather/scale
    prologue is plain jnp, and the kernel itself carries a custom VJP
    (see :func:`_spmm_ell_diff`), so both aggregation backends support
    ``jax.grad`` with identical semantics."""
    nb, Eb = seg.shape
    msgs = replica[rows.reshape(-1)].reshape(nb, Eb, -1)
    msgs = msgs * weights[..., None].astype(msgs.dtype)
    if impl == "xla":
        acc = _ref.spmm_ell_ref(seg, msgs, block_slots)
    else:
        acc = _spmm_ell_diff(seg, msgs, block_slots, _use_interpret())
    return acc.reshape(nb * block_slots, -1)[:num_slots]
