"""Jit'd wrapper + layout builder for the aggregation SpMM kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.spmm import kernel as _k
from repro.kernels.spmm import ref as _ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def build_ell_layout(edge_repl: np.ndarray, edge_slot: np.ndarray,
                     edge_w: np.ndarray, num_slots: int,
                     block_slots: int = 128, edge_align: int = 512):
    """Host-side: sort COO edges by slot block and pad per block.

    Returns (seg (nb, Eb), gather_rows (nb, Eb), weights (nb, Eb)) where
    seg is the within-block slot index (-1 pad)."""
    nb = max(1, -(-num_slots // block_slots))
    blk = edge_slot // block_slots
    order = np.argsort(blk, kind="stable")
    counts = np.bincount(blk, minlength=nb)
    Eb = max(edge_align, -(-int(counts.max(initial=1)) // edge_align) * edge_align)
    seg = np.full((nb, Eb), -1, np.int32)
    rows = np.zeros((nb, Eb), np.int32)
    w = np.zeros((nb, Eb), np.float32)
    starts = np.zeros(nb + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    for b in range(nb):
        sel = order[starts[b]:starts[b + 1]]
        seg[b, :sel.size] = edge_slot[sel] - b * block_slots
        rows[b, :sel.size] = edge_repl[sel]
        w[b, :sel.size] = edge_w[sel]
    return seg, rows, w


@functools.partial(jax.jit, static_argnames=("num_slots", "block_slots",
                                             "impl"))
def aggregate(replica, seg, rows, weights, *, num_slots: int,
              block_slots: int = 128, impl: str = "auto"):
    """replica: (R, F). Returns (num_slots, F) aggregated accumulators."""
    nb, Eb = seg.shape
    msgs = replica[rows.reshape(-1)].reshape(nb, Eb, -1)
    msgs = msgs * weights[..., None].astype(msgs.dtype)
    if impl == "xla":
        acc = _ref.spmm_ell_ref(seg, msgs, block_slots)
    else:
        acc = _k.spmm_ell(seg, msgs, block_slots=block_slots,
                          interpret=_use_interpret())
    return acc.reshape(nb * block_slots, -1)[:num_slots]
