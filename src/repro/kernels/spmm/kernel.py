"""Pallas TPU kernel for GCN aggregation: weighted segment-sum of gathered
neighbor messages (the Aggregation engine of the paper's processing node).

TPU adaptation (see DESIGN.md): instead of a CUDA-style scatter-with-atomics
SpMM, aggregation is recast as a *block indicator matmul* so the MXU does
the reduction: edges are pre-sorted by destination slot and padded per slot
block; within a (slot_block, edge_block) tile the kernel builds the 0/w
indicator matrix ind[s, e] = w_e * [seg_e == s] with iota compares and
computes acc_block += ind @ messages — a dense (bs, be) x (be, bf) MXU
matmul. This is the VMEM/MXU-native form of the paper's 1x128 systolic
reduction rows.

Layout contract (established by ``ops.build_ell_layout`` — see that
module's docstring for the full invariant list):
  * edges arrive grouped by destination-slot block; ``seg`` is the slot
    index *within* the block, so the accumulator tile for one grid row
    is a dense ``(block_slots, block_feat)`` VMEM scratch that stays
    resident across all edge blocks (``acc_ref`` is initialized at the
    first edge block and flushed at the last — off-chip traffic is one
    message stream in, one accumulator tile out);
  * padding entries carry ``seg == -1``, which the iota compare maps to
    an all-zero indicator row (and their weight is already 0), so no
    masking pass is needed.

Inputs (built by ops.build_ell_layout from the COO edge lists in the
communication plan):
  messages: (n_slot_blocks, Eb, F)  gathered+weighted neighbor features
  seg:      (n_slot_blocks, Eb)     slot index within block, -1 = padding
Output:
  acc:      (n_slot_blocks * bs, F)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _divisor_at_most(n: int, k: int) -> int:
    """Largest divisor of ``n`` that is <= ``k`` (>= 1)."""
    k = max(1, min(k, n))
    while n % k:
        k -= 1
    return k


def _spmm_kernel(seg_ref, msg_ref, o_ref, acc_ref, *, block_slots,
                 block_edges):
    sb, fb, eb = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    ne = pl.num_programs(2)

    @pl.when(eb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seg = seg_ref[0]  # (be,)
    msg = msg_ref[0]  # (be, bf)
    slots = jax.lax.broadcasted_iota(jnp.int32, (block_slots, block_edges), 0)
    ind = (seg[None, :] == slots).astype(msg.dtype)  # (bs, be); -1 never hits
    acc_ref[...] += jax.lax.dot(ind, msg).astype(jnp.float32)

    @pl.when(eb == ne - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def spmm_ell(seg, messages, *, block_slots: int = 128,
             block_edges: int = 512, block_feat: int = 128,
             interpret: bool = False):
    """seg: (nb, Eb) int32 (-1 pad); messages: (nb, Eb, F).
    Returns acc (nb, block_slots, F) — caller reshapes to (slots, F).

    ``block_edges`` / ``block_feat`` are clamped to the LARGEST divisor
    of Eb / F that does not exceed the request, so any padded layout
    tiles evenly without collapsing to degenerate tile sizes (a gcd
    would, e.g. gcd(1022, 512) == 2)."""
    nb, Eb, F = messages.shape
    block_edges = _divisor_at_most(Eb, block_edges)
    block_feat = _divisor_at_most(F, block_feat)
    ne = Eb // block_edges
    nf = F // block_feat

    kernel = functools.partial(_spmm_kernel, block_slots=block_slots,
                               block_edges=block_edges)
    return pl.pallas_call(
        kernel,
        grid=(nb, nf, ne),
        in_specs=[
            pl.BlockSpec((1, block_edges), lambda b, f, e: (b, e)),
            pl.BlockSpec((1, block_edges, block_feat),
                         lambda b, f, e: (b, e, f)),
        ],
        out_specs=pl.BlockSpec((1, block_slots, block_feat),
                               lambda b, f, e: (b, 0, f)),
        out_shape=jax.ShapeDtypeStruct((nb, block_slots, F), messages.dtype),
        scratch_shapes=[pltpu.VMEM((block_slots, block_feat), jnp.float32)],
        interpret=interpret,
    )(seg, messages)


def _spmm_t_kernel(seg_ref, dacc_ref, o_ref, *, block_slots, block_edges):
    seg = seg_ref[0]  # (be,)
    dacc = dacc_ref[0]  # (bs, bf)
    slots = jax.lax.broadcasted_iota(jnp.int32, (block_slots, block_edges), 0)
    ind = (seg[None, :] == slots).astype(dacc.dtype)  # (bs, be); -1 never hits
    o_ref[0] = jax.lax.dot(ind.T, dacc).astype(o_ref.dtype)


def spmm_ell_t(seg, dacc, *, block_slots: int = 128,
               block_edges: int = 512, block_feat: int = 128,
               interpret: bool = False):
    """Transpose of :func:`spmm_ell` in its (linear) ``messages`` input:
    scatter an accumulator cotangent back onto the edge stream.

    dacc: (nb, block_slots, F); returns d_messages (nb, Eb, F) where
    ``d_messages[b, e] = dacc[b, seg[b, e]]`` (zero for ``seg == -1``
    padding). Same indicator-matmul trick as the forward, contracted
    the other way — ``ind.T @ dacc`` is a dense (be, bs) x (bs, bf) MXU
    matmul per tile, so the backward pass of the aggregation stays on
    the systolic array. No scratch accumulator is needed: the slot
    dimension is fully contracted within one grid cell, so each
    (edge block, feat block) tile is written exactly once."""
    nb, Eb = seg.shape
    F = dacc.shape[-1]
    block_edges = _divisor_at_most(Eb, block_edges)
    block_feat = _divisor_at_most(F, block_feat)

    kernel = functools.partial(_spmm_t_kernel, block_slots=block_slots,
                               block_edges=block_edges)
    return pl.pallas_call(
        kernel,
        grid=(nb, F // block_feat, Eb // block_edges),
        in_specs=[
            pl.BlockSpec((1, block_edges), lambda b, f, e: (b, e)),
            pl.BlockSpec((1, block_slots, block_feat),
                         lambda b, f, e: (b, 0, f)),
        ],
        out_specs=pl.BlockSpec((1, block_edges, block_feat),
                               lambda b, f, e: (b, e, f)),
        out_shape=jax.ShapeDtypeStruct((nb, Eb, F), dacc.dtype),
        interpret=interpret,
    )(seg, dacc)
