"""Pallas TPU fused matmul kernel: Y = act(X @ W + b).

Used for the GCN Combination phase (the paper's systolic-array MLP) and as
the building block the LM stack's hot matmuls map onto on real TPUs.
Canonical tiling: grid (M/bm, N/bn, K/bk), K innermost, f32 VMEM
accumulator, activation fused into the final K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, act):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[...], w_ref[...],
        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if act == "relu":
            y = jnp.maximum(y, 0.0)
        elif act == "gelu":
            y = jax.nn.gelu(y)
        elif act == "silu":
            y = y * jax.nn.sigmoid(y)
        o_ref[...] = y.astype(o_ref.dtype)


def matmul_fused(x, w, b=None, *, act: str = "none", block_m: int = 128,
                 block_n: int = 128, block_k: int = 512,
                 interpret: bool = False):
    """x: (M, K); w: (K, N); b: (N,) -> act(x @ w + b) (M, N)."""
    M, K = x.shape
    _, N = w.shape
    if b is None:
        b = jnp.zeros((N,), x.dtype)
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0

    kernel = functools.partial(_mm_kernel, act=act)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, N // block_n, K // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_n,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w, b)
