"""Jit'd wrapper for the fused matmul kernel with platform dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels.matmul import kernel as _k
from repro.kernels.matmul import ref as _ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("act", "impl"))
def matmul_fused(x, w, b=None, *, act: str = "none", impl: str = "auto"):
    if impl == "xla":
        return _ref.matmul_fused_ref(x, w, b, act=act)
    return _k.matmul_fused(x, w, b, act=act, interpret=_use_interpret())
