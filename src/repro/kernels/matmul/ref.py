"""Pure-jnp oracle for the fused matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_fused_ref(x, w, b=None, *, act: str = "none"):
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        y = y + b.astype(jnp.float32)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "gelu":
        y = jax.nn.gelu(y)
    elif act == "silu":
        y = y * jax.nn.sigmoid(y)
    return y.astype(x.dtype)
