"""Pure-jnp oracle for flash attention (naive masked softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, scale: float, causal: bool = True,
                  window: int = 0, q_offset: int = 0):
    """q: (B, Hkv, G, Sq, D); k, v: (B, Hkv, Skv, D) -> (B, Hkv, G, Sq, D).

    Materializes the full score matrix — oracle only."""
    B, Hkv, G, Sq, D = q.shape
    Skv = k.shape[2]
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    rows = q_offset + jnp.arange(Sq)[:, None]
    cols = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
