"""Pallas TPU flash-attention forward kernel.

Grid: (batch*kv_heads, num_q_blocks, num_kv_blocks) — the kv dimension is
innermost so the online-softmax state for one q block lives in VMEM
scratch across kv iterations (canonical TPU flash pattern). GQA folds the
q-head group into the q block rows so the MXU sees (G*bq, D) x (D, bk)
matmuls.

Causal/sliding-window masking is applied in-kernel; fully-masked kv blocks
are skipped by the index-map-free @pl.when guard (they still iterate but
do no FLOPs on the MXU path — the XLA fallback in repro.nn.attention skips
them structurally instead; both are validated against ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref, *,
               scale, causal, window, block_q, block_k, q_offset, seq_kv):
    bh, qi, ki = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]  # (G*bq, D)
    k = k_ref[0]  # (bk, D)
    v = v_ref[0]
    G_bq, D = q.shape
    bk = k.shape[0]
    G = G_bq // block_q

    s = jax.lax.dot_general(q.astype(jnp.float32), k.astype(jnp.float32),
                            (((1,), (1,)), ((), ()))) * scale  # (G*bq, bk)

    # absolute row/col positions: q rows repeat per group member
    row_in_blk = jax.lax.broadcasted_iota(jnp.int32, (G_bq, bk), 0) % block_q
    rows = q_offset + qi * block_q + row_in_blk
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (G_bq, bk), 1)
    mask = cols < seq_kv
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p.astype(v.dtype), v).astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[...] + jnp.log(l)


def flash_attention_fwd(q, k, v, *, scale: float, causal: bool = True,
                        window: int = 0, q_offset: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q: (B, Hkv, G, Sq, D); k, v: (B, Hkv, Skv, D).

    Returns (out (B, Hkv, G, Sq, D), lse (B, Hkv, G, Sq))."""
    B, Hkv, G, Sq, D = q.shape
    Skv = k.shape[2]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    nq, nk = Sq // block_q, Skv // block_k

    # fold (B, Hkv) and (G, bq): q view (B*Hkv, nq, G*bq, D)
    qf = q.transpose(0, 1, 3, 2, 4).reshape(B * Hkv, Sq, G, D)
    # block rows: group-major within a q block -> (G*bq, D)
    qf = qf.reshape(B * Hkv, nq, block_q, G, D).transpose(0, 1, 3, 2, 4) \
        .reshape(B * Hkv, nq, G * block_q, D)
    kf = k.reshape(B * Hkv, Skv, D)
    vf = v.reshape(B * Hkv, Skv, D)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, q_offset=q_offset, seq_kv=Skv)

    out, lse = pl.pallas_call(
        kernel,
        grid=(B * Hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G * block_q, D), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G * block_q, D), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, 1, G * block_q), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hkv, nq, G * block_q, D), q.dtype),
            jax.ShapeDtypeStruct((B * Hkv, nq, G * block_q), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G * block_q, D), jnp.float32),
            pltpu.VMEM((G * block_q,), jnp.float32),
            pltpu.VMEM((G * block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)

    # unfold back to (B, Hkv, G, Sq, D)
    out = out.reshape(B * Hkv, nq, G, block_q, D).transpose(0, 1, 3, 2, 4) \
        .reshape(B, Hkv, Sq, G, D).transpose(0, 1, 3, 2, 4)
    lse = lse.reshape(B * Hkv, nq, G, block_q).transpose(0, 1, 3, 2) \
        .reshape(B, Hkv, Sq, G).transpose(0, 1, 3, 2)
    return out, lse
