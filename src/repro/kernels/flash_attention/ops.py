"""Jit'd wrapper for the flash-attention kernel with platform dispatch.

``flash_attention`` takes the model-layout tensors used by
``repro.nn.attention`` (q: (B, Sq, Hkv, G, D); k/v: (B, Skv, Hkv, D)),
runs the Pallas kernel on TPU (interpret-mode elsewhere), and provides a
custom VJP whose backward is the blockwise XLA flash backward from
``repro.nn.attention`` (identical math; kernelizing the backward is a
listed follow-up, not a correctness gap).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _k


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, scale: float, causal: bool = True,
                    window: int = 0, q_offset: int = 0):
    """q: (B, Sq, Hkv, G, D); k, v: (B, Skv, Hkv, D) -> (B, Sq, Hkv, G, D)."""
    out, _ = _fwd_impl(q, k, v, scale, causal, window, q_offset)
    return out


def _fwd_impl(q, k, v, scale, causal, window, q_offset):
    # kernel layout: (B, Hkv, G, Sq, D) / (B, Hkv, Skv, D)
    qk = q.transpose(0, 2, 3, 1, 4)
    kk = k.transpose(0, 2, 1, 3)
    vk = v.transpose(0, 2, 1, 3)
    out, lse = _k.flash_attention_fwd(
        qk, kk, vk, scale=scale, causal=causal, window=window,
        q_offset=q_offset, interpret=_use_interpret())
    return out.transpose(0, 3, 1, 2, 4), lse


def _fa_fwd(q, k, v, scale, causal, window, q_offset):
    out, lse = _fwd_impl(q, k, v, scale, causal, window, q_offset)
    # lse layout from kernel: (B, Hkv, G, Sq) -> attention.py's (B,Sq,Hkv,G)
    lse_m = lse.transpose(0, 3, 1, 2)
    return out, (q, k, v, out, lse_m)


def _fa_bwd(scale, causal, window, q_offset, res, dout):
    from repro.nn import attention as xattn

    q, k, v, out, lse = res
    Sq, Skv = q.shape[1], k.shape[1]
    q_chunk = min(512, Sq)
    kv_chunk = min(512, Skv)
    return xattn._bw_attn_bwd(scale, causal, window, q_chunk, kv_chunk,
                              q_offset, (q, k, v, out, lse), dout)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
