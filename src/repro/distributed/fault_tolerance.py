"""Fault tolerance for long-running multi-pod jobs.

Pieces (each independently testable on CPU):

* PreemptionGuard — SIGTERM/SIGINT handler that flips a flag; the train
  loop checkpoints and exits cleanly at the next step boundary (standard
  TPU-preemption protocol).
* HeartbeatMonitor — per-host heartbeat files + stale-host detection; on a
  real cluster this feeds the controller that shrinks the mesh (elastic
  restart); here it drives the elastic-resume test.
* elastic_resume — restore a checkpoint written on any mesh onto the
  current mesh (delegates to checkpoint.restore's reshard-on-load), then
  re-lower the step: this is the restart path after a node failure with a
  different healthy-device count.
* StragglerPolicy — bounded-staleness data handling: the prefetch queue
  plus a deadline; a host that misses the deadline reuses its previous
  batch (documented bounded-staleness semantics) instead of stalling the
  collective.
"""
from __future__ import annotations

import os
import signal
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._flag = threading.Event()
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:
                pass  # non-main thread (tests)
        return self

    def _handler(self, signum, frame):
        self._flag.set()

    @property
    def should_stop(self) -> bool:
        return self._flag.is_set()

    def request_stop(self):
        self._flag.set()

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False


@dataclass
class HeartbeatMonitor:
    dir: Path
    host_id: int
    stale_after_s: float = 30.0

    def __post_init__(self):
        self.dir = Path(self.dir)
        self.dir.mkdir(parents=True, exist_ok=True)

    def beat(self):
        p = self.dir / f"host_{self.host_id}"
        p.write_text(str(time.time()))

    def stale_hosts(self) -> list[int]:
        now = time.time()
        out = []
        for p in self.dir.glob("host_*"):
            try:
                t = float(p.read_text())
            except ValueError:
                t = 0.0
            if now - t > self.stale_after_s:
                out.append(int(p.name.split("_")[1]))
        return sorted(out)


def elastic_resume(ckpt_dir, like_tree, mesh, specs):
    """Restore latest checkpoint onto the CURRENT mesh (any device count).

    Returns (tree_on_mesh, step). Raises FileNotFoundError when there is
    nothing to resume from (fresh start)."""
    from repro.checkpoint import ckpt

    return ckpt.restore(ckpt_dir, like_tree, mesh=mesh, specs=specs)


@dataclass
class StragglerPolicy:
    """Bounded-staleness batch fetch: never stall the collective on a slow
    data host; reuse the last batch after ``deadline_s``."""

    deadline_s: float = 5.0
    _last_batch: dict | None = field(default=None, repr=False)
    reused: int = 0

    def fetch(self, q) -> tuple[int, dict] | None:
        import queue as _q

        try:
            step, batch = q.get(timeout=self.deadline_s)
            self._last_batch = (step, batch)
            return step, batch
        except _q.Empty:
            if self._last_batch is None:
                raise TimeoutError("no batch ever produced")
            self.reused += 1
            return self._last_batch
