from . import compression, fault_tolerance

__all__ = ["compression", "fault_tolerance"]
