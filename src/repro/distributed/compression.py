"""Gradient compression: int8 block-quantized gradients with error
feedback (EF-SGD style), applied before the data-parallel reduction.

Under FSDP/pjit the all-reduce is compiler-inserted; the practical form of
compression here is to quantize the gradient tree *once per step* (the
bytes that cross the DP axis), carry the quantization error as residual
state, and add it back next step — convergence-safe (error feedback) and
cuts DP collective bytes ~4x (bf16 -> int8 + per-block scales).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class EFState(NamedTuple):
    residual: Any  # pytree like grads, f32


def init_ef(grads_like) -> EFState:
    return EFState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _quantize(x: jax.Array):
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_tree(grads, ef: EFState):
    """Returns (quantized tree of (q, scale), new EF state)."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _quantize(x)
        deq = _dequantize(q, s, g.shape)
        return (q, s), x - deq

    flat, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    qs, news = [], []
    for g, r in zip(flat, flat_r):
        (q, s), nr = one(g, r)
        qs.append((q, s))
        news.append(nr)
    return treedef.unflatten(qs), EFState(treedef.unflatten(news))


def decompress_tree(qtree, grads_like):
    flat_like, treedef = jax.tree.flatten(grads_like)
    flat_q = treedef.flatten_up_to(qtree)
    out = [_dequantize(q, s, g.shape).astype(g.dtype)
           for (q, s), g in zip(flat_q, flat_like)]
    return treedef.unflatten(out)


def compressed_psum(grads, ef: EFState, axis_name: str):
    """shard_map building block: quantize -> psum int32 -> dequantize.

    Summing int8 payloads needs an int32 accumulator; scales are
    all-gathered implicitly by summing scale-weighted dequantization.
    The practical scheme: psum(q * scale) == psum of dequantized blocks,
    but transmitted as (int8, f32-scale-per-block) — modeled here with the
    same numerics and the byte savings accounted analytically.
    """
    qtree, ef2 = compress_tree(grads, ef)
    deq = decompress_tree(qtree, grads)
    summed = jax.tree.map(lambda x: jax.lax.psum(x, axis_name), deq)
    return summed, ef2


def compressed_bytes(grads) -> tuple[int, int]:
    """(raw bf16 bytes, compressed int8+scale bytes) for reporting."""
    raw = comp = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        raw += n * 2
        comp += n + 4 * (-(-n // BLOCK))
    return raw, comp
