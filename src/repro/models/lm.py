"""Top-level language models: parameter construction, forward, chunked
vocab-parallel loss, prefill and decode steps — for all 10 assigned
architectures (dense / MoE / SSM / hybrid / enc-dec / VLM-backbone).

All entry points work both with concrete arrays (smoke tests, examples)
and with ``jax.eval_shape``-style abstract values (the multi-pod dry-run).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import LMConfig
from repro.nn import transformer as tfm
from repro.nn.layers import (
    embedding_apply,
    embedding_defs,
    lm_head_defs,
    lm_head_matrix,
    norm_apply,
    norm_defs,
    sinusoidal_positions,
)
from repro.nn.module import abstract_tree, init_tree, shard, spec_tree


# ---------------------------------------------------------------------------
# Parameter tree
# ---------------------------------------------------------------------------


def lm_defs(cfg: LMConfig):
    seg_defs, segs = tfm.stack_defs(cfg, cross=cfg.is_encdec)
    defs: dict[str, Any] = {
        "embed": embedding_defs(cfg),
        "segments": seg_defs,
        "final_norm": norm_defs(cfg),
        "head": lm_head_defs(cfg),
    }
    if any(b.shared_attn for b in cfg.blocks):
        defs["shared_attn"] = tfm.shared_attn_defs(cfg)
    if cfg.is_encdec:
        enc_cfg = _encoder_cfg(cfg)
        enc_segs, enc_layout = tfm.stack_defs(enc_cfg)
        defs["encoder"] = {"segments": enc_segs, "final_norm": norm_defs(cfg)}
    return defs, segs


def _encoder_cfg(cfg: LMConfig) -> LMConfig:
    import dataclasses

    return dataclasses.replace(
        cfg, num_layers=cfg.encoder_layers, blocks=(), encoder_layers=0,
        default_mixer="gqa", default_ffn="dense", frontend="none")


def lm_init(cfg: LMConfig, key: jax.Array):
    defs, _ = lm_defs(cfg)
    return init_tree(defs, key)


def lm_abstract(cfg: LMConfig):
    defs, _ = lm_defs(cfg)
    return abstract_tree(defs)


def lm_specs(cfg: LMConfig, rules):
    defs, _ = lm_defs(cfg)
    return spec_tree(defs, rules)


def lm_segments(cfg: LMConfig):
    return tfm.segment_layout(cfg)


# ---------------------------------------------------------------------------
# Forward (hidden states)
# ---------------------------------------------------------------------------


def encode(cfg: LMConfig, params, frames, rules=None, remat=True):
    """Whisper encoder over precomputed frame embeddings (audio stub)."""
    enc_cfg = _encoder_cfg(cfg)
    S = frames.shape[1]
    x = frames + sinusoidal_positions(S, cfg.d_model)[None].astype(frames.dtype)
    segs = tfm.segment_layout(enc_cfg)
    x, _, _ = tfm.stack_apply(enc_cfg, segs, params["encoder"]["segments"], x,
                              positions=jnp.arange(S)[None], rules=rules,
                              causal=False, remat=remat)
    return norm_apply(params["encoder"]["final_norm"], x)


def forward_hidden(cfg: LMConfig, params, tokens, *, extra_embeds=None,
                   memory=None, rules=None, impl="auto", remat=True,
                   caches=None, pos=None, positions=None):
    """tokens: (B, S_text) -> hidden (B, S, D), new_caches, aux.

    extra_embeds: (B, S_front, D) precomputed modality embeddings (VLM/audio
    stubs) prepended to the token embeddings.
    """
    segs = tfm.segment_layout(cfg)
    x = embedding_apply(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        start = 0 if pos is None else pos
        positions = (jnp.arange(S, dtype=jnp.int32) + start)[None]
    if cfg.is_encdec and cfg.rope_theta <= 0:
        # whisper: learned-position stand-in = sinusoidal added to embeddings,
        # indexed by absolute position (prefill: 0..S-1; decode: pos)
        table_len = 65536
        sp = sinusoidal_positions(table_len, cfg.d_model).astype(x.dtype)
        idx = jnp.minimum(positions, table_len - 1)
        x = x + jnp.take(sp, idx, axis=0)  # (1,S,D) or (B,1,D), broadcasts
    if rules is not None:
        x = shard(x, rules, "act_batch", "act_seq", "act_embed")

    shared = params.get("shared_attn")
    x, new_caches, aux = tfm.stack_apply(
        cfg, segs, params["segments"], x, positions=positions, rules=rules,
        caches=caches, pos=pos, shared_params=shared, impl=impl, remat=remat,
        memory=memory)
    x = norm_apply(params["final_norm"], x)
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# Chunked vocab-parallel cross entropy
# ---------------------------------------------------------------------------


def chunked_xent(cfg: LMConfig, params, hidden, labels, *, chunk: int = 512,
                 rules=None):
    """Never materializes (B, S, V) logits: scans sequence chunks.

    labels: (B, S) int32, -1 = masked (e.g. image positions in VLM).
    Returns (mean_nll, token_count).
    """
    W = lm_head_matrix(params.get("head", {}), params["embed"], cfg)
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)
    h = hidden.reshape(B, nc, chunk, D)
    y = labels.reshape(B, nc, chunk)

    def body(carry, inp):
        nll_sum, cnt = carry
        hc, yc = inp  # (B, chunk, D), (B, chunk)
        logits = (hc @ W.astype(hc.dtype)).astype(jnp.float32)  # (B, chunk, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(jnp.maximum(yc, 0), cfg.vocab_size,
                                dtype=logits.dtype)
        ll = jnp.sum(logits * onehot, axis=-1)
        w = (yc >= 0).astype(jnp.float32)
        nll_sum = nll_sum + jnp.sum((logz - ll) * w)
        cnt = cnt + jnp.sum(w)
        return (nll_sum, cnt), None

    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (jnp.moveaxis(h, 1, 0), jnp.moveaxis(y, 1, 0)))
    return nll / jnp.maximum(cnt, 1.0), cnt


# ---------------------------------------------------------------------------
# Steps: train loss, prefill, decode
# ---------------------------------------------------------------------------


def lm_loss(cfg: LMConfig, params, batch, *, rules=None, impl="auto",
            remat=True, aux_weight: float = 0.01):
    """batch: dict(tokens (B,S), labels (B,S) [, frames/patches (B,F,D)])."""
    memory = None
    extra = None
    if cfg.is_encdec:
        memory = encode(cfg, params, batch["frames"], rules=rules, remat=remat)
    elif cfg.frontend == "patch_stub":
        extra = batch["patches"]

    hidden, _, aux = forward_hidden(cfg, params, batch["tokens"],
                                    extra_embeds=extra, memory=memory,
                                    rules=rules, impl=impl, remat=remat)
    labels = batch["labels"]
    if extra is not None:  # image positions carry no next-token loss
        pad = jnp.full(extra.shape[:2], -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    nll, cnt = chunked_xent(cfg, params, hidden, labels, rules=rules)
    return nll + aux_weight * aux, {"nll": nll, "aux": aux, "tokens": cnt}


class DecodeState(NamedTuple):
    caches: Any
    pos: jax.Array  # () int32 — tokens already cached
    memory: Any = None  # enc-dec cross memory


def init_decode_state(cfg: LMConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, memory=None):
    segs = tfm.segment_layout(cfg)
    caches = tfm.stack_cache(cfg, segs, batch, max_len, dtype)
    return DecodeState(caches=caches, pos=jnp.zeros((), jnp.int32),
                       memory=memory)


def prefill(cfg: LMConfig, params, tokens, state: DecodeState, *, rules=None,
            impl="auto", extra_embeds=None):
    """Run the prompt through the stack, filling caches. Returns
    (last_hidden (B, D), new state)."""
    hidden, caches, _ = forward_hidden(
        cfg, params, tokens, rules=rules, impl=impl, remat=False,
        caches=state.caches, pos=state.pos, memory=state.memory,
        extra_embeds=extra_embeds)
    new_len = tokens.shape[1] + (extra_embeds.shape[1] if extra_embeds is not None else 0)
    return hidden[:, -1], DecodeState(caches, state.pos + new_len,
                                      state.memory)


def decode_step(cfg: LMConfig, params, token, state: DecodeState, *,
                rules=None, impl="auto"):
    """token: (B, 1) -> (logits (B, V), new state). One-token serve step."""
    hidden, caches, _ = forward_hidden(
        cfg, params, token, rules=rules, impl=impl, remat=False,
        caches=state.caches, pos=state.pos, memory=state.memory)
    W = lm_head_matrix(params.get("head", {}), params["embed"], cfg)
    logits = (hidden[:, -1] @ W.astype(hidden.dtype)).astype(jnp.float32)
    if rules is not None:
        logits = shard(logits, rules, "act_batch", "act_vocab")
    return logits, DecodeState(caches, state.pos + 1, state.memory)
