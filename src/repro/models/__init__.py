from . import lm

__all__ = ["lm"]
