"""SPMD executor for MultiGCN communication plans.

Runs inside ``jax.shard_map`` over the torus mesh axes and replays the
static relay schedule from ``repro.core.plan``:

  for each round (lax.scan):                       # SREM
    obuf_0 <- gather(local features, orig_rows)    # Load & Send (Alg. 3 (2))
    for each torus dim k:                          # TMM multicast
      local h=0 turns: obuf_k -> obuf_{k+1}
      for h in 1..dim_k-1:
        send prefix L_h one hop (+1 ring ppermute) # one put per multicast
        masked-deposit received rows into obuf_{k+1} (or replica buffer)
    aggregate replica buffer via the edge COO      # Compute (Alg. 3 (4))

The per-round replica buffer is the paper's aggregation buffer: it lives
for exactly one round (on-chip residency by construction), and the edge
COO is the paper's edge buffer. Synchronization (Alg. 3 (5)) is the SPMD
barrier at the scan-carry boundary.

The Compute step (4) has two interchangeable backends, selected by
``ExchangeStatics.agg_impl``:

  * ``"jnp"``    — COO ``at[].add`` scatter (portable XLA path);
  * ``"pallas"`` — the blocked-ELL indicator-matmul kernel in
    :mod:`repro.kernels.spmm` (interpret mode off-TPU). The host-side
    ELL tensors ride in the plan-array tree (``ell_seg/ell_rows/ell_w``
    REPLACING the COO ``edge_*`` arrays, so only one encoding is ever
    uploaded) and are scanned/sharded exactly like the rest of the plan.

Differentiability: the whole exchange is LINEAR in ``feats`` (gathers,
masked deposits, ppermutes and weighted segment-sums), so its VJP is a
reversed relay replay — every ``ppermute`` transposes to the inverse
ring permutation and every deposit to a gather, all derived
automatically by jax (the pallas Compute step carries an explicit
transpose kernel, ``kernels.spmm.ops._spmm_ell_diff``). The training
subsystem (:mod:`repro.gcn.train`) relies on ``jax.grad`` composing
through this module for BOTH aggregation backends; the properties are
pinned by ``tests/test_gcn_train.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import CommPlan


def plan_device_arrays(plan: CommPlan, ell=None) -> dict[str, Any]:
    """Plan arrays, reshaped so axis 1.. are the mesh dims (shardable).

    ``ell`` is an optional ``(seg, rows, w)`` triple of ``(R, N, nb, Eb)``
    blocked-ELL tensors (see ``repro.kernels.spmm.ops``); when given they
    REPLACE the COO ``edge_*`` arrays — the two encodings carry the same
    aggregation edge list, so uploading both would double the plan's
    device footprint for no consumer.
    """
    dims = plan.mesh.dims
    R = plan.num_rounds

    def rs(a):  # (R, N, ...) -> (R, *dims, ...)
        return jnp.asarray(a.reshape((R,) + tuple(dims) + a.shape[2:]))

    out = {
        "orig_rows": rs(plan.orig_rows),
        "orig_valid": rs(plan.orig_valid),
        "repl_lc_src": rs(plan.repl_lc_src),
        "repl_lc_dst": rs(plan.repl_lc_dst),
        "repl_lc_valid": rs(plan.repl_lc_valid),
        "phases": [],
    }
    if ell is None:
        out.update(edge_repl=rs(plan.edge_repl),
                   edge_slot=rs(plan.edge_slot),
                   edge_w=rs(plan.edge_w))
    else:
        seg, rows, w = ell
        out.update(ell_seg=rs(seg), ell_rows=rs(rows), ell_w=rs(w))
    for ph in plan.phases:
        d = {
            "dep": rs(ph.dep),
            "dep_slot": rs(ph.dep_slot),
            "lc_src": rs(ph.lc_src),
            "lc_dst": rs(ph.lc_dst),
            "lc_valid": rs(ph.lc_valid),
        }
        if ph.hop_len_rev:
            d["dep_rev"] = rs(ph.dep_rev)
            d["dep_slot_rev"] = rs(ph.dep_slot_rev)
        if ph.dup is not None:
            d["dup_src"] = rs(ph.dup[0])
            d["dup_dst"] = rs(ph.dup[1])
            d["dup_valid"] = rs(ph.dup[2])
        out["phases"].append(d)
    return out


@dataclass(frozen=True)
class ExchangeStatics:
    """Static (python) metadata the executor needs alongside the arrays.

    ``agg_impl`` selects the Compute-step backend ("jnp" | "pallas");
    with "pallas" the plan-array tree must carry the ELL tensors (pass
    ``ell=`` to :func:`plan_device_arrays`) and ``ell_block_slots`` must
    match the layout's slot-block height."""

    axis_names: tuple[str, ...]
    dims: tuple[int, ...]
    caps: tuple[int, ...]
    caps_fwd: tuple[int, ...]
    hop_lens: tuple[tuple[int, ...], ...]
    hop_lens_rev: tuple[tuple[int, ...], ...]
    replica_rows: int
    slots_per_round: int
    num_rounds: int
    agg_impl: str = "jnp"
    ell_block_slots: int = 128


def exchange_statics(plan: CommPlan, axis_names, *, agg_impl: str = "jnp",
                     ell_block_slots: int = 128) -> ExchangeStatics:
    return ExchangeStatics(
        axis_names=tuple(axis_names),
        dims=tuple(plan.mesh.dims),
        caps=tuple(ph.capacity for ph in plan.phases),
        caps_fwd=tuple(ph.cap_fwd or ph.capacity for ph in plan.phases),
        hop_lens=tuple(tuple(ph.hop_len) for ph in plan.phases),
        hop_lens_rev=tuple(tuple(ph.hop_len_rev) for ph in plan.phases),
        replica_rows=plan.replica_rows,
        slots_per_round=plan.part.slots_per_round,
        num_rounds=plan.num_rounds,
        agg_impl=agg_impl,
        ell_block_slots=ell_block_slots,
    )


def _squeeze_mesh(a, ndim_mesh):
    # inside shard_map the per-device block has size-1 mesh dims at axes 1..
    return a.reshape((a.shape[0],) + a.shape[1 + ndim_mesh:])


def exchange_and_aggregate(st: ExchangeStatics, plan_dev, feats):
    """Per-device body (call inside shard_map).

    feats: (1, 1, ..., Vp, F) this node's feature table block.
    Returns acc: (num_rounds, slots_per_round, F) aggregated features.
    """
    nd = len(st.dims)
    F = feats.shape[-1]
    feats = feats.reshape(feats.shape[-2], F)
    dtype = feats.dtype

    pdev = jax.tree.map(lambda a: _squeeze_mesh(a, nd), plan_dev,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray))

    def round_body(_, pr):
        # (2) Load & Send: phase-0 origination buffer
        obuf = feats[pr["orig_rows"]] * pr["orig_valid"][:, None].astype(dtype)
        replica = jnp.zeros((st.replica_rows, F), dtype)
        # local source vertices copied straight into the aggregation buffer
        lval = pr["repl_lc_valid"][:, None].astype(dtype)
        replica = replica.at[pr["repl_lc_dst"]].add(
            feats[pr["repl_lc_src"]] * lval)

        # (3) Receive / multicast relay per torus dimension
        for k in range(nd):
            phase = pr["phases"][k]
            is_last = k == nd - 1
            # direction-split duplication (bidir plans, phases k >= 1)
            if "dup_src" in phase:
                dv = phase["dup_valid"][:, None].astype(dtype)
                obuf = obuf.at[phase["dup_dst"]].add(obuf[phase["dup_src"]] * dv)
            nxt = replica if is_last else jnp.zeros((st.caps[k + 1], F), dtype)
            # h = 0 turns
            v = phase["lc_valid"][:, None].astype(dtype)
            nxt = nxt.at[phase["lc_dst"]].add(obuf[phase["lc_src"]] * v)
            # +1 ring relay (forward section = buffer prefix)
            buf = obuf
            for h, L in enumerate(st.hop_lens[k], start=1):
                if L == 0:
                    break
                buf = jax.lax.ppermute(
                    buf[:L], st.axis_names[k],
                    [(i, (i + 1) % st.dims[k]) for i in range(st.dims[k])])
                m = phase["dep"][h - 1, :L][:, None].astype(dtype)
                nxt = nxt.at[phase["dep_slot"][h - 1, :L]].add(buf * m)
            # -1 ring relay (backward section, bidir plans)
            if st.hop_lens_rev[k]:
                buf = obuf[st.caps_fwd[k]:]
                for h, L in enumerate(st.hop_lens_rev[k], start=1):
                    if L == 0:
                        break
                    buf = jax.lax.ppermute(
                        buf[:L], st.axis_names[k],
                        [(i, (i - 1) % st.dims[k]) for i in range(st.dims[k])])
                    m = phase["dep_rev"][h - 1, :L][:, None].astype(dtype)
                    nxt = nxt.at[phase["dep_slot_rev"][h - 1, :L]].add(buf * m)
            if is_last:
                replica = nxt
            else:
                obuf = nxt

        # (4) Compute: segment-sum into per-round accumulators, via the
        # selected aggregation backend
        if st.agg_impl == "pallas":
            from repro.kernels.spmm import ops as spmm_ops

            acc = spmm_ops.aggregate(
                replica, pr["ell_seg"], pr["ell_rows"], pr["ell_w"],
                num_slots=st.slots_per_round,
                block_slots=st.ell_block_slots)
        else:
            gathered = (replica[pr["edge_repl"]]
                        * pr["edge_w"][:, None].astype(dtype))
            acc = jnp.zeros((st.slots_per_round, F), dtype)
            acc = acc.at[pr["edge_slot"]].add(gathered)
        return _, acc

    _, accs = jax.lax.scan(round_body, None, pdev)
    return accs  # (R, slots, F)


def shard_node_values(plan: CommPlan, values: np.ndarray,
                      fill=0) -> np.ndarray:
    """(V,) or (V, K) per-vertex host values -> (*dims, Vp[, K]) in the
    same node-major layout as :func:`shard_features`; the SPMD padding
    slots (``Vp * N >= V``) are set to ``fill``.

    This is how the training subsystem lands labels (int) and loss
    masks (float; pass the mask with ``fill=0`` so padded slots never
    contribute to the loss) on the same partition as the features."""
    part = plan.part
    values = np.asarray(values)
    V = values.shape[0]
    Vp = part.vertices_per_node()
    out = np.full((plan.num_nodes, Vp) + values.shape[1:], fill,
                  values.dtype)
    v = np.arange(V)
    out[part.node_of(v), part.local_index(v)] = values
    return out.reshape(tuple(plan.mesh.dims) + (Vp,) + values.shape[1:])


def shard_features(plan: CommPlan, feats_global: np.ndarray) -> np.ndarray:
    """(V, F) global features -> (*dims, Vp, F) node-major layout."""
    return shard_node_values(plan, feats_global, fill=0)


def scatter_rows_sharded(plan: CommPlan, rows: np.ndarray,
                         index: np.ndarray | None = None) -> np.ndarray:
    """Sparse per-vertex rows -> the full sharded ``(*dims, Vp, F)``
    table, zero everywhere else. ``rows`` is ``(S, F)``; ``index``
    (default ``arange(S)``) gives each row's global vertex id.

    The exchange executor is *linear* per feature column, so any
    constant additive offset to the aggregation — the control-variate
    history term ``repro.gcn.train`` adds per layer — composes OUTSIDE
    the exchange: the offset is scattered into this layout host-side
    and added to the exchanged accumulators on device, which keeps the
    exchange's custom_vjp untouched (the backward pass sees the offset
    as a constant and moves not one extra ppermute byte)."""
    rows = np.asarray(rows)
    V = plan.part.num_vertices
    full = np.zeros((V,) + rows.shape[1:], rows.dtype)
    full[np.arange(rows.shape[0]) if index is None
         else np.asarray(index, np.int64)] = rows
    return shard_node_values(plan, full, fill=0)


def unshard_features(plan: CommPlan, local: np.ndarray, V: int) -> np.ndarray:
    """Inverse of shard_features for (..., Vp, F) tables."""
    part = plan.part
    flat = np.asarray(local).reshape(plan.num_nodes, -1, local.shape[-1])
    v = np.arange(V)
    return flat[part.node_of(v), part.local_index(v)]


def rounds_to_local(accs: np.ndarray) -> np.ndarray:
    """(.., R, slots, F) round-major accumulators -> (.., Vp, F) table."""
    shape = accs.shape
    return accs.reshape(shape[:-3] + (shape[-3] * shape[-2], shape[-1]))
