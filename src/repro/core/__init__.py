"""MultiGCN core: the paper's contribution as composable JAX modules.

* graph / rmat / partition — graph substrate + §4.3 bit-field round partition
* plan — static dimension-ordered multicast plans (OPPE/OPPR/OPPM)
* message_passing — shard_map executor (ppermute relay, SREM round scan)
* gcn_models — GCN/GIN/GraphSAGE builders + single-device oracles
  (user-facing execution: the ``repro.gcn.GCNEngine`` session API)
* cost_model — paper-table analytical counters (transmissions/DRAM/energy)
* moe_dispatch — the paper's one-put-per-multicast applied to MoE all-to-all
"""
from . import (
    cost_model,
    gcn_models,
    graph,
    message_passing,
    moe_dispatch,
    partition,
    plan,
    rmat,
)

__all__ = [
    "cost_model",
    "gcn_models",
    "graph",
    "message_passing",
    "moe_dispatch",
    "partition",
    "plan",
    "rmat",
]
