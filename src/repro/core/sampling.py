"""Seeded layer-wise neighbor sampling for mini-batch GCN training.

The paper's whole premise is that full graphs outgrow a single node's
memory; the repo-level mirror of that axis is the trainer's working set.
This module bounds it GraphSAGE-style: per mini-batch of *seed* vertices
(the vertices whose loss terms the batch optimizes), expand the in-
neighborhood layer by layer with a bounded fanout over ``Graph.csr_in``,
then take the **vertex-induced** subgraph of the visited set. MG-GCN
(Balin et al.) and Demirci et al. plan communication per mini-batch in
exactly this regime; here each sampled subgraph gets its own (cached,
padded) relay plan on the same torus — see ``repro.gcn.train``.

Design contracts (pinned by ``tests/test_sampling.py``):

  * **bounded fanout** — at each layer every frontier vertex samples at
    most ``fanout`` of its in-neighbors (without replacement; ``-1`` =
    all of them);
  * **stable local<->global map** — ``SampledBatch.nodes`` is the sorted
    global id array; local id ``i`` IS ``nodes[i]``, so the same visited
    set always produces the same subgraph (and the same fingerprint,
    which is what makes the batch-plan cache hit on recurring seed
    sets);
  * **vertex-induced edges** — the subgraph keeps every parent edge with
    both endpoints in the visited set, so subgraph edges are a subset of
    the parent's under the map, and with full fanout the subgraph is
    exactly the closed k-hop in-neighborhood of the seeds (k =
    ``len(fanouts)``) — the guarantee the sampled-vs-full-batch parity
    tests lean on;
  * **per-seed-set determinism** — the sample drawn for a seed set
    depends only on ``(sampler seed, seed set)``, not on how many
    batches were drawn before it, so a seed set recurring across epochs
    reproduces its subgraph bit-for-bit (and therefore its cached
    plan).
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.core.graph import Graph

__all__ = ["NeighborSampler", "SampledBatch", "csr_in_with_values",
           "induce_in_edges", "missing_in_edges"]

_OBS = None


def _obs():
    """Lazy handle on ``repro.gcn.obs`` — imported on first use, not at
    module import, because ``repro.gcn`` imports this module (via
    ``train``) and an eager import would cycle. ``core`` stays
    importable without the gcn package on the path."""
    global _OBS
    if _OBS is None:
        try:
            from repro.gcn import obs as _OBS  # noqa: PLW0603
        except ImportError:
            _OBS = False
    return _OBS or None


class _NullCtx:
    """Stand-in span when ``repro.gcn.obs`` is unavailable."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


def csr_in_with_values(graph: Graph, values: np.ndarray | None = None):
    """:meth:`Graph.csr_in` plus an optional per-edge ``values`` array
    (e.g. the prepared model's edge weights) permuted into the same
    order, so induced subgraphs can carry parent-derived weights."""
    indptr, src, order = graph.csr_in(return_order=True)
    vals = None if values is None else np.asarray(values)[order]
    return indptr, src, vals


def induce_in_edges(indptr: np.ndarray, src: np.ndarray,
                    values: np.ndarray | None, nodes: np.ndarray,
                    num_vertices: int | None = None, *, name: str = "sub"):
    """Vertex-induced subgraph over ``nodes`` (sorted global ids) from a
    destination-CSR view of the parent.

    Keeps every parent edge whose src AND dst are in ``nodes`` and
    relabels both endpoints to local ids (``local i == nodes[i]``).
    ``num_vertices`` may exceed ``len(nodes)`` to leave padding vertices
    (no edges) — the power-of-two bucketing the batch planner uses.
    Returns ``(Graph, values_sub)`` (``values_sub`` is None when
    ``values`` is)."""
    nodes = np.asarray(nodes, np.int64)
    S = int(nodes.size)
    Vout = S if num_vertices is None else int(num_vertices)
    if Vout < S:
        raise ValueError(f"num_vertices {Vout} < |nodes| {S}")
    counts = (indptr[nodes + 1] - indptr[nodes]).astype(np.int64)
    if counts.sum() == 0:
        empty = np.zeros(0, np.int32)
        return (Graph(Vout, empty, empty.copy(), name=name),
                None if values is None else np.zeros(0, values.dtype))
    # gather all in-edges of the node set, then membership-filter sources
    idx = np.concatenate([np.arange(indptr[v], indptr[v + 1])
                          for v in nodes])
    dst_local = np.repeat(np.arange(S, dtype=np.int64), counts)
    src_glob = src[idx].astype(np.int64)
    pos = np.searchsorted(nodes, src_glob)
    pos_c = np.minimum(pos, S - 1)
    keep = nodes[pos_c] == src_glob
    sub = Graph(Vout, pos_c[keep].astype(np.int32),
                dst_local[keep].astype(np.int32), name=name)
    vals = None if values is None else values[idx[keep]]
    return sub, vals


def missing_in_edges(indptr: np.ndarray, src: np.ndarray,
                     values: np.ndarray | None, nodes: np.ndarray):
    """The exact complement of :func:`induce_in_edges` over the same
    destination-CSR view: every parent edge whose dst is in ``nodes``
    but whose src is NOT — the edges a vertex-induced mini-batch drops.

    This is the control-variate correction set (``repro.gcn.train``):
    aggregating cached historical activations ``h̄[src]`` over exactly
    these edges makes ``Â_sub·h + Σ_missing w·h̄[src]`` an unbiased,
    low-variance estimate of the parent aggregation, and because the
    set is the *precise* complement, it is empty for every interior
    vertex of a full-fanout batch — the correction vanishes identically
    and CV training degenerates to plain sampling bit-for-bit.

    Returns ``(dst_local, src_global, values_missing)`` with
    ``dst_local`` indexing into ``nodes`` (``values_missing`` is None
    when ``values`` is)."""
    nodes = np.asarray(nodes, np.int64)
    S = int(nodes.size)
    counts = (indptr[nodes + 1] - indptr[nodes]).astype(np.int64)
    if counts.sum() == 0:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                None if values is None else np.zeros(0, values.dtype))
    idx = np.concatenate([np.arange(indptr[v], indptr[v + 1])
                          for v in nodes])
    dst_local = np.repeat(np.arange(S, dtype=np.int64), counts)
    src_glob = src[idx].astype(np.int64)
    pos = np.searchsorted(nodes, src_glob)
    pos_c = np.minimum(pos, S - 1)
    drop = nodes[pos_c] != src_glob
    vals = None if values is None else values[idx[drop]]
    return dst_local[drop], src_glob[drop], vals


@dataclass
class SampledBatch:
    """One sampled mini-batch: seeds, the visited node set (sorted —
    local id ``i`` <-> global id ``nodes[i]``), the per-layer visited
    frontiers (``layers[0]`` is the seed set; ``layers[l]`` the set
    after ``l`` expansions — cumulative, for the fanout/coverage
    property tests), and the vertex-induced subgraph in local ids."""

    seeds: np.ndarray  # (B,) int64, sorted unique global ids
    nodes: np.ndarray  # (S,) int64, sorted unique global ids
    layers: tuple  # tuple of (Si,) int64 arrays, cumulative per layer
    subgraph: Graph | None  # vertex-induced, local ids (None if skipped)
    parent_vertices: int

    @property
    def num_nodes(self) -> int:
        return int(self.nodes.size)

    def local_of(self, global_ids) -> np.ndarray:
        """Global ids (must be in ``nodes``) -> local subgraph ids."""
        g = np.asarray(global_ids, np.int64)
        pos = np.searchsorted(self.nodes, g)
        if pos.size and (np.any(pos >= self.nodes.size)
                         or np.any(self.nodes[pos] != g)):
            raise ValueError("global id not in the sampled node set")
        return pos

    def feature_blocks(self, block_vertices: int) -> np.ndarray:
        """Sorted unique feature-store block ids this batch touches
        (block ``b`` covers global vertices ``[b*bv, (b+1)*bv)``). The
        gather working set of a batch, in the feature store's unit of
        admission — what determines its device-cache footprint."""
        if block_vertices <= 0:
            raise ValueError("block_vertices must be positive")
        return np.unique(self.nodes // int(block_vertices))

    def fingerprint(self) -> str:
        """Content identity of the batch: parent size + node set + seed
        set. Two batches with equal fingerprints induce the same
        subgraph AND the same loss mask, so this is the batch-plan
        cache key (``repro.gcn.cache.get_batch``)."""
        h = hashlib.sha1()
        h.update(np.int64(self.parent_vertices).tobytes())
        h.update(np.ascontiguousarray(self.nodes).tobytes())
        h.update(np.ascontiguousarray(self.seeds).tobytes())
        return h.hexdigest()


class NeighborSampler:
    """Layer-wise bounded-fanout in-neighbor sampler over one parent
    graph.

    ``fanouts`` has one entry per GCN layer (applied seed-set outward);
    entry ``-1`` (or ``None``) means take the full in-neighborhood at
    that layer. Sampling is without replacement and **per-seed-set
    deterministic**: the rng for one batch is derived from the sampler
    seed and the seed-set content, so identical seed sets always sample
    identical subgraphs regardless of draw order.

    ``epoch_batches`` partitions a train-vertex array into seed sets of
    ``batch_size`` (deterministic shuffle per ``(seed, epoch)``).
    """

    #: bound on memoized batches per sampler (`sample_memoized`); the
    #: value objects are shared, so this caps host copies, not plans
    MEMO_CAPACITY = 512

    def __init__(self, graph: Graph, fanouts, *, seed: int = 0):
        self.graph = graph
        self.fanouts = tuple(-1 if f is None else int(f) for f in fanouts)
        if any(f < -1 for f in self.fanouts):
            raise ValueError(f"fanouts must be >= 0 or -1 (full): "
                             f"{self.fanouts}")
        self.seed = int(seed)
        self.indptr, self.src = graph.csr_in()
        self._memo: OrderedDict[tuple, SampledBatch] = OrderedDict()
        self._memo_lock = threading.Lock()

    # ---------------- one batch ----------------

    def _batch_rng(self, seeds: np.ndarray) -> np.random.Generator:
        h = hashlib.sha1(np.ascontiguousarray(seeds).tobytes()).digest()
        words = np.frombuffer(h[:16], np.uint32)
        return np.random.default_rng([self.seed, *map(int, words)])

    def sample_in_neighbors(self, vertices, fanout: int,
                            rng: np.random.Generator) -> np.ndarray:
        """At most ``fanout`` in-neighbors per vertex (without
        replacement; ``-1`` = all), unioned over ``vertices``."""
        picks = []
        for v in np.asarray(vertices, np.int64):
            lo, hi = int(self.indptr[v]), int(self.indptr[v + 1])
            nbrs = self.src[lo:hi]
            if 0 <= fanout < nbrs.size:
                nbrs = rng.choice(nbrs, size=fanout, replace=False)
            picks.append(nbrs)
        if not picks:
            return np.zeros(0, np.int64)
        return np.unique(np.concatenate(picks).astype(np.int64))

    def sample(self, seeds, *, induce_subgraph: bool = True) -> SampledBatch:
        """Sample one mini-batch for ``seeds`` (global vertex ids).

        ``induce_subgraph=False`` skips materializing the raw induced
        edge list (``SampledBatch.subgraph`` is None) — the training
        path only needs the node set (its execution subgraph is induced
        from the parent *prepared* graph so edge weights carry parent
        degrees; see ``repro.gcn.train``)."""
        seeds = np.unique(np.asarray(seeds, np.int64))
        if seeds.size == 0:
            raise ValueError("empty seed set")
        V = self.graph.num_vertices
        if seeds.min() < 0 or seeds.max() >= V:
            raise ValueError(f"seed ids must be in [0, {V})")
        obs = _obs()
        with (obs.trace.span("sample", seeds=int(seeds.size),
                             graph=self.graph.name)
              if obs is not None else _NullCtx()) as sp:
            rng = self._batch_rng(seeds)
            nodes = seeds
            layers = [seeds]
            for fanout in self.fanouts:
                sampled = self.sample_in_neighbors(nodes, fanout, rng)
                nodes = np.union1d(nodes, sampled)
                layers.append(nodes)
            sub = None
            if induce_subgraph:
                sub, _ = induce_in_edges(self.indptr, self.src, None,
                                         nodes,
                                         name=f"{self.graph.name}#batch")
            sp.set(nodes=int(nodes.size))
        if obs is not None:
            obs.metrics.counter(
                "sample.batches", unit="batches",
                help="mini-batches drawn by NeighborSampler.sample").add(1)
            obs.metrics.counter(
                "sample.nodes", unit="vertices",
                help="visited vertices across all sampled batches").add(
                    int(nodes.size))
        return SampledBatch(seeds=seeds, nodes=nodes, layers=tuple(layers),
                            subgraph=sub, parent_vertices=V)

    def sample_memoized(self, seeds, *,
                        induce_subgraph: bool = False) -> SampledBatch:
        """:meth:`sample` behind a bounded, thread-safe per-seed-set
        memo — the sampler-side cache the pipelined trainer's builder
        threads share (``repro.gcn.pipeline``).

        The sample is a pure function of ``(sampler seed, seed set)``
        (per-seed-set determinism above), so concurrent misses for the
        same key may both build but must agree bit-for-bit; the first
        commit wins and the duplicate is discarded — the same
        first-commit-wins contract as ``repro.gcn.cache``. Sampling
        happens OUTSIDE the lock, so a slow sample never serializes
        other builder threads. LRU-bounded at :attr:`MEMO_CAPACITY`
        entries."""
        seeds = np.unique(np.asarray(seeds, np.int64))
        key = (bool(induce_subgraph), seeds.tobytes())
        with self._memo_lock:
            hit = self._memo.get(key)
            if hit is not None:
                self._memo.move_to_end(key)
        if hit is not None:
            # a hit skips sample() entirely, so without its own counter
            # telemetry under-reports sampler work from epoch 2 on (and
            # pipelined vs serial runs disagree on identical work):
            # sample.batches + sample.memo_hits == batches consumed
            obs = _obs()
            if obs is not None:
                obs.metrics.counter(
                    "sample.memo_hits", unit="batches",
                    help="sample_memoized calls served from the memo "
                         "without re-sampling").add(1)
            return hit
        batch = self.sample(seeds, induce_subgraph=induce_subgraph)
        with self._memo_lock:
            won = self._memo.setdefault(key, batch)
            self._memo.move_to_end(key)
            while len(self._memo) > self.MEMO_CAPACITY:
                self._memo.popitem(last=False)
        return won

    # ---------------- epoch iteration ----------------

    def epoch_batches(self, train_nodes, batch_size: int, *,
                      epoch: int = 0) -> list[np.ndarray]:
        """Partition ``train_nodes`` into seed sets of ``batch_size``
        (last one may be smaller), shuffled deterministically per
        ``(sampler seed, epoch)``. ``epoch=0`` every epoch keeps the
        SAME seed sets across epochs — what makes the batch-plan cache
        hit from epoch 2 on (``GCNTrainer.fit_sampled`` default)."""
        train_nodes = np.unique(np.asarray(train_nodes, np.int64))
        if batch_size <= 0:
            raise ValueError("batch_size must be positive")
        rng = np.random.default_rng([self.seed, 0x5EED, int(epoch)])
        order = rng.permutation(train_nodes.size)
        shuffled = train_nodes[order]
        return [shuffled[i:i + batch_size]
                for i in range(0, shuffled.size, batch_size)]
