"""Per-model GCN / GIN / GraphSAGE builders for the MultiGCN runtime.

This module is the *low-level* layer: it only defines, per model, the
three callables the :mod:`repro.gcn` registry wires into the shared
execution path —

  * ``*_prepare(graph) -> (graph', edge_weights)``
  * ``*_init_layer(key, fan_in, fan_out) -> dict``
  * ``*_combine(layer, agg, self_feats, last) -> array``

plus the single-device oracle loop (``reference_loop``) both the engine
and any standalone check share. All user-facing GCN execution lives in
``repro.gcn.GCNEngine``; new aggregation semantics are added with
``repro.gcn.register_model``, not by editing this file.

The oracle's aggregation is a plain dense COO segment-sum on one device;
the distributed engine must match it from EITHER aggregation backend
(``agg_impl="jnp"`` scatter or ``agg_impl="pallas"`` blocked-ELL kernel)
— the parity tests in ``tests/test_gcn_agg_impl.py`` pin that contract.

Aggregation semantics (all expressed as edge weights in the plan so the
executor stays model-agnostic):
  * GCN  — Â = D^-1/2 (A + I) D^-1/2; combine = ReLU(W a + b)
  * GIN  — a = (1+eps) h_v + sum_{u in N} h_u; combine = 2-layer MLP
  * SAGE — mean aggregator; combine = ReLU(W_self h_v + W_neigh a)
GIN's eps is kept a fixed constant (0) so the communication plan stays
static; the paper also runs inference with fixed weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph


# ---------------------------------------------------------------------------
# Per-model edge-weight builders (registered with repro.gcn)
# ---------------------------------------------------------------------------


def gcn_prepare(graph: Graph) -> tuple[Graph, np.ndarray]:
    """Â = D^-1/2 (A + I) D^-1/2 expressed as per-edge weights."""
    din = graph.in_degrees().astype(np.float64)
    g2 = graph.with_self_loops()
    d1 = din + 1.0
    w = 1.0 / np.sqrt(d1[g2.dst] * d1[g2.src])
    return g2, w.astype(np.float32)


def gin_prepare(graph: Graph) -> tuple[Graph, np.ndarray]:
    g2 = graph.with_self_loops()
    w = np.ones(g2.num_edges, np.float32)  # eps = 0: self weight 1+eps
    return g2, w


def sage_prepare(graph: Graph) -> tuple[Graph, np.ndarray]:
    din = graph.in_degrees().astype(np.float64)
    w = (1.0 / np.maximum(din[graph.dst], 1.0)).astype(np.float32)
    return graph, w


# ---------------------------------------------------------------------------
# Per-model parameters
# ---------------------------------------------------------------------------


def gcn_init_layer(key, fan_in: int, fan_out: int) -> dict:
    std = 1.0 / np.sqrt(fan_in)
    return {"w": std * jax.random.normal(key, (fan_in, fan_out), jnp.float32),
            "b": jnp.zeros((fan_out,), jnp.float32)}


def sage_init_layer(key, fan_in: int, fan_out: int) -> dict:
    layer = gcn_init_layer(key, fan_in, fan_out)
    std = 1.0 / np.sqrt(fan_in)
    layer["w_self"] = std * jax.random.normal(
        jax.random.fold_in(key, 1), (fan_in, fan_out), jnp.float32)
    return layer


def gin_init_layer(key, fan_in: int, fan_out: int) -> dict:
    layer = gcn_init_layer(key, fan_in, fan_out)
    layer["w2"] = (1.0 / np.sqrt(fan_out)) * jax.random.normal(
        jax.random.fold_in(key, 2), (fan_out, fan_out), jnp.float32)
    layer["b2"] = jnp.zeros((fan_out,), jnp.float32)
    return layer


# ---------------------------------------------------------------------------
# Per-model combination (the MLP of the paper's Combination engine)
# ---------------------------------------------------------------------------


def gcn_combine(layer, agg, self_feats, last: bool):
    h = agg @ layer["w"] + layer["b"]
    return h if last else jax.nn.relu(h)


def sage_combine(layer, agg, self_feats, last: bool):
    h = agg @ layer["w"] + layer["b"] + self_feats @ layer["w_self"]
    return h if last else jax.nn.relu(h)


def gin_combine(layer, agg, self_feats, last: bool):
    h = jax.nn.relu(agg @ layer["w"] + layer["b"])
    h = h @ layer["w2"] + layer["b2"]
    return h if last else jax.nn.relu(h)


# ---------------------------------------------------------------------------
# Single-device reference (the oracle)
# ---------------------------------------------------------------------------


def reference_loop(g2: Graph, edge_w: np.ndarray, combine, params, feats):
    """Exact dense-graph oracle: segment-sum aggregation on one device,
    with the SAME prepared graph / weights / combine callable as the
    distributed path, so agreement checks are apples-to-apples."""
    src, dst = jnp.asarray(g2.src), jnp.asarray(g2.dst)
    wj = jnp.asarray(edge_w)
    x = jnp.asarray(feats)
    for li, layer in enumerate(params):
        msgs = x[src] * wj[:, None]
        agg = jnp.zeros_like(x, shape=(g2.num_vertices, x.shape[-1]))
        agg = agg.at[dst].add(msgs)
        x = combine(layer, agg, x, last=li == len(params) - 1)
    return x
