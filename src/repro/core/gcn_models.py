"""GCN / GIN / GraphSAGE on top of the MultiGCN communication runtime,
plus the exact single-device references used for verification.

Aggregation semantics (all expressed as edge weights in the plan so the
executor stays model-agnostic):
  * GCN  — Â = D^-1/2 (A + I) D^-1/2; combine = ReLU(W a + b)
  * GIN  — a = (1+eps) h_v + sum_{u in N} h_u; combine = 2-layer MLP
  * SAGE — mean aggregator; combine = ReLU(W_self h_v + W_neigh a)
GIN's eps is kept a fixed constant (0) so the communication plan stays
static; the paper also runs inference with fixed weights.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import GCNConfig
from repro.core import message_passing as mp
from repro.core.graph import Graph
from repro.core.partition import TorusMesh, make_partition
from repro.core.plan import CommPlan, build_plan


# ---------------------------------------------------------------------------
# Plan construction with model-specific edge weights
# ---------------------------------------------------------------------------


def model_graph_and_weights(cfg: GCNConfig, graph: Graph):
    din = graph.in_degrees().astype(np.float64)
    if cfg.model == "gcn":
        g2 = graph.with_self_loops()
        d1 = din + 1.0
        w = 1.0 / np.sqrt(d1[g2.dst] * d1[g2.src])
        return g2, w.astype(np.float32)
    if cfg.model == "gin":
        g2 = graph.with_self_loops()
        w = np.ones(g2.num_edges, np.float32)  # eps = 0: self weight 1+eps
        return g2, w
    if cfg.model == "sage":
        w = (1.0 / np.maximum(din[graph.dst], 1.0)).astype(np.float32)
        return graph, w
    raise ValueError(cfg.model)


def build_gcn_plan(cfg: GCNConfig, graph: Graph, mesh: TorusMesh) -> CommPlan:
    g2, w = model_graph_and_weights(cfg, graph)
    part = make_partition(cfg, mesh.num_nodes, num_vertices=graph.num_vertices)
    return build_plan(cfg, g2, mesh, part, edge_weights=w)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def gcn_params(cfg: GCNConfig, key, dims: list[int]):
    """dims = [feat_in, hidden..., out]."""
    params = []
    keys = jax.random.split(key, len(dims) - 1)
    for i, k in enumerate(keys):
        fi, fo = dims[i], dims[i + 1]
        std = 1.0 / np.sqrt(fi)
        layer = {"w": std * jax.random.normal(k, (fi, fo), jnp.float32),
                 "b": jnp.zeros((fo,), jnp.float32)}
        if cfg.model == "sage":
            layer["w_self"] = std * jax.random.normal(
                jax.random.fold_in(k, 1), (fi, fo), jnp.float32)
        if cfg.model == "gin":
            layer["w2"] = (1.0 / np.sqrt(fo)) * jax.random.normal(
                jax.random.fold_in(k, 2), (fo, fo), jnp.float32)
            layer["b2"] = jnp.zeros((fo,), jnp.float32)
        params.append(layer)
    return params


def combine(cfg: GCNConfig, layer, agg, self_feats, last: bool):
    """Combination phase (the MLP of the paper's Combination engine)."""
    h = agg @ layer["w"] + layer["b"]
    if cfg.model == "sage":
        h = h + self_feats @ layer["w_self"]
    if cfg.model == "gin":
        h = jax.nn.relu(h)
        h = h @ layer["w2"] + layer["b2"]
    return h if last else jax.nn.relu(h)


# ---------------------------------------------------------------------------
# Distributed forward (shard_map over the torus)
# ---------------------------------------------------------------------------


def distributed_forward(cfg: GCNConfig, params, plan: CommPlan, mesh_jax,
                        axis_names, feats_sharded):
    """feats_sharded: (*dims, Vp, F) jnp array (sharded over the mesh).
    Returns (*dims, Vp, F_out)."""
    from jax.sharding import PartitionSpec as P

    st = mp.exchange_statics(plan, axis_names)
    pdev = mp.plan_device_arrays(plan)
    nd = len(plan.mesh.dims)
    plan_spec = P(None, *axis_names)  # (R, *dims, ...)
    feat_spec = P(*axis_names)  # (*dims, Vp, F)

    @functools.partial(
        jax.shard_map, mesh=mesh_jax,
        in_specs=(jax.tree.map(lambda _: plan_spec, pdev), feat_spec),
        out_specs=P(*(tuple(axis_names) + (None, None, None))),
    )
    def _exchange(pdev, feats):
        accs = mp.exchange_and_aggregate(st, pdev, feats)
        return accs[(None,) * nd]  # re-add mesh dims for out_spec

    x = feats_sharded
    for li, layer in enumerate(params):
        accs = _exchange(pdev, x)  # (*dims, R, slots, F)
        agg = accs.reshape(accs.shape[:nd] + (-1, accs.shape[-1]))  # (*dims, Vp, F)
        x = combine(cfg, layer, agg, x, last=li == len(params) - 1)
    return x


# ---------------------------------------------------------------------------
# Single-device reference (the oracle)
# ---------------------------------------------------------------------------


def reference_forward(cfg: GCNConfig, params, graph: Graph, feats):
    """Exact dense-graph reference: segment-sum aggregation on one device."""
    g2, w = model_graph_and_weights(cfg, graph)
    src = jnp.asarray(g2.src)
    dst = jnp.asarray(g2.dst)
    wj = jnp.asarray(w)

    x = feats
    for li, layer in enumerate(params):
        msgs = x[src] * wj[:, None]
        agg = jnp.zeros_like(x, shape=(graph.num_vertices, x.shape[-1]))
        agg = agg.at[dst].add(msgs)
        x = combine(cfg, layer, agg, x, last=li == len(params) - 1)
    return x
