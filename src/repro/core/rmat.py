"""R-MAT graph generator (Chakrabarti et al.) + degree-matched synthetic
twins for the paper's SNAP graphs, with on-disk caching.

The recursive-matrix probabilities default to the Graph500 values
(a, b, c) = (0.57, 0.19, 0.19), which produce the heavy-tailed degree
distributions the paper's redundancy numbers depend on.
"""
from __future__ import annotations

import os
from pathlib import Path

import numpy as np

from repro.config import GraphSpec
from repro.core.graph import Graph

_CACHE = Path(os.environ.get("REPRO_GRAPH_CACHE", "/tmp/repro_graphs"))


def rmat(scale_v: int, num_edges: int, *, a=0.57, b=0.19, c=0.19, seed=0,
         name="rmat") -> Graph:
    """Generate an R-MAT graph with 2**scale_v vertices."""
    rng = np.random.default_rng(seed)
    n_bits = scale_v
    E = num_edges
    src = np.zeros(E, np.int64)
    dst = np.zeros(E, np.int64)
    ab, abc = a + b, a + b + c
    for _ in range(n_bits):
        r = rng.random(E)
        src <<= 1
        dst <<= 1
        # quadrant choice: TL(a) -> (0,0); TR(b) -> (0,1); BL(c) -> (1,0)
        dst |= ((r >= a) & (r < ab)) | (r >= abc)
        src |= r >= ab
    # permute vertex IDs so the bit-field partitioner sees no generator bias
    perm = rng.permutation(1 << scale_v).astype(np.int64)
    src, dst = perm[src], perm[dst]
    return Graph(1 << scale_v, src.astype(np.int32), dst.astype(np.int32),
                 name=name)


def _cache_path(name: str, v: int, e: int, seed: int) -> Path:
    return _CACHE / f"{name}_v{v}_e{e}_s{seed}.npz"


def build_graph(spec: GraphSpec, scale_factor: int = 1) -> Graph:
    """Materialize the graph for ``spec``.

    ``scale_factor > 1`` shrinks |V| and |E| together (preserving the
    average degree — the quantity the paper's redundancy ratios are driven
    by). Full-size graphs are only ever *described* (ShapeDtypeStructs) in
    the dry-run; cost-model benchmarks use scaled twins and report the
    factor.
    """
    v = max(1024, spec.num_vertices // scale_factor)
    e = max(4096, spec.num_edges // scale_factor)
    scale_v = max(10, int(np.ceil(np.log2(v))))
    name = f"{spec.name}x{scale_factor}"
    p = _cache_path(name, scale_v, e, spec.rmat_seed)
    if p.exists():
        z = np.load(p)
        return Graph(int(z["nv"]), z["src"], z["dst"], name=name)
    g = rmat(scale_v, e, seed=spec.rmat_seed, name=name)
    _CACHE.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(p, nv=g.num_vertices, src=g.src, dst=g.dst)
    return g
