"""Bit-field vertex mapping and round partition (paper §4.3, Fig. 7).

vID bit layout:  [ round | slot (x bits) | node (n bits) ]
  * node  = vID[0:n)        — which processing node owns the vertex
  * slot  = vID[n:n+x)      — local index within a round (2^x per node)
  * round = vID[n+x:)       — execution round (SREM)

``x`` is sized by the paper's rule 2^x <= alpha * M / S (aggregation buffer
capacity over aggregated-feature bytes), alpha = 0.75.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.config import GCNConfig


@dataclass(frozen=True)
class TorusMesh:
    """d-dimensional torus of processing nodes. dims row-major; node id =
    mixed-radix encoding of coordinates (last dim fastest)."""

    dims: tuple[int, ...]

    @property
    def num_nodes(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    def coords(self, node: np.ndarray | int):
        node = np.asarray(node)
        out = []
        for d in reversed(self.dims):
            out.append(node % d)
            node = node // d
        return tuple(reversed(out))

    def node_id(self, coords) -> np.ndarray | int:
        nid = 0
        for c, d in zip(coords, self.dims):
            nid = nid * d + c
        return nid

    def ring_dist(self, a, b, dim: int, bidir: bool = False):
        """Hops from coord a to b along ``dim`` (unidirectional ring by
        default; ``bidir`` takes the shorter way — a perf-iteration lever)."""
        d = self.dims[dim]
        fwd = (np.asarray(b) - np.asarray(a)) % d
        if not bidir:
            return fwd
        return np.minimum(fwd, d - fwd)


@dataclass(frozen=True)
class RoundPartition:
    num_nodes: int  # power of two
    n_bits: int
    x_bits: int
    num_rounds: int
    num_vertices: int

    def node_of(self, v):
        return np.asarray(v) & (self.num_nodes - 1)

    def slot_of(self, v):
        return (np.asarray(v) >> self.n_bits) & ((1 << self.x_bits) - 1)

    def round_of(self, v):
        return np.asarray(v) >> (self.n_bits + self.x_bits)

    @property
    def slots_per_round(self) -> int:
        return 1 << self.x_bits

    def local_index(self, v):
        """Index of v within its node's full vertex table (round-major)."""
        return (self.round_of(v) << self.x_bits) | self.slot_of(v)

    def vertices_per_node(self) -> int:
        return self.num_rounds << self.x_bits


def choose_x_bits(cfg: GCNConfig, num_nodes: int) -> int:
    """Paper: 2^x <= alpha*M/S < 2^(x+1); S = aggregated feature bytes."""
    S = cfg.graph.feat_in * 4  # replicas hold |h^(k-1)| floats
    budget = cfg.alpha * cfg.agg_buffer_bytes / S
    x = max(0, int(math.floor(math.log2(max(budget, 1.0)))))
    return x


def make_partition(cfg: GCNConfig, num_nodes: int,
                   num_vertices: int | None = None) -> RoundPartition:
    assert num_nodes & (num_nodes - 1) == 0, "node count must be 2^n"
    n_bits = int(math.log2(num_nodes))
    V = num_vertices if num_vertices is not None else cfg.graph.num_vertices
    if cfg.use_rounds:
        x_bits = choose_x_bits(cfg, num_nodes)
        per_round_capacity = num_nodes << x_bits
        num_rounds = max(1, -(-V // per_round_capacity))
    else:
        # no SREM: a single round spanning the whole vertex range
        x_bits = max(0, (V - 1).bit_length() - n_bits)
        num_rounds = 1
    return RoundPartition(num_nodes, n_bits, x_bits, num_rounds, V)
