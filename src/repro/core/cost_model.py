"""Analytical cost & energy model for MultiGCN configurations.

Counts the same events the paper's cycle simulator reports — hop-weighted
network transmissions, DRAM accesses, ALU ops — directly from the graph
partition, fully vectorized (no per-item Python), so paper-scale graphs
are tractable. The time model is bulk-synchronous with intra-round
overlap: per node, round time = max(resource terms); per round, time =
max over nodes; total = sum over rounds (inter-round overlap shaves the
non-dominant terms, matching the paper's overlap claims).

Modeling assumptions (documented; calibration noted in EXPERIMENTS.md):
  * Unidirectional dimension-ordered routing (the deterministic core of
    DyXY; adaptivity does not transfer to static SPMD).
  * Per-packet router overhead t_pkt = 20 ns — calibrated once so the
    OPPE baseline lands in the paper's measured 17–19 % network
    utilization band (Table 4); all other numbers are derived counts.
  * DRAM spill rules: a buffer-exceeding working set (replicas or
    accumulators) pays store+reload per use, per the paper's §3
    characterization of OPPR.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.config import GCNConfig, PAPER_NODE, PaperNodeSpec
from repro.core.graph import Graph
from repro.core.partition import RoundPartition, TorusMesh, make_partition

T_PKT = 20e-9  # router per-packet overhead (calibrated, see module docstring)
HDR_BYTES = 16  # per-packet header (position, sizes)
ETA_RAND = 0.25  # DRAM efficiency for random replica reloads (row misses)


@dataclass
class CostReport:
    name: str
    # per-node arrays (N,)
    net_bytes: np.ndarray  # hop-weighted feature+list bytes through links
    packets: np.ndarray  # link-level packet events
    dram_bytes: np.ndarray
    dram_rand_bytes: np.ndarray  # random-access portion (charged at ETA_RAND)
    ops: np.ndarray  # aggregation + combination MACs
    num_rounds: int = 1
    # scalar totals
    preprocess_s: float = 0.0

    def totals(self) -> dict:
        return {
            "net_bytes": float(self.net_bytes.sum()),
            "dram_bytes": float((self.dram_bytes + self.dram_rand_bytes).sum()),
            "packets": float(self.packets.sum()),
            "ops": float(self.ops.sum()),
        }

    def time_model(self, hw: PaperNodeSpec = PAPER_NODE) -> dict:
        t_net = self.net_bytes / hw.net_bandwidth
        t_dram = (self.dram_bytes + self.dram_rand_bytes / ETA_RAND) / hw.hbm_bandwidth
        t_comp = 2.0 * self.ops / hw.peak_ops
        t_pkt = self.packets * T_PKT
        per_node = np.maximum.reduce([t_net, t_dram, t_comp, t_pkt])
        # bulk-synchronous with inter-round pipelining: sync latency is
        # hidden unless the rounds are tiny
        t_total = max(float(per_node.max()),
                      self.num_rounds * hw.net_latency_cycles / hw.clock_hz)
        raw_dram = (self.dram_bytes + self.dram_rand_bytes) / hw.hbm_bandwidth
        return {
            "time_s": t_total,
            "util_net": float(t_net.max() / t_total),
            "util_dram": float(raw_dram.max() / t_total),
            "util_compute": float(t_comp.max() / t_total),
        }

    def energy_model(self, hw: PaperNodeSpec = PAPER_NODE) -> dict:
        e_net = self.net_bytes.sum() * 8 * hw.nvlink_pj_per_bit * 1e-12
        e_dram = ((self.dram_bytes + self.dram_rand_bytes).sum()
                  * 8 * hw.hbm_pj_per_bit * 1e-12)
        t = self.time_model(hw)["time_s"]
        e_nodes = 3.67113 * t * len(self.net_bytes)  # Table 5: 3671.13 mW/node
        return {"energy_j": e_net + e_dram + e_nodes, "e_net": e_net,
                "e_dram": e_dram, "e_nodes": e_nodes}


def _ring_dist(a, b, dim):
    return (b - a) % dim


def _unique_rows(*cols):
    """Dedup over stacked int columns; returns index of one representative
    per unique row (sorted order) and the sorted composite keys."""
    key = cols[0].astype(np.int64)
    for c in cols[1:]:
        key = key * (int(c.max(initial=0)) + 2) + c.astype(np.int64)
    order = np.argsort(key, kind="stable")
    ks = key[order]
    first = np.concatenate([[True], ks[1:] != ks[:-1]])
    return order[first], order, first


def analyze(cfg: GCNConfig, graph: Graph, mesh: TorusMesh,
            part: RoundPartition | None = None,
            feat_in: int | None = None, feat_out: int | None = None,
            name: str | None = None, bidir: bool = False) -> CostReport:
    """Count events for cfg's (message_passing, use_rounds) configuration.

    ``bidir``: route each packet the shorter way around every ring
    (bidirectional torus links — §Perf iteration for the GCN cell)."""
    part = part or make_partition(cfg, mesh.num_nodes)
    N = mesh.num_nodes
    model = cfg.message_passing
    rounds = cfg.use_rounds
    Fi = feat_in if feat_in is not None else cfg.graph.feat_in
    Fo = feat_out if feat_out is not None else cfg.graph.feat_hidden
    Bf = Fi * 4
    Bo = Fo * 4
    V, E = graph.num_vertices, graph.num_edges

    src, dst = graph.src, graph.dst
    sn, dn = part.node_of(src), part.node_of(dst)
    rd = np.minimum(part.round_of(dst), part.num_rounds - 1) if rounds \
        else np.zeros(E, np.int32)
    R = part.num_rounds if rounds else 1

    coords = np.stack(mesh.coords(np.arange(N)), axis=1)  # (N, ndim)
    ndim = len(mesh.dims)
    cut = sn != dn

    net_bytes = np.zeros(N, np.float64)
    packets = np.zeros(N, np.float64)
    dram = np.zeros(N, np.float64)  # sequential-friendly traffic
    dram_rand = np.zeros(N, np.float64)  # random replica spill traffic
    ops = np.zeros(N, np.float64)

    # ---------------- hop-weighted unicast distance (oppe / oppr) -------
    def unicast_hops(s_idx, d_idx):
        h = np.zeros(s_idx.shape, np.int64)
        for k in range(ndim):
            f = _ring_dist(coords[s_idx, k], coords[d_idx, k], mesh.dims[k])
            h += np.minimum(f, mesh.dims[k] - f) if bidir else f
        return h

    # source-node attribution of link bytes (paper normalizes per system,
    # per-node split uses origin attribution)
    def add_net(src_nodes, byte_counts, pkt_counts):
        np.add.at(net_bytes, src_nodes, byte_counts)
        np.add.at(packets, src_nodes, pkt_counts)

    if model == "oppe":
        h = unicast_hops(sn[cut], dn[cut])
        add_net(sn[cut], h * (Bf + HDR_BYTES + 4), h)
        # src reads: streamed per edge (local edges included)
        np.add.at(dram, sn, np.full(E, Bf, np.float64))
        # accumulator working set per (round, node)
        acc_rows = np.zeros((R, N), np.int64)
        uq, _, _ = _unique_rows(rd, dn, part.local_index(dst))
        np.add.at(acc_rows, (rd[uq], dn[uq]), 1)
        acc_spill = acc_rows * Bf > cfg.alpha * cfg.agg_buffer_bytes  # (R, N)
        recv_edges = np.zeros((R, N), np.int64)
        np.add.at(recv_edges, (rd, dn), 1)
        cut_recv = np.zeros((R, N), np.int64)
        np.add.at(cut_recv, (rd[cut], dn[cut]), 1)
        if not rounds:  # SREM sizes rounds so accs/replicas stay on-chip
            # §3 characterization: received features are stored to DRAM on
            # receipt and reloaded when aggregated (random access)
            dram_rand += (2.0 * Bf * cut_recv * acc_spill).sum(axis=0)
            # spilled accumulators pay read-modify-write per edge
            dram += (2.0 * Bf * recv_edges * acc_spill).sum(axis=0)
        # with rounds (SREM): accs and per-round replicas fit on chip
    else:
        # dedup to (u, dst_node, round) replicas
        key_sel, order, first = _unique_rows(rd, dst * 0 + src, dn)
        u_rep, dn_rep, rd_rep = src[key_sel], dn[key_sel], rd[key_sel]
        sn_rep = part.node_of(u_rep)
        rcut = sn_rep != dn_rep
        if model == "oppr":
            h = unicast_hops(sn_rep[rcut], dn_rep[rcut])
            # neighbor-list bytes ride along: 4B per served edge
            served = np.diff(np.flatnonzero(
                np.concatenate([first, [True]])))  # edges per replica
            add_net(sn_rep[rcut], h * (Bf + HDR_BYTES) + 4 * served[rcut] * h,
                    h)
        else:  # oppm: dimension-ordered multicast tree
            # phase-k link count per (u, round, prefix coords)
            rem = rcut
            u_c, dn_c, rd_c = u_rep[rem], dn_rep[rem], rd_rep[rem]
            sn_c = part.node_of(u_c)
            served_all = np.diff(np.flatnonzero(
                np.concatenate([first, [True]])))[rem]
            tree_links = np.zeros(N, np.float64)
            tree_pkts = np.zeros(N, np.float64)
            prefix_cols = [rd_c, u_c]
            for k in range(ndim):
                dk = mesh.dims[k]
                dist_f = _ring_dist(coords[sn_c, k], coords[dn_c, k], dk)
                dist_b = (dk - dist_f) % dk
                # group by (round, u, dest coords 0..k-1): max travel in dim k
                uq_idx, order_k, first_k = _unique_rows(*prefix_cols,
                                                        np.zeros_like(u_c))
                grp_id = np.cumsum(first_k) - 1
                ng = grp_id.max() + 1
                if bidir:
                    go_fwd = dist_f <= dist_b
                    gmax_f = np.zeros(ng, np.int64)
                    gmax_b = np.zeros(ng, np.int64)
                    np.maximum.at(gmax_f, grp_id,
                                  np.where(go_fwd, dist_f, 0)[order_k])
                    np.maximum.at(gmax_b, grp_id,
                                  np.where(go_fwd, 0, dist_b)[order_k])
                    gmax = gmax_f + gmax_b
                else:
                    gmax = np.zeros(ng, np.int64)
                    np.maximum.at(gmax, grp_id, dist_f[order_k])
                src_of_grp = sn_c[order_k][first_k]
                np.add.at(tree_links, src_of_grp, gmax)
                np.add.at(tree_pkts, src_of_grp, gmax)  # per-hop link events
                prefix_cols.append(coords[dn_c, k])
            net_bytes += tree_links * (Bf + HDR_BYTES)
            packets += tree_pkts
            # neighbor lists travel the unicast path portion to their node
            h_uni = unicast_hops(sn_c, dn_c)
            np.add.at(net_bytes, sn_c, 4.0 * served_all * h_uni)

        # src DRAM reads: once per (u, round) with any sends or local use
        uq2, _, _ = _unique_rows(rd_rep, u_rep, np.zeros_like(u_rep))
        np.add.at(dram, part.node_of(u_rep[uq2]),
                  np.full(uq2.size, Bf, np.float64))
        # receiver replica spill: replicas per (round, node)
        repl = np.zeros((R, N), np.int64)
        np.add.at(repl, (rd_rep[rcut], dn_rep[rcut]), 1)
        spill = repl * Bf > cfg.alpha * cfg.agg_buffer_bytes
        dram_rand += (2.0 * Bf * repl * spill).sum(axis=0)
        if not rounds:
            # spilled accumulators pay read-modify-write per served edge
            acc_rows = np.zeros((R, N), np.int64)
            uqa, _, _ = _unique_rows(rd, dn, part.local_index(dst))
            np.add.at(acc_rows, (rd[uqa], dn[uqa]), 1)
            acc_spill = acc_rows * Bf > cfg.alpha * cfg.agg_buffer_bytes
            recv_edges = np.zeros((R, N), np.int64)
            np.add.at(recv_edges, (rd, dn), 1)
            dram += (2.0 * Bf * recv_edges * acc_spill).sum(axis=0)

    # results: combination reads aggregated acc + writes output
    vload = np.bincount(part.node_of(np.arange(V)), minlength=N)
    dram += vload * (Bf + Bo)

    # compute: aggregation MAC per edge element + combination matmul
    np.add.at(ops, dn, np.full(E, Fi, np.float64))
    ops += vload * (Fi * Fo)

    return CostReport(
        name=name or f"{model}{'+srem' if rounds else ''}",
        net_bytes=net_bytes, packets=packets, dram_bytes=dram,
        dram_rand_bytes=dram_rand, ops=ops, num_rounds=R)


def paper_configuration_suite(cfg: GCNConfig, graph: Graph, mesh: TorusMesh):
    """The paper's five configurations (Fig. 8 / Table 6)."""
    import dataclasses

    suite = {
        "oppe": dataclasses.replace(cfg, message_passing="oppe", use_rounds=False),
        "oppr": dataclasses.replace(cfg, message_passing="oppr", use_rounds=False),
        "tmm": dataclasses.replace(cfg, message_passing="oppm", use_rounds=False),
        "srem": dataclasses.replace(cfg, message_passing="oppe", use_rounds=True),
        "tmm+srem": dataclasses.replace(cfg, message_passing="oppm", use_rounds=True),
    }
    return {k: analyze(c, graph, mesh, name=k) for k, c in suite.items()}
