"""Version compatibility shims for the jax API surface we use.

The repo targets the current jax API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.set_mesh``); this
container ships jax 0.4.x where those live under older names. Every
mesh/shard_map touchpoint goes through this module so the version split
lives in exactly one place.
"""
from __future__ import annotations

import contextlib

import jax

try:  # jax >= 0.5
    _AXIS_TYPE_AUTO = jax.sharding.AxisType.Auto
except AttributeError:  # jax 0.4.x: no explicit-sharding axis types yet
    _AXIS_TYPE_AUTO = None


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with all-Auto axis types where supported."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    if _AXIS_TYPE_AUTO is not None:
        return jax.make_mesh(
            axis_shapes, axis_names, devices=devices,
            axis_types=(_AXIS_TYPE_AUTO,) * len(axis_shapes))
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def shard_map(f=None, *, mesh, in_specs, out_specs, check_rep=True):
    """``jax.shard_map`` (new) / ``jax.experimental.shard_map`` (0.4.x).

    Usable both as ``shard_map(f, mesh=...)`` and as a decorator factory
    ``@shard_map(mesh=...)`` like the modern API. ``check_rep=False``
    disables the replication-rule check (required when the body contains
    a ``pallas_call``, which has no replication rule); the kwarg was
    renamed ``check_vma`` in newer jax, so both spellings are tried.
    """
    if f is None:
        return lambda g: shard_map(g, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_rep=check_rep)
    if hasattr(jax, "shard_map"):
        _sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as _sm
    try:
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_rep)
    except TypeError:  # newer jax renamed the flag
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=check_rep)


def set_mesh(mesh):
    """Context manager making ``mesh`` ambient for jit/lowering."""
    sm = getattr(jax.sharding, "set_mesh", None)
    if sm is not None:
        return sm(mesh)
    if hasattr(mesh, "__enter__"):  # 0.4.x: Mesh is itself a context
        return mesh
    return contextlib.nullcontext()


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict (0.4.x returns ``[dict]``)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


try:  # jaxpr types were moved out of the trimmed jax.core namespace
    from jax.extend.core import ClosedJaxpr as _ClosedJaxpr, Jaxpr as _Jaxpr
except ImportError:
    from jax.core import ClosedJaxpr as _ClosedJaxpr, Jaxpr as _Jaxpr


def subjaxprs_in_params(params):
    """Yield every sub-``Jaxpr`` held in a jaxpr equation's params
    (version-independent replacement for ``jax.core.jaxprs_in_params``)."""
    for v in params.values():
        for x in v if isinstance(v, (tuple, list)) else (v,):
            if isinstance(x, _ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, _Jaxpr):
                yield x
