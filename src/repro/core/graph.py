"""Graph structures for the MultiGCN core.

Graphs are host-side numpy edge lists (the partitioner and communication
planner run on host, exactly like the paper's one-time graph mapping);
device-side structures (replica buffers, padded neighbor lists) are built
by ``repro.core.plan``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class Graph:
    """Directed graph; edge (src[i], dst[i]) means src's feature is
    aggregated into dst (dst's in-neighbor set contains src)."""

    num_vertices: int
    src: np.ndarray  # (E,) int32
    dst: np.ndarray  # (E,) int32
    name: str = "graph"

    def __post_init__(self):
        self.src = np.asarray(self.src, np.int32)
        self.dst = np.asarray(self.dst, np.int32)
        assert self.src.shape == self.dst.shape

    @property
    def num_edges(self) -> int:
        return int(self.src.size)

    @property
    def avg_degree(self) -> float:
        return self.num_edges / max(self.num_vertices, 1)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.num_vertices).astype(np.int32)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.num_vertices).astype(np.int32)

    def csr_in(self, return_order: bool = False):
        """CSR over destinations: (indptr, src_indices) sorted by dst.
        ``return_order=True`` also returns the stable edge permutation,
        so per-edge side arrays (e.g. prepared weights) can be carried
        into the same order (see ``repro.core.sampling``)."""
        order = np.argsort(self.dst, kind="stable")
        dsts = self.dst[order]
        indptr = np.zeros(self.num_vertices + 1, np.int64)
        np.add.at(indptr, dsts + 1, 1)
        np.cumsum(indptr, out=indptr)
        if return_order:
            return indptr, self.src[order], order
        return indptr, self.src[order]

    def with_self_loops(self) -> "Graph":
        """GCN aggregates over {v} ∪ N(v); add v->v edges."""
        loops = np.arange(self.num_vertices, dtype=np.int32)
        return Graph(self.num_vertices,
                     np.concatenate([self.src, loops]),
                     np.concatenate([self.dst, loops]),
                     name=self.name + "+self")


def erdos(num_vertices: int, num_edges: int, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_vertices, num_edges, dtype=np.int32)
    dst = rng.integers(0, num_vertices, num_edges, dtype=np.int32)
    return Graph(num_vertices, src, dst, name=f"er-{num_vertices}")
