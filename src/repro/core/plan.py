"""Static communication plans for MultiGCN message passing.

This is the host-side "graph mapping" stage of the paper (§4.3): given a
graph, a torus mesh, a round partition, and a message-passing model, build
the static relay schedule that the SPMD executor (``message_passing.py``)
replays with ``ppermute`` collectives.

Message-passing models (paper §2, §4):
  * ``oppe``            — one put per edge  (Tesseract-style baseline)
  * ``oppr``            — one put per (vertex, destination node) (GraphP)
  * ``oppm``            — one put per multicast (the paper's TMM): one item
                          per vertex, forked along a dimension-ordered tree
Rounds (SREM) are orthogonal: any model can run round-partitioned.

Relay encoding ("sorted-prefix relay"): per phase (= torus dimension), each
node's outgoing items are sorted by descending remaining travel distance H.
At ring hop h only the prefix of items with H >= h is still in flight, so
the ppermute payload at hop h has static length L_h = max over nodes of
|{H >= h}|. A multicast deposit at hop h is a static (mask, slot) pair
into the receiving node's next-phase buffer (or, at the last dimension,
into its replica buffer — the paper's aggregation buffer).

Every byte the executor moves is therefore also countable analytically;
``CommPlan.stats`` carries the counts the cost model cross-checks.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.config import GCNConfig
from repro.core.graph import Graph
from repro.core.partition import RoundPartition, TorusMesh, make_partition


# ---------------------------------------------------------------------------
# Plan containers
# ---------------------------------------------------------------------------


@dataclass
class PhasePlan:
    """Relay schedule for one torus dimension (all rounds stacked).

    With ``bidir`` plans a second relay runs in the -1 ring direction
    (``hop_len_rev``/``dep_rev``...); each item picks the direction with
    the shorter maximum travel — the bidirectional-torus optimization the
    paper's DyXY routing gets for free and our unidirectional baseline
    deliberately omitted (see EXPERIMENTS.md §Perf, GCN cell)."""

    dim_size: int
    capacity: int  # origination buffer length C0 (max over nodes & rounds)
    hop_len: list[int]  # L_h for h = 1..dim_size-1 (static, max over rounds)
    # deposit schedule: at hop h node n takes masked rows into next buffer
    dep: np.ndarray  # (R, N, dim_size-1, Lmax) bool
    dep_slot: np.ndarray  # (R, N, dim_size-1, Lmax) int32
    # local (h=0) copies: obuf_k[src] -> next buffer [dst]
    lc_src: np.ndarray  # (R, N, CL) int32
    lc_dst: np.ndarray  # (R, N, CL) int32
    lc_valid: np.ndarray  # (R, N, CL) bool
    # reverse-direction relay (bidir plans; empty hop_len_rev otherwise)
    hop_len_rev: list[int] = field(default_factory=list)
    dep_rev: np.ndarray | None = None
    dep_slot_rev: np.ndarray | None = None
    # direction-split duplication copies within this phase's buffer
    dup: tuple | None = None  # (dup_src, dup_dst, dup_valid) (R, N, CD)
    cap_fwd: int = 0  # forward-section length (== capacity when not bidir)


@dataclass
class CommPlan:
    mesh: TorusMesh
    part: RoundPartition
    model: str
    num_rounds: int
    # phase-0 originations: rows of the node-local feature table
    orig_rows: np.ndarray  # (R, N, C0) int32
    orig_valid: np.ndarray  # (R, N, C0) bool
    phases: list[PhasePlan]
    replica_rows: int
    # local source vertices copied straight into the replica buffer
    repl_lc_src: np.ndarray  # (R, N, CRL) int32 rows of local feature table
    repl_lc_dst: np.ndarray  # (R, N, CRL) int32 replica rows
    repl_lc_valid: np.ndarray  # (R, N, CRL) bool
    # aggregation edge list (COO into the replica buffer)
    edge_repl: np.ndarray  # (R, N, E) int32
    edge_slot: np.ndarray  # (R, N, E) int32  (destination slot in round)
    edge_w: np.ndarray  # (R, N, E) float32 (0 = invalid)
    stats: dict = field(default_factory=dict)

    @property
    def num_nodes(self) -> int:
        return self.mesh.num_nodes


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


class _Item:
    """One in-flight payload during planning."""

    __slots__ = ("src_ref", "dests", "H", "children", "slot", "repl_rows",
                 "dir", "dup_of")

    def __init__(self, src_ref: int, dests):
        self.src_ref = src_ref  # slot in previous buffer (or feat row, ph 0)
        self.dests = dests  # list of destination node ids
        self.H = 0
        self.children = []  # (hop h, node, child) — child=_Item or ("repl", row)
        self.slot = -1
        self.repl_rows: dict[int, int] = {}
        self.dir = 0  # 0 = +1 ring, 1 = -1 ring (bidir plans)
        self.dup_of: "_Item | None" = None  # sibling created by a dir split


def _expand_groups(mesh, my, k, ndim, groups, it, phase_items, stats, bidir):
    """Expand one item's coord groups along dim k in its chosen direction."""
    Dk = mesh.dims[k]
    H = 0
    for c, dn_list in groups.items():
        h = int((c - my[k]) % Dk) if it.dir == 0 else int((my[k] - c) % Dk)
        H = max(H, h)
        child_coords = my.copy()
        child_coords[k] = c
        child_node = int(mesh.node_id(tuple(child_coords)))
        if k == ndim - 1:
            assert len(dn_list) == 1 and dn_list[0] == child_node
            row = it.repl_rows[child_node]
            it.children.append((h, child_node, ("repl", row)))
            stats["deposits"] += 1
        else:
            ch = _Item(-1, dn_list)
            ch.repl_rows = it.repl_rows
            it.children.append((h, child_node, ch))
            phase_items[k + 1][child_node].append(ch)
    it.H = H
    stats["items"] += 1
    stats["link_feat_hops"] += H


def build_plan(cfg: GCNConfig, graph: Graph, mesh: TorusMesh,
               part: RoundPartition | None = None,
               edge_weights: np.ndarray | None = None,
               bidir: bool = False) -> CommPlan:
    part = part or make_partition(cfg, mesh.num_nodes)
    N = mesh.num_nodes
    R = part.num_rounds
    model = cfg.message_passing
    ndim = len(mesh.dims)

    src, dst = graph.src, graph.dst
    w = edge_weights if edge_weights is not None else np.ones(src.size, np.float32)
    src_node = part.node_of(src)
    dst_node = part.node_of(dst)
    dst_round = np.minimum(part.round_of(dst), R - 1)
    dst_slot = part.slot_of(dst)
    src_row = part.local_index(src)

    all_coords = np.stack(mesh.coords(np.arange(N)), axis=1)  # (N, ndim)

    # ---------------- per-round item construction ----------------
    rounds_phase_items: list[list[list[list[_Item]]]] = []  # [r][k][n] -> items
    rounds_repl_lc: list[list[list[tuple[int, int]]]] = []  # [r][n] -> (feat_row, repl_row)
    rounds_edges: list[list[list[tuple[int, int, float]]]] = []  # [r][n] -> (repl_row, slot, w)
    repl_count = np.zeros((R, N), np.int64)
    stats = {"items": 0, "deposits": 0, "link_feat_hops": 0, "local_copies": 0}

    # group edges by round
    order = np.argsort(dst_round, kind="stable")
    bounds = np.searchsorted(dst_round[order], np.arange(R + 1))

    for r in range(R):
        sel = order[bounds[r]:bounds[r + 1]]
        phase_items: list[list[list[_Item]]] = [
            [[] for _ in range(N)] for _ in range(ndim)]
        repl_lc: list[list[tuple[int, int]]] = [[] for _ in range(N)]
        edges: list[list[tuple[int, int, float]]] = [[] for _ in range(N)]

        # replica row allocation per (origin item, dst node) — dict per node
        def alloc_repl(n: int) -> int:
            row = int(repl_count[r, n])
            repl_count[r, n] += 1
            return row

        # organize edges: (src vertex, dst node) -> dst slots
        if sel.size:
            s_, d_, dn_, ds_, w_, sr_, sn_ = (
                src[sel], dst[sel], dst_node[sel], dst_slot[sel], w[sel],
                src_row[sel], src_node[sel])
        else:
            s_ = d_ = dn_ = ds_ = sn_ = np.zeros(0, np.int32)
            w_ = np.zeros(0, np.float32)
            sr_ = np.zeros(0, np.int64)

        if model == "oppe":
            # one item per cut edge; local edges copy directly
            for i in range(s_.size):
                n_s, n_d = int(sn_[i]), int(dn_[i])
                if n_s == n_d:
                    row = alloc_repl(n_d)
                    repl_lc[n_d].append((int(sr_[i]), row))
                    edges[n_d].append((row, int(ds_[i]), float(w_[i])))
                else:
                    it = _Item(int(sr_[i]), [n_d])
                    phase_items[0][n_s].append(it)
                    row = alloc_repl(n_d)
                    it.repl_rows = {n_d: row}
                    edges[n_d].append((row, int(ds_[i]), float(w_[i])))
        else:
            # group by (src vertex, ...) for dedup
            key = s_.astype(np.int64) * N + dn_
            gorder = np.argsort(key, kind="stable")
            ks = key[gorder]
            # iterate groups of identical (src, dst_node); an edgeless
            # round (padded sampled subgraphs) has no groups at all
            grp_bounds = np.flatnonzero(
                np.concatenate([[True], ks[1:] != ks[:-1], [True]])) \
                if ks.size else np.zeros(1, np.int64)
            # per (src vertex): collect (dst node -> [(slot, w)])
            per_vertex: dict[int, dict[int, list[tuple[int, float]]]] = {}
            for gi in range(grp_bounds.size - 1):
                lo, hi = grp_bounds[gi], grp_bounds[gi + 1]
                idxs = gorder[lo:hi]
                u = int(s_[idxs[0]])
                nd = int(dn_[idxs[0]])
                per_vertex.setdefault(u, {})[nd] = [
                    (int(ds_[j]), float(w_[j])) for j in idxs]
            for u, node_map in per_vertex.items():
                n_s = int(part.node_of(u))
                u_row = int(part.local_index(u))
                # local destinations: direct replica copy
                if n_s in node_map:
                    row = alloc_repl(n_s)
                    repl_lc[n_s].append((u_row, row))
                    for slot, ww in node_map[n_s]:
                        edges[n_s].append((row, slot, ww))
                remote = sorted(nd for nd in node_map if nd != n_s)
                if not remote:
                    continue
                repl_rows = {}
                for nd in remote:
                    row = alloc_repl(nd)
                    repl_rows[nd] = row
                    for slot, ww in node_map[nd]:
                        edges[nd].append((row, slot, ww))
                if model == "oppm":
                    it = _Item(u_row, remote)
                    it.repl_rows = repl_rows  # type: ignore[attr-defined]
                    phase_items[0][n_s].append(it)
                else:  # oppr: unicast per destination node
                    for nd in remote:
                        it = _Item(u_row, [nd])
                        it.repl_rows = {nd: repl_rows[nd]}  # type: ignore[attr-defined]
                        phase_items[0][n_s].append(it)

        # ---------------- multicast tree expansion per phase ----------------
        for k in range(ndim):
            Dk = mesh.dims[k]
            for n in range(N):
                my = all_coords[n]
                items_here = list(phase_items[k][n])  # splits append below
                for it in items_here:
                    dest_coords = all_coords[np.asarray(it.dests)]
                    groups: dict[int, list[int]] = {}
                    for dnode, dc in zip(it.dests, dest_coords):
                        groups.setdefault(int(dc[k]), []).append(int(dnode))
                    if bidir:
                        fwd = {c: g for c, g in groups.items()
                               if (c - my[k]) % Dk <= (my[k] - c) % Dk}
                        bwd = {c: g for c, g in groups.items() if c not in fwd}
                        if fwd and bwd:
                            # direction split: sibling item carries the
                            # backward-going share of the payload
                            sib = _Item(it.src_ref, sorted(
                                d for g in bwd.values() for d in g))
                            sib.repl_rows = it.repl_rows
                            sib.dir = 1
                            sib.dup_of = it
                            phase_items[k][n].append(sib)
                            _expand_groups(mesh, my, k, ndim, bwd, sib,
                                           phase_items, stats, bidir)
                            it.dests = sorted(
                                d for g in fwd.values() for d in g)
                            groups = fwd
                        elif bwd:
                            it.dir = 1
                            groups = bwd
                    _expand_groups(mesh, my, k, ndim, groups, it,
                                   phase_items, stats, bidir)

        rounds_phase_items.append(phase_items)
        rounds_repl_lc.append(repl_lc)
        rounds_edges.append(edges)
        stats["local_copies"] += sum(len(l) for l in repl_lc)

    # ---------------- flatten into static arrays ----------------
    # per (round, phase, node): forward items (sorted desc H) occupy the
    # buffer prefix; backward items the section after the static split
    # point C_fwd (max forward count) — both sections keep the prefix
    # property for their own relay direction.
    cap_fwd = [1] * ndim
    cap_bwd = [0] * ndim
    for r in range(R):
        for k in range(ndim):
            for n in range(N):
                items = rounds_phase_items[r][k][n]
                f = sum(1 for it in items if it.dir == 0)
                cap_fwd[k] = max(cap_fwd[k], f)
                cap_bwd[k] = max(cap_bwd[k], len(items) - f)
    for r in range(R):
        for k in range(ndim):
            for n in range(N):
                items = rounds_phase_items[r][k][n]
                fwd = sorted((it for it in items if it.dir == 0),
                             key=lambda it: -it.H)
                bwd = sorted((it for it in items if it.dir == 1),
                             key=lambda it: -it.H)
                for pos, it in enumerate(fwd):
                    it.slot = pos
                for pos, it in enumerate(bwd):
                    it.slot = cap_fwd[k] + pos

    caps = [cap_fwd[k] + cap_bwd[k] for k in range(ndim)]
    C0 = caps[0]
    orig_rows = np.zeros((R, N, C0), np.int32)
    orig_valid = np.zeros((R, N, C0), bool)
    for r in range(R):
        for n in range(N):
            for it in rounds_phase_items[r][0][n]:
                orig_rows[r, n, it.slot] = it.src_ref
                orig_valid[r, n, it.slot] = True

    phases: list[PhasePlan] = []
    for k in range(ndim):
        Dk = mesh.dims[k]

        def _hop_lens(direction: int) -> list[int]:
            out = []
            for h in range(1, Dk):
                L = 0
                for r in range(R):
                    for n in range(N):
                        L = max(L, sum(
                            1 for it in rounds_phase_items[r][k][n]
                            if it.dir == direction and it.H >= h))
                out.append(L)
            return out

        hop_len = _hop_lens(0)
        hop_len_rev = _hop_lens(1) if bidir else []
        Lmax = max(hop_len) if hop_len else 0
        Lmax_r = max(hop_len_rev) if hop_len_rev else 0
        dep = np.zeros((R, N, max(Dk - 1, 1), max(Lmax, 1)), bool)
        dep_slot = np.zeros((R, N, max(Dk - 1, 1), max(Lmax, 1)), np.int32)
        dep_r = np.zeros((R, N, max(Dk - 1, 1), max(Lmax_r, 1)), bool)
        dep_slot_r = np.zeros((R, N, max(Dk - 1, 1), max(Lmax_r, 1)), np.int32)
        lc: list[list[tuple[int, int]]] = [[] for _ in range(R * N)]
        for r in range(R):
            for n in range(N):
                for it in rounds_phase_items[r][k][n]:
                    for (h, child_node, child) in it.children:
                        tgt = (child.slot if not isinstance(child, tuple)
                               else child[1])
                        if h == 0:
                            lc[r * N + n].append((it.slot, tgt))
                        elif it.dir == 0:
                            dep[r, child_node, h - 1, it.slot] = True
                            dep_slot[r, child_node, h - 1, it.slot] = tgt
                        else:
                            row = it.slot - cap_fwd[k]
                            dep_r[r, child_node, h - 1, row] = True
                            dep_slot_r[r, child_node, h - 1, row] = tgt
        CL = max(1, max(len(x) for x in lc))
        lc_src = np.zeros((R, N, CL), np.int32)
        lc_dst = np.zeros((R, N, CL), np.int32)
        lc_valid = np.zeros((R, N, CL), bool)
        for r in range(R):
            for n in range(N):
                for j, (s0, d0) in enumerate(lc[r * N + n]):
                    lc_src[r, n, j] = s0
                    lc_dst[r, n, j] = d0
                    lc_valid[r, n, j] = True
        phases.append(PhasePlan(Dk, caps[k], hop_len, dep, dep_slot,
                                lc_src, lc_dst, lc_valid,
                                hop_len_rev=hop_len_rev, dep_rev=dep_r,
                                dep_slot_rev=dep_slot_r,
                                cap_fwd=cap_fwd[k]))

    # dup copies: phase k>0 direction-split siblings (obuf_k internal)
    for k in range(1, ndim):
        dups: list[list[tuple[int, int]]] = [[] for _ in range(R * N)]
        for r in range(R):
            for n in range(N):
                for it in rounds_phase_items[r][k][n]:
                    if it.dup_of is not None:
                        dups[r * N + n].append((it.dup_of.slot, it.slot))
        CD = max(1, max(len(x) for x in dups))
        dup_src = np.zeros((R, N, CD), np.int32)
        dup_dst = np.zeros((R, N, CD), np.int32)
        dup_valid = np.zeros((R, N, CD), bool)
        for r in range(R):
            for n in range(N):
                for j, (s0, d0) in enumerate(dups[r * N + n]):
                    dup_src[r, n, j] = s0
                    dup_dst[r, n, j] = d0
                    dup_valid[r, n, j] = True
        phases[k].dup = (dup_src, dup_dst, dup_valid)

    replica_rows = int(repl_count.max()) if repl_count.size else 1
    CRL = max(1, max(len(l) for r in range(R) for l in rounds_repl_lc[r]))
    repl_lc_src = np.zeros((R, N, CRL), np.int32)
    repl_lc_dst = np.zeros((R, N, CRL), np.int32)
    repl_lc_valid = np.zeros((R, N, CRL), bool)
    for r in range(R):
        for n in range(N):
            for j, (s0, d0) in enumerate(rounds_repl_lc[r][n]):
                repl_lc_src[r, n, j] = s0
                repl_lc_dst[r, n, j] = d0
                repl_lc_valid[r, n, j] = True

    Emax = max(1, max(len(e) for r in range(R) for e in rounds_edges[r]))
    edge_repl = np.zeros((R, N, Emax), np.int32)
    edge_slot = np.zeros((R, N, Emax), np.int32)
    edge_w = np.zeros((R, N, Emax), np.float32)
    for r in range(R):
        for n in range(N):
            for j, (row, slot, ww) in enumerate(rounds_edges[r][n]):
                edge_repl[r, n, j] = row
                edge_slot[r, n, j] = slot
                edge_w[r, n, j] = ww

    # executor byte accounting (per feature element, x4 bytes x feat later)
    exec_slots = 0
    for k, ph in enumerate(phases):
        exec_slots += (sum(ph.hop_len) + sum(ph.hop_len_rev)) * N * R
    stats["executor_feat_slots"] = exec_slots  # includes SPMD padding
    stats["replica_rows"] = replica_rows
    stats["num_rounds"] = R
    # aggregation (Compute step) edge accounting: valid COO entries vs the
    # padded slots the dense scatter backend actually streams — the basis
    # of the engine's dense-vs-ELL memory-traffic comparison
    stats["agg_edges"] = int(np.count_nonzero(edge_w))
    stats["agg_edge_slots_padded"] = int(edge_w.size)  # R * N * Emax
    stats["agg_acc_slots"] = R * N * part.slots_per_round

    return CommPlan(mesh, part, model, R, orig_rows, orig_valid, phases,
                    max(replica_rows, 1), repl_lc_src, repl_lc_dst,
                    repl_lc_valid, edge_repl, edge_slot, edge_w, stats)


# ---------------------------------------------------------------------------
# Capacity bucketing (sampled mini-batch plans)
# ---------------------------------------------------------------------------


def _ceil_pow2(n: int) -> int:
    n = int(n)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _pad_last(a: np.ndarray, length: int, fill=0) -> np.ndarray:
    if a.shape[-1] >= length:
        return a
    pad = [(0, 0)] * (a.ndim - 1) + [(0, length - a.shape[-1])]
    return np.pad(a, pad, constant_values=fill)


def pad_plan_pow2(plan: CommPlan) -> CommPlan:
    """Round every content-derived capacity of ``plan`` up to a power of
    two: buffer capacities, per-hop relay prefix lengths, replica rows,
    local-copy widths and the aggregation edge-slot count.

    The padding is pure dead weight to the executor — padded origination
    slots are invalid (zero contribution), padded relay rows are never
    deposited (masks stay False), padded edges carry weight 0 — so the
    replayed result is bit-identical to the unpadded plan. What it buys
    is *shape- and statics-stability*: two plans padded to the same
    buckets produce equal :class:`~repro.core.message_passing.
    ExchangeStatics` (the static values baked into the jitted executor)
    whenever their bucketed capacities agree, which is what lets the
    sampled mini-batch trainer reuse ONE compiled train step across
    different same-sized subgraphs instead of recompiling per batch
    (mirroring ``forward_batched``'s power-of-two request bucketing).

    Only unidirectional plans are supported (the sampled path never
    builds bidir plans); partition/round structure is untouched — bucket
    the vertex count BEFORE planning to align those.
    """
    if any(ph.hop_len_rev for ph in plan.phases):
        raise ValueError("pad_plan_pow2 supports unidirectional plans only")
    R, N = plan.num_rounds, plan.num_nodes
    phases: list[PhasePlan] = []
    for ph in plan.phases:
        cap = _ceil_pow2(ph.capacity)
        # pad hop prefixes, preserving the relay invariants: each L_h is
        # a power of two, <= the buffer it slices (cap, then the
        # previous hop's length), and once zero stays zero
        hop_len, prev = [], cap
        for L in ph.hop_len:
            L = min(_ceil_pow2(L), prev) if L else 0
            hop_len.append(L)
            prev = L if L else prev
        Lmax = max(max(hop_len, default=0), 1)
        CL = _ceil_pow2(ph.lc_src.shape[-1])
        padded = PhasePlan(
            ph.dim_size, cap, hop_len,
            _pad_last(ph.dep, Lmax, False), _pad_last(ph.dep_slot, Lmax),
            _pad_last(ph.lc_src, CL), _pad_last(ph.lc_dst, CL),
            _pad_last(ph.lc_valid, CL, False),
            cap_fwd=cap)
        if ph.dup is not None:  # phases k >= 1 carry (possibly all-
            ds, dd, dv = ph.dup  # invalid) direction-split copy tables
            CD = _ceil_pow2(ds.shape[-1])
            padded.dup = (_pad_last(ds, CD), _pad_last(dd, CD),
                          _pad_last(dv, CD, False))
        phases.append(padded)
    C0 = phases[0].capacity
    replica_rows = _ceil_pow2(plan.replica_rows)
    CRL = _ceil_pow2(plan.repl_lc_src.shape[-1])
    E = _ceil_pow2(plan.edge_repl.shape[-1])
    stats = dict(plan.stats)
    stats["replica_rows"] = replica_rows
    stats["agg_edge_slots_padded"] = R * N * E
    stats["executor_feat_slots"] = sum(
        sum(ph.hop_len) * N * R for ph in phases)
    return CommPlan(
        plan.mesh, plan.part, plan.model, R,
        _pad_last(plan.orig_rows, C0), _pad_last(plan.orig_valid, C0, False),
        phases, replica_rows,
        _pad_last(plan.repl_lc_src, CRL), _pad_last(plan.repl_lc_dst, CRL),
        _pad_last(plan.repl_lc_valid, CRL, False),
        _pad_last(plan.edge_repl, E), _pad_last(plan.edge_slot, E),
        _pad_last(plan.edge_w, E, 0.0), stats)
