"""One-put-per-multicast applied to MoE expert-parallel dispatch.

The paper's insight transfers directly: a token routed to top-k experts is
a vertex whose "neighbors" are experts; with experts sharded over the
"model" axis, several of a token's experts often co-reside on one shard.
The baseline all-to-all ships one activation copy per (token, expert) —
the OPPE pattern. The OPPM dispatch ships one copy per (token,
destination shard) and shares it among that shard's experts — the paper's
"one replica shared by all neighbors in the processing node".

Executable via shard_map over the "model" axis:
  1. route: top-k experts per token (local tokens)
  2. dedup: sort each token's shard list, keep first occurrences
  3. pack per-destination send buffers (capacity-padded)
  4. all_to_all (the torus multicast degenerates to A2A here because every
     shard pair exchanges — the dedup is where the paper's savings live)
  5. local second-level dispatch to this shard's experts (one replica,
     many experts), expert FFN, weighted partial sums
  6. reverse all_to_all, combine at the origin.

``dispatch_stats`` reports the measured byte savings (deduped vs per-pair)
— benchmarked in benchmarks/moe_dispatch_bench.py against deepseek's
64-expert top-6 routing where the savings are largest.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LMConfig
from repro.nn.layers import ffn_apply
from repro.nn import moe as moe_lib


@dataclass(frozen=True)
class EPConfig:
    axis: str = "model"
    num_shards: int = 1
    capacity_factor: float = 1.5
    dedup: bool = True  # False -> OPPE-style per-(token, expert) baseline


def _dedup_shards(shard_ids: jax.Array, dedup: bool):
    """shard_ids: (T, K). Returns (ids, keep_mask) with duplicates (same
    token -> same shard) masked when dedup is on."""
    if not dedup:
        return shard_ids, jnp.ones_like(shard_ids, bool)
    s = jnp.sort(shard_ids, axis=1)
    first = jnp.concatenate(
        [jnp.ones_like(s[:, :1], bool), s[:, 1:] != s[:, :-1]], axis=1)
    return s, first


def ep_moe_apply(cfg: LMConfig, ep: EPConfig, p, x):
    """Expert-parallel MoE layer body — call inside shard_map over ep.axis.

    p: local expert weights {w_gate,w_up,w_down: (E_local, d, ff)} +
       router (d, E) replicated.
    x: (T_loc, D) local tokens. Returns (y (T_loc, D), stats dict).
    """
    T, D = x.shape
    S = ep.num_shards
    E = cfg.num_experts
    E_loc = E // S
    K = cfg.top_k

    logits = x.astype(jnp.float32) @ p["router"]
    gates, experts, aux = moe_lib.route(cfg, logits)  # (T,K)
    shard_of = experts // E_loc  # (T,K)

    # ---- dedup per (token, shard): one replica per destination shard ----
    sorted_shards, keep = _dedup_shards(shard_of, ep.dedup)
    # capacity per destination shard
    cap = int(ep.capacity_factor * T * K / S)
    cap = max(8, -(-cap // 8) * 8)

    # duplicates (same token -> same shard) masked to sentinel shard S;
    # dispatch over S+1 "experts" whose overflow row is S+1
    flat_dst = jnp.where(keep, sorted_shards, S).reshape(T, K)
    dest_e, dest_r, kept = moe_lib.dispatch_indices(flat_dst, S + 1, cap)
    tok_idx = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    send = jnp.zeros((S + 2, cap, D), x.dtype).at[dest_e, dest_r].set(x[tok_idx])
    send_tok = jnp.full((S + 2, cap), -1, jnp.int32).at[dest_e, dest_r].set(tok_idx)

    # replica row of token t on shard s (only kept first-occurrences land
    # in columns < S; duplicates/overflow land in sentinel columns)
    rep_row = jnp.zeros((T, S + 2), jnp.int32).at[tok_idx, dest_e].set(dest_r)
    exists = jnp.zeros((T, S + 2), jnp.int32).at[tok_idx, dest_e].add(1)
    # per-replica gate rows: every (t,k) adds its gate to the SHARED
    # replica of (t, shard_of[t,k]) at its local expert column — the
    # paper's "one replica shared by all neighbors on the node"
    row_tk = rep_row[jnp.arange(T)[:, None], shard_of]  # (T, K)
    ok_tk = exists[jnp.arange(T)[:, None], shard_of] > 0
    gate_rows = jnp.zeros((S + 2, cap, E_loc), jnp.float32).at[
        shard_of.reshape(-1), row_tk.reshape(-1),
        (experts % E_loc).reshape(-1)].add(
        (gates * ok_tk).reshape(-1))
    send, gate_rows, send_tok = send[:S], gate_rows[:S], send_tok[:S]

    # ---- exchange ----
    recv = jax.lax.all_to_all(send, ep.axis, 0, 0, tiled=False)
    recv_gates = jax.lax.all_to_all(gate_rows, ep.axis, 0, 0, tiled=False)
    # recv: (S, cap, D) — tokens from every source shard

    # ---- local expert compute: one replica serves all local experts ----
    xr = recv.reshape(S * cap, D)
    gr = recv_gates.reshape(S * cap, E_loc)
    h_g = jnp.einsum("td,edf->etf", xr, p["w_gate"].astype(xr.dtype))
    h_u = jnp.einsum("td,edf->etf", xr, p["w_up"].astype(xr.dtype))
    h = jax.nn.silu(h_g) * h_u
    out_e = jnp.einsum("etf,efd->etd", h, p["w_down"].astype(xr.dtype))
    # weighted combine over local experts per replica
    part = jnp.einsum("etd,te->td", out_e.astype(jnp.float32), gr)
    part = part.reshape(S, cap, D)

    # ---- return partials to origins ----
    # A2A is symmetric: back[s, c] is the partial result for MY send row
    # (s, c), so the local send_tok gives the reverse-scatter indices.
    back = jax.lax.all_to_all(part.astype(x.dtype), ep.axis, 0, 0,
                              tiled=False)
    flat_back = back.reshape(S * cap, D).astype(jnp.float32)
    flat_tok = send_tok.reshape(S * cap)
    y = jnp.zeros((T + 1, D), jnp.float32).at[
        jnp.where(flat_tok >= 0, flat_tok, T)].add(flat_back)
    y = y[:T]

    if "shared" in p:
        y = y + ffn_apply(cfg, p["shared"], x).astype(jnp.float32)

    sent_replicas = jnp.sum(kept & (dest_e < S))  # real cross-shard copies
    stats = {
        "aux": aux,
        "replicas": sent_replicas,
        "naive_replicas": jnp.asarray(T * K, jnp.int32),
        "bytes_saved_frac": 1.0 - sent_replicas / (T * K),
    }
    return y.astype(x.dtype), stats


def dispatch_stats(cfg: LMConfig, num_shards: int, tokens: int,
                   seed: int = 0) -> dict:
    """Analytical/Monte-Carlo measurement of OPPM dedup savings for an
    arch's routing shape (used by the MoE dispatch benchmark)."""
    rng = np.random.default_rng(seed)
    E, K = cfg.num_experts, cfg.top_k
    E_loc = E // num_shards
    # uniform routing (trained routers are flatter than random — this is
    # the conservative case for dedup savings)
    picks = np.stack([rng.choice(E, size=K, replace=False)
                      for _ in range(tokens)])
    shards = picks // E_loc
    dedup = sum(len(set(row)) for row in shards)
    return {
        "tokens": tokens,
        "per_edge_replicas": tokens * K,  # OPPE baseline
        "per_shard_replicas": int(dedup),  # OPPM
        "savings": 1.0 - dedup / (tokens * K),
    }
