"""Typed configuration system for the repro framework.

Every architecture in ``repro.configs`` produces an :class:`LMConfig` (or
:class:`GCNConfig` for the paper's own graph workloads) via two factory
functions: ``full()`` (the exact published configuration, exercised only by
the compile-only dry-run) and ``smoke()`` (a reduced same-family config that
runs a real forward/train step on CPU in tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Sequence

# --------------------------------------------------------------------------
# Per-layer block description
# --------------------------------------------------------------------------

MixerKind = Literal["gqa", "mla", "mamba2", "wkv6", "none"]
FFNKind = Literal["dense", "moe", "none"]


@dataclass(frozen=True)
class BlockSpec:
    """One transformer block = token mixer + channel FFN."""

    mixer: MixerKind = "gqa"
    ffn: FFNKind = "dense"
    # zamba2-style shared-weight attention block applied alongside this layer
    shared_attn: bool = False


# --------------------------------------------------------------------------
# Model configs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class LMConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # block layout; empty -> num_layers x BlockSpec(default_mixer, default_ffn)
    blocks: tuple[BlockSpec, ...] = ()
    default_mixer: MixerKind = "gqa"
    default_ffn: FFNKind = "dense"

    # attention details
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # 0 -> full causal attention
    # MLA (deepseek)
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM / linear attention
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    wkv_head_dim: int = 64
    # chunk sizes (perf levers: interior working set ~ S*chunk per layer)
    ssm_chunk: int = 128
    wkv_chunk: int = 64

    # encoder-decoder (whisper)
    encoder_layers: int = 0  # >0 -> enc-dec; num_layers = decoder layers
    encoder_seq_len: int = 1500  # whisper frame count after conv frontend

    # modality frontend stub: inputs carry precomputed embeddings
    frontend: Literal["none", "audio_stub", "patch_stub"] = "none"
    frontend_seq_len: int = 0  # patches/frames prepended to the text stream

    # numerics / structure
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["swiglu", "gelu", "relu2"] = "swiglu"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # citation tier from the assignment table
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.blocks:
            object.__setattr__(
                self,
                "blocks",
                tuple(
                    BlockSpec(self.default_mixer, self.default_ffn)
                    for _ in range(self.num_layers)
                ),
            )
        assert len(self.blocks) == self.num_layers, (
            f"{self.name}: blocks={len(self.blocks)} != num_layers={self.num_layers}"
        )

    # ---------------- derived quantities ----------------
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def uses_attention(self) -> bool:
        return any(b.mixer in ("gqa", "mla") or b.shared_attn for b in self.blocks)

    @property
    def pure_full_attention(self) -> bool:
        """True when every mixer is unwindowed softmax attention
        (-> long_500k is skipped per the assignment)."""
        return (
            all(b.mixer in ("gqa", "mla") for b in self.blocks)
            and self.sliding_window == 0
        )

    def param_count(self) -> int:
        """Analytic total parameter count (used for 6ND roofline checks)."""
        d, h = self.d_model, self.head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        v_head = self.v_head_dim or h
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        if self.is_encdec:
            total += self.encoder_layers * self._attn_params() + (
                self.encoder_layers * self._ffn_params("dense")
            )

        for b in self.blocks:
            if b.mixer == "gqa":
                total += self._attn_params()
            elif b.mixer == "mla":
                total += self._mla_params()
            elif b.mixer == "mamba2":
                total += self._mamba_params()
            elif b.mixer == "wkv6":
                total += self._wkv_params()
            if b.shared_attn:
                pass  # shared weights counted once below
            total += self._ffn_params(b.ffn)
            total += 2 * d  # norms
        if any(b.shared_attn for b in self.blocks):
            total += self._attn_params() + self._ffn_params("dense") + 2 * self.d_model
        if self.is_encdec:  # cross attention in each decoder layer
            total += self.num_layers * self._attn_params()
        return total

    def _attn_params(self) -> int:
        d, h = self.d_model, self.head_dim
        return d * (self.num_heads * h) + 2 * d * (self.num_kv_heads * h) + (
            self.num_heads * h
        ) * d

    def _mla_params(self) -> int:
        d = self.d_model
        r = self.kv_lora_rank
        qk = self.qk_nope_dim + self.qk_rope_dim
        n = self.num_heads
        return (
            d * n * qk  # q proj (no q-lora in v2-lite)
            + d * (r + self.qk_rope_dim)  # kv down
            + r * n * (self.qk_nope_dim + self.v_head_dim)  # kv up
            + n * self.v_head_dim * d  # o proj
        )

    def _mamba_params(self) -> int:
        d = self.d_model
        d_in = self.ssm_expand * d
        nh = d_in // self.ssm_head_dim
        # in_proj covers z, x, B, C, dt  (mamba2 fused projection)
        return (
            d * (2 * d_in + 2 * self.ssm_state + nh)
            + d_in * d  # out proj
            + self.ssm_conv_width * (d_in + 2 * self.ssm_state)
            + 2 * nh  # A, D
        )

    def _wkv_params(self) -> int:
        d = self.d_model
        # r, k, v, g, w projections + output
        return 5 * d * d + d * d

    def _ffn_params(self, kind: str) -> int:
        d = self.d_model
        n_mat = 3 if self.act == "swiglu" else 2
        if kind == "dense":
            return n_mat * d * self.d_ff
        if kind == "moe":
            p = self.num_experts * n_mat * d * self.moe_d_ff
            p += self.num_shared_experts * n_mat * d * self.moe_d_ff
            p += d * self.num_experts  # router
            return p
        return 0

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts top_k + shared experts)."""
        if self.num_experts == 0:
            return self.param_count()
        total = self.param_count()
        n_mat = 3 if self.act == "swiglu" else 2
        per_expert = n_mat * self.d_model * self.moe_d_ff
        n_moe_layers = sum(1 for b in self.blocks if b.ffn == "moe")
        inactive = n_moe_layers * (self.num_experts - self.top_k) * per_expert
        return total - inactive


# --------------------------------------------------------------------------
# Input shapes (assigned per-arch shape set)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


LM_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# --------------------------------------------------------------------------
# GCN configs (the paper's own workloads)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class GraphSpec:
    """A graph dataset (Table 3). Real SNAP graphs are represented by
    degree/size-matched RMAT twins in this offline container."""

    name: str
    num_vertices: int
    num_edges: int
    feat_in: int  # |h^0|
    feat_hidden: int  # |h^1|
    avg_degree: float = 0.0
    rmat_seed: int = 0
    synthetic_twin_of: str = ""  # e.g. "Reddit" when degree-matched

    @property
    def topology_bytes(self) -> int:
        return self.num_edges * 4

    @property
    def feature_bytes(self) -> int:
        return self.num_vertices * self.feat_in * 4


@dataclass(frozen=True)
class GCNConfig:
    name: str
    model: Literal["gcn", "gin", "sage"]
    graph: GraphSpec
    num_layers: int = 2
    # message-passing model: oppe | oppr | oppm ; rounds via SREM
    message_passing: Literal["oppe", "oppr", "oppm"] = "oppm"
    use_rounds: bool = True
    agg_buffer_bytes: int = 1 << 20  # paper: 1 MB aggregation buffer
    alpha: float = 0.75  # paper's buffer reservation factor
    # aggregation backend for the executor's Compute step:
    #   "jnp"    — COO scatter-add (portable XLA path)
    #   "pallas" — blocked-ELL indicator-matmul kernel (repro.kernels.spmm);
    #              interpret mode off-TPU, so the same code path runs in tests
    #   "auto"   — "pallas" on TPU, "jnp" elsewhere (resolved at engine build)
    agg_impl: Literal["auto", "jnp", "pallas"] = "auto"
    # ELL layout shape knobs (pallas backend): slot-block height of one
    # accumulator tile and the edge-count alignment of a block row
    ell_block_slots: int = 128
    ell_edge_align: int = 512
    dtype: str = "float32"
    source: str = "MultiGCN paper, Table 3"


# --------------------------------------------------------------------------
# Mesh / hardware description
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants. Defaults = TPU v5e per chip."""

    peak_bf16_flops: float = 197e12
    hbm_bandwidth: float = 819e9
    ici_link_bandwidth: float = 50e9  # per link per direction
    ici_links_per_chip: int = 4  # 2D torus
    hbm_bytes: int = 16 * 1024**3
    vmem_bytes: int = 128 * 1024**2


@dataclass(frozen=True)
class PaperNodeSpec:
    """The paper's processing-node constants (Table 2) for table-for-table
    reproduction inside core/cost_model.py."""

    clock_hz: float = 1e9
    num_nodes: int = 16
    net_bandwidth: float = 600e9  # NVLink-class per node
    net_latency_cycles: int = 500
    hbm_bandwidth: float = 256e9
    peak_ops: float = 8 * 128 * 2 * 1e9  # 8 arrays x 1x128 MAC @ 1GHz
    agg_buffer_bytes: int = 1 << 20
    edge_buffer_bytes: int = 128 << 10
    weight_buffer_bytes: int = 2 << 20
    router_buffer_bytes: int = 3 << 19  # 1.5 MB
    nvlink_pj_per_bit: float = 8.0
    hbm_pj_per_bit: float = 7.0


DEFAULT_HW = HardwareSpec()
PAPER_NODE = PaperNodeSpec()
