"""Architecture registry: ``--arch <id>`` lookup for launchers and tests."""
from __future__ import annotations

from typing import Callable

from .base import GCNConfig, LMConfig, LM_SHAPES, ShapeConfig

_LM_REGISTRY: dict[str, dict[str, Callable[[], LMConfig]]] = {}
_GCN_REGISTRY: dict[str, dict[str, Callable[[], GCNConfig]]] = {}


def register_lm(name: str, *, full: Callable[[], LMConfig], smoke: Callable[[], LMConfig]):
    assert name not in _LM_REGISTRY, f"duplicate arch {name}"
    _LM_REGISTRY[name] = {"full": full, "smoke": smoke}


def register_gcn(name: str, *, full: Callable[[], GCNConfig], smoke: Callable[[], GCNConfig]):
    assert name not in _GCN_REGISTRY, f"duplicate gcn arch {name}"
    _GCN_REGISTRY[name] = {"full": full, "smoke": smoke}


def _ensure_loaded():
    # configs/__init__ registers everything on import
    import repro.configs  # noqa: F401


def get_lm_config(name: str, variant: str = "full") -> LMConfig:
    _ensure_loaded()
    if name not in _LM_REGISTRY:
        raise KeyError(f"unknown LM arch {name!r}; have {sorted(_LM_REGISTRY)}")
    return _LM_REGISTRY[name][variant]()


def get_gcn_config(name: str, variant: str = "full") -> GCNConfig:
    _ensure_loaded()
    if name not in _GCN_REGISTRY:
        raise KeyError(f"unknown GCN arch {name!r}; have {sorted(_GCN_REGISTRY)}")
    return _GCN_REGISTRY[name][variant]()


def list_lm_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_LM_REGISTRY)


def list_gcn_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_GCN_REGISTRY)


def get_shape(name: str) -> ShapeConfig:
    return LM_SHAPES[name]


def lm_cells(include_skipped: bool = False) -> list[tuple[str, str, str]]:
    """All (arch, shape, status) dry-run cells. status in {run, skip:<why>}."""
    _ensure_loaded()
    cells = []
    for arch in list_lm_archs():
        cfg = _LM_REGISTRY[arch]["full"]()
        for shape in LM_SHAPES.values():
            status = "run"
            if shape.name == "long_500k":
                if cfg.is_encdec:
                    status = "skip:enc-dec decoder context << 500k"
                elif cfg.pure_full_attention:
                    status = "skip:pure full attention (assignment: sub-quadratic only)"
            if status == "run" or include_skipped:
                cells.append((arch, shape.name, status))
    return cells
