"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing never touches JAX
device state. The single-pod mesh is a 16x16 slice (256 chips); multi-pod
adds a "pod" axis (2 pods = 512 chips). The GCN runtime treats the same
meshes as tori: ("data", "model") = (X, Y) rings, with "pod" a third ring.
"""
from __future__ import annotations

from repro.core.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (host platform devices)."""
    return make_mesh(shape, axes)


def mesh_axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)
