# NOTE: repro.launch.dryrun must be executed as __main__ (it sets XLA_FLAGS
# before importing jax); import the submodules you need directly.
