"""Step builders + abstract input specs for every (arch x shape) cell.

These are shared by the real launchers (train.py / serve.py) and the
compile-only multi-pod dry-run: the same step function is either executed
on concrete arrays or lowered against the ShapeDtypeStructs returned by
``input_specs``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import LMConfig, ShapeConfig
from repro.models import lm
from repro.nn import transformer as tfm
from repro.sharding import ShardingRules, decode_rules, prefill_rules, train_rules
from repro.train import optimizer as opt


# ---------------------------------------------------------------------------
# Rules per (cfg, shape, mesh)
# ---------------------------------------------------------------------------


def rules_for(cfg: LMConfig, shape: ShapeConfig, mesh,
              sequence_parallel: bool = True) -> ShardingRules:
    multi_pod = "pod" in mesh.axis_names
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    if shape.kind == "train":
        r = train_rules(multi_pod, sequence_parallel=sequence_parallel)
    elif shape.kind == "prefill":
        r = prefill_rules(multi_pod)
    else:
        r = decode_rules(multi_pod)
        if cfg.num_kv_heads >= sizes.get("model", 1) and cfg.uses_attention:
            # enough KV heads to shard them instead of the cache length
            r = r.with_(act_kv_seq=None, act_heads="model")
    if shape.global_batch < dp:
        # e.g. long_500k (batch 1): nothing to shard on the batch axis
        r = r.with_(act_batch=None)
    return r


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------


def batch_struct(cfg: LMConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        S_text = S - (cfg.frontend_seq_len if cfg.frontend == "patch_stub" else 0)
        b = {"tokens": jax.ShapeDtypeStruct((B, S_text), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S_text), jnp.int32)}
        if cfg.is_encdec:
            b["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_seq_len, cfg.d_model), jnp.bfloat16)
        if cfg.frontend == "patch_stub":
            b["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_seq_len, cfg.d_model), jnp.bfloat16)
        return b
    if shape.kind == "prefill":
        S_text = S - (cfg.frontend_seq_len if cfg.frontend == "patch_stub" else 0)
        b = {"tokens": jax.ShapeDtypeStruct((B, S_text), jnp.int32)}
        if cfg.is_encdec:
            b["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_seq_len, cfg.d_model), jnp.bfloat16)
        if cfg.frontend == "patch_stub":
            b["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_seq_len, cfg.d_model), jnp.bfloat16)
        return b
    return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def batch_specs(cfg: LMConfig, shape: ShapeConfig, rules: ShardingRules):
    bspec = rules.spec("act_batch")
    # every input is sharded on its leading (batch) dim only
    return {k: bspec for k in batch_struct(cfg, shape)}


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: LMConfig, rules: ShardingRules | None,
                    opt_cfg: opt.AdamWConfig = opt.AdamWConfig(),
                    impl: str = "auto"):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return lm.lm_loss(cfg, p, batch, rules=rules, impl=impl)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params2, opt2, om = opt.apply_updates(opt_cfg, params, grads, opt_state)
        return params2, opt2, {"loss": loss, **metrics, **om}

    return train_step


def make_prefill_step(cfg: LMConfig, rules: ShardingRules | None,
                      max_len: int, impl: str = "auto"):
    def prefill_step(params, batch):
        tokens = batch["tokens"]
        B = tokens.shape[0]
        memory = None
        if cfg.is_encdec:
            memory = lm.encode(cfg, params, batch["frames"], rules=rules,
                               remat=False)
        state = lm.init_decode_state(cfg, B, max_len, memory=memory)
        last_h, state = lm.prefill(cfg, params, tokens, state, rules=rules,
                                   impl=impl,
                                   extra_embeds=batch.get("patches"))
        W = lm.lm_head_matrix(params.get("head", {}), params["embed"], cfg)
        logits = (last_h @ W.astype(last_h.dtype)).astype(jnp.float32)
        return logits, state

    return prefill_step


def make_decode_step(cfg: LMConfig, rules: ShardingRules | None,
                     impl: str = "auto"):
    def decode_step(params, state, batch):
        return lm.decode_step(cfg, params, batch["token"], state,
                              rules=rules, impl=impl)

    return decode_step


# ---------------------------------------------------------------------------
# Abstract inputs + shardings for the dry-run
# ---------------------------------------------------------------------------


@dataclass
class CellSpec:
    step: Callable  # the function to lower
    args: tuple  # ShapeDtypeStruct pytrees
    in_shardings: tuple  # PartitionSpec pytrees
    out_shardings: Any  # PartitionSpec pytrees or None


def decode_state_struct(cfg: LMConfig, batch: int, max_len: int):
    segs = tfm.segment_layout(cfg)
    caches = tfm.stack_abstract_cache(cfg, segs, batch, max_len)
    memory = None
    if cfg.is_encdec:
        memory = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return lm.DecodeState(caches=caches,
                          pos=jax.ShapeDtypeStruct((), jnp.int32),
                          memory=memory)


def decode_state_specs(cfg: LMConfig, rules: ShardingRules):
    segs = tfm.segment_layout(cfg)
    cspecs = tfm.stack_cache_specs(cfg, segs, rules)
    mem = rules.spec("act_batch") if cfg.is_encdec else None
    return lm.DecodeState(caches=cspecs, pos=P(), memory=mem)


def cache_len_for(cfg: LMConfig, shape: ShapeConfig) -> int:
    if cfg.sliding_window > 0:
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len


def build_cell(cfg: LMConfig, shape: ShapeConfig, mesh,
               sequence_parallel: bool = True, impl: str = "auto",
               opt_cfg: opt.AdamWConfig = opt.AdamWConfig(),
               rule_overrides: dict | None = None) -> CellSpec:
    from repro.sharding.rules import sanitize_tree

    rules = rules_for(cfg, shape, mesh, sequence_parallel)
    if rule_overrides:
        rules = rules.with_(**rule_overrides)
    params_abs = lm.lm_abstract(cfg)
    params_spec = sanitize_tree(params_abs, lm.lm_specs(cfg, rules), mesh)
    b_abs = batch_struct(cfg, shape)
    b_spec = sanitize_tree(b_abs, batch_specs(cfg, shape, rules), mesh)

    if shape.kind == "train":
        step = make_train_step(cfg, rules, opt_cfg, impl)
        opt_abs = opt.abstract_state(params_abs)
        opt_spec = sanitize_tree(opt_abs, opt.state_specs(params_spec), mesh)
        return CellSpec(step, (params_abs, opt_abs, b_abs),
                        (params_spec, opt_spec, b_spec),
                        (params_spec, opt_spec, None))
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, rules, max_len=shape.seq_len, impl=impl)
        st_abs = decode_state_struct(cfg, shape.global_batch,
                                     cache_len_for(cfg, shape))
        st_spec = sanitize_tree(st_abs, decode_state_specs(cfg, rules), mesh)
        logit_spec = sanitize_spec_for_logits(cfg, shape, rules, mesh)
        return CellSpec(step, (params_abs, b_abs), (params_spec, b_spec),
                        (logit_spec, st_spec))
    # decode
    step = make_decode_step(cfg, rules, impl)
    st_abs = decode_state_struct(cfg, shape.global_batch,
                                 cache_len_for(cfg, shape))
    st_spec = sanitize_tree(st_abs, decode_state_specs(cfg, rules), mesh)
    logit_spec = sanitize_spec_for_logits(cfg, shape, rules, mesh)
    return CellSpec(step, (params_abs, st_abs, b_abs),
                    (params_spec, st_spec, b_spec),
                    (logit_spec, st_spec))


def sanitize_spec_for_logits(cfg, shape, rules, mesh):
    from repro.sharding.rules import sanitize_spec

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sanitize_spec(rules.spec("act_batch", "act_vocab"),
                         (shape.global_batch, cfg.vocab_size), sizes)
