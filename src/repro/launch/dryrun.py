import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, prove the sharding is coherent, and save
memory/cost/collective artifacts for the roofline analysis.

MUST set XLA_FLAGS before any other import (jax locks the device count on
first init) — hence the two lines above.

Usage:
  python -m repro.launch.dryrun --sweep                 # all cells, both meshes
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --gcn                   # GCN workload cells
Artifacts land in artifacts/dryrun/<cell>.json (+ .hlo.gz with --save-hlo);
completed cells are skipped unless --force.
"""
import argparse
import gzip
import json
import re
import time
import traceback
from collections import Counter
from pathlib import Path

import jax

ART = Path(os.environ.get("REPRO_ARTIFACTS", "artifacts")) / "dryrun"


def _mesh(kind: str):
    from repro.launch.mesh import make_production_mesh

    return make_production_mesh(multi_pod=(kind == "multipod"))


def cell_name(arch: str, shape: str, mesh_kind: str) -> str:
    return f"{arch}__{shape}__{mesh_kind}"


def collective_histogram(hlo: str) -> dict:
    ops = re.findall(
        r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)\b", hlo)
    return dict(Counter(ops))


def run_lm_cell(arch: str, shape_name: str, mesh_kind: str,
                save_hlo: bool = True, seq_par: bool = True,
                overrides: dict | None = None,
                rule_overrides: dict | None = None) -> dict:
    import dataclasses

    from repro.config import get_lm_config, get_shape
    from repro.launch.steps import build_cell
    from jax.sharding import NamedSharding

    cfg = get_lm_config(arch)
    if overrides:
        typed = {}
        for k, v in overrides.items():
            cur = getattr(cfg, k)
            typed[k] = type(cur)(v) if cur is not None else v
        cfg = dataclasses.replace(cfg, **typed)
    shape = get_shape(shape_name)
    mesh = _mesh(mesh_kind)
    cell = build_cell(cfg, shape, mesh, sequence_parallel=seq_par,
                      rule_overrides=rule_overrides)

    def ns(spec):
        return NamedSharding(mesh, spec)

    in_sh = jax.tree.map(ns, cell.in_shardings,
                         is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    out_sh = jax.tree.map(ns, cell.out_shardings,
                          is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)) \
        if cell.out_shardings is not None else None

    from repro.core.jax_compat import set_mesh

    t0 = time.time()
    with set_mesh(mesh):
        jitted = jax.jit(cell.step, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    from repro.core.jax_compat import cost_analysis

    ma = compiled.memory_analysis()
    ca = cost_analysis(compiled)
    hlo = compiled.as_text()
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "kind": shape.kind,
        "num_devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        "cost": {k: ca.get(k) for k in ("flops", "bytes accessed")},
        "collectives": collective_histogram(hlo),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    return rec, hlo


def run_gcn_cell(arch: str, mesh_kind: str, save_hlo: bool = True) -> dict:
    from repro.launch.gcn_dryrun import lower_gcn_cell

    return lower_gcn_cell(arch, mesh_kind, _mesh(mesh_kind))


def save_cell(name: str, rec: dict, hlo: str | None, save_hlo: bool):
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(rec, indent=1))
    if hlo is not None and save_hlo:
        with gzip.open(ART / f"{name}.hlo.gz", "wt") as f:
            f.write(hlo)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("pod", "multipod"), default="pod")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--gcn", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--no-seq-par", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for experiment variants")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override k=v (perf experiments)")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding rule override logical=axis")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.override)
    rule_overrides = {}
    for kv in args.rule:
        k, v = kv.split("=", 1)
        rule_overrides[k] = None if v in ("none", "None") else \
            (tuple(v.split("+")) if "+" in v else v)

    from repro.config import lm_cells, list_gcn_archs

    jobs: list[tuple[str, str, str]] = []
    if args.sweep:
        for mesh_kind in ("pod", "multipod"):
            for arch, shape, status in lm_cells(include_skipped=True):
                if status == "run":
                    jobs.append((arch, shape, mesh_kind))
        if args.gcn:
            for mesh_kind in ("pod", "multipod"):
                for arch in ("gcn-gcn-rd", "gcn-gin-or", "gcn-sage-lj",
                             "gcn-gcn-rm23"):
                    jobs.append((arch, "graph", mesh_kind))
    elif args.gcn:
        archs = [args.arch] if args.arch else ["gcn-gcn-rd", "gcn-gin-or",
                                               "gcn-sage-lj", "gcn-gcn-rm23"]
        jobs = [(a, "graph", args.mesh) for a in archs]
    else:
        assert args.arch and args.shape
        jobs = [(args.arch, args.shape, args.mesh)]

    results = []
    for arch, shape, mesh_kind in jobs:
        name = cell_name(arch, shape, mesh_kind) + args.tag
        if (ART / f"{name}.json").exists() and not args.force:
            print(f"[skip] {name}")
            continue
        print(f"[run ] {name} ...", flush=True)
        try:
            if shape == "graph":
                rec, hlo = run_gcn_cell(arch, mesh_kind)
            else:
                rec, hlo = run_lm_cell(arch, shape, mesh_kind,
                                       seq_par=not args.no_seq_par,
                                       overrides=overrides,
                                       rule_overrides=rule_overrides)
            save_cell(name, rec, hlo, save_hlo=not args.no_hlo)
            m = rec["memory"]
            print(f"[ ok ] {name}: compile={rec['compile_s']}s "
                  f"args={m['argument_bytes']/2**30:.2f}GiB "
                  f"temp={m['temp_bytes']/2**30:.2f}GiB "
                  f"colls={rec['collectives']}", flush=True)
            results.append((name, "ok"))
        except Exception as e:
            print(f"[FAIL] {name}: {type(e).__name__}: {e}", flush=True)
            ART.mkdir(parents=True, exist_ok=True)
            (ART / f"{name}.fail.txt").write_text(traceback.format_exc())
            results.append((name, "fail"))
    ok = sum(1 for _, s in results if s == "ok")
    print(f"done: {ok}/{len(results)} newly passed")


if __name__ == "__main__":
    main()
