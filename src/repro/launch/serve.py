"""Serving launcher: batched continuous-batching engine over a slot pool.

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-8b \
        --requests 16 --slots 4
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.config import get_lm_config
    from repro.models import lm
    from repro.serve.engine import Request, ServeEngine

    cfg = get_lm_config(args.arch, args.variant)
    params = lm.lm_init(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 4 + i % 7),
                    max_new=args.max_new) for i in range(args.requests)]
    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    while engine.queue or any(engine.active):
        engine.step()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"[serve] {len(reqs)} reqs, {toks} tokens, {toks / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
