"""Multi-graph GCN serving driver: a mixed RMAT workload through
``GCNService``.

Admits ``--graphs`` distinct RMAT graphs (sizes and message-passing
models cycle, so no two sessions share a plan), interleaves
``--requests`` feature-inference requests across them, and serves the
queue with per-step batching and async double-buffered plan upload.
Prints a summary and optionally records the machine-readable perf
trajectory (``--json BENCH_gcn.json``) that ``benchmarks/run.py
--suite serve`` checks in for future-PR comparisons.

    PYTHONPATH=src python -m repro.launch.gcn_serve \
        --mesh 2x2 --graphs 3 --requests 24 --batch 4 --json BENCH_gcn.json

``--sync`` selects the synchronous-upload fallback (same results — the
async path is fenced — but no upload/execute overlap; useful for
before/after measurements of the overlap win).

``--admission {full,layer-major,auto}`` picks the serving path:
``auto`` (default) serves a session layer-major when its full plan
provably exceeds the plan budget (``--plan-budget-kb``), so over-budget
graphs are admitted and served in bounded ``--chunk-size`` vertex
chunks instead of erroring; ``--verify-full`` additionally checks one
served output per layer-major session bit-exactly against an
UNBUDGETED full-graph forward (the acceptance oracle for the bench's
layer-major record).
"""
from __future__ import annotations

import argparse
import os
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import dataclasses

import numpy as np

MODELS = ("gcn", "gin", "sage")


def fmt_pct(x) -> str:
    """Percent for display; unmeasured (None) ratios print as n/a —
    service/engine stats report None, never a silent 0.0, when the
    underlying path did not run."""
    return "n/a" if x is None else f"{x:.0%}"


def rnd(x, n: int):
    """``round`` that passes None (unmeasured) through."""
    return None if x is None else round(x, n)


def build_service(mesh_dims, *, num_graphs: int, base_scale: int,
                  feat_in: int, layer_dims, max_batch: int,
                  async_upload: bool, plan_budget_bytes: int | None,
                  agg_buffer_bytes: int = 8 << 10,
                  admission: str = "auto", chunk_size: int = 128):
    """Admit ``num_graphs`` mixed RMAT sessions (scale and model cycle)
    onto one service, each with store-registered vertex features (the
    recurring-workload setup: requests can then be store-backed);
    returns ``(service, {name: graph}, {name: features})``."""
    from repro.config import get_gcn_config
    from repro.core.rmat import rmat
    from repro.gcn import GCNService

    svc = GCNService(mesh_dims, max_batch=max_batch,
                     async_upload=async_upload,
                     plan_budget_bytes=plan_budget_bytes,
                     admission=admission, chunk_size=chunk_size)
    graphs, featmap = {}, {}
    for i in range(num_graphs):
        model = MODELS[i % len(MODELS)]
        scale = base_scale + i % 3
        name = f"rmat{scale}-{model}-{i}"
        g = rmat(scale, 1 << (scale + 3), seed=100 + i, name=name)
        cfg = dataclasses.replace(
            get_gcn_config(f"gcn-{model}-rd", "smoke"),
            agg_buffer_bytes=agg_buffer_bytes)
        feats = (np.random.default_rng(200 + i)
                 .normal(size=(g.num_vertices, feat_in))
                 .astype(np.float32))
        svc.admit(name, cfg, g, layer_dims=[feat_in, *layer_dims],
                  seed=i, features=feats)
        graphs[name] = g
        featmap[name] = feats
    return svc, graphs, featmap


def verify_layer_major(svc, graphs, featmap, done) -> int:
    """Bit-exact oracle for the layer-major path: for each layer-major
    session with a served request, rebuild a fresh engine with the plan
    budget LIFTED, run the full-graph forward on the same input and
    params, and require exact equality. Returns sessions checked."""
    from repro.gcn import GCNEngine, cache

    saved = cache._PLANS.budget_bytes
    cache.set_cache_budget(plan_bytes=None)
    checked = 0
    try:
        for name, eng in svc.sessions.items():
            if svc.session_mode(name) != "layer-major":
                continue
            req = next((r for r in done if r.session == name and r.done),
                       None)
            if req is None:
                continue
            ref_eng = GCNEngine.build(eng.cfg, graphs[name], svc.dims)
            x = featmap[name] if req.feats is None else req.feats
            ref = np.asarray(ref_eng.forward(x, eng.params))
            assert np.array_equal(req.out, ref), \
                f"layer-major output differs from full forward: {name}"
            checked += 1
    finally:
        cache.set_cache_budget(plan_bytes=saved)
    return checked


def drive(svc, graphs, *, num_requests: int, feat_in: int, seed: int = 0):
    """Interleave requests across sessions (worst case for plan
    residency: consecutive batches almost always switch graphs) and
    serve the whole queue. Requests are store-backed (the session's
    registered features), so repeated requests for one graph hit the
    feature store's device-resident blocks — the recurring hot-vertex
    workload the storage tier is for."""
    names = list(graphs)
    for k in range(num_requests):
        svc.submit(names[k % len(names)])
    t0 = time.perf_counter()
    done = svc.run()
    wall = time.perf_counter() - t0
    return done, wall


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--mesh", default="2x2",
                    help="torus dims, e.g. 2x2 or 4x2 (<= forced host "
                         "device count)")
    ap.add_argument("--graphs", type=int, default=3,
                    help="distinct RMAT sessions to admit")
    ap.add_argument("--scale", type=int, default=9,
                    help="base RMAT vertex scale (graph i uses "
                         "scale + i %% 3)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4,
                    help="max compatible requests per service step")
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--layers", default="16,8",
                    help="comma list of hidden/output widths")
    ap.add_argument("--sync", action="store_true",
                    help="disable async upload (reference behavior)")
    ap.add_argument("--plan-budget-mb", type=int, default=None,
                    help="byte budget for the shared plan cache")
    ap.add_argument("--plan-budget-kb", type=int, default=None,
                    help="plan budget in KiB (sub-MiB budgets: the "
                         "over-budget layer-major scenario at smoke "
                         "scale); wins over --plan-budget-mb")
    ap.add_argument("--admission", default="auto",
                    choices=("full", "layer-major", "auto"),
                    help="serving path per session: full-graph plan, "
                         "layer-major chunks, or auto (layer-major "
                         "only when the plan provably exceeds the "
                         "budget)")
    ap.add_argument("--chunk-size", type=int, default=128,
                    help="vertices a layer-major chunk owns")
    ap.add_argument("--verify-full", action="store_true",
                    help="check one served output per layer-major "
                         "session bit-exactly against an unbudgeted "
                         "full-graph forward")
    ap.add_argument("--feature-budget", type=int, default=64,
                    help="device byte budget for the feature store "
                         "(MiB; 0 = serve everything from host)")
    ap.add_argument("--json", default="",
                    help="write the perf record here (BENCH_gcn.json)")
    ap.add_argument("--trace-out", default="",
                    help="export a Chrome trace_event JSON of the whole "
                         "run here (load in chrome://tracing or "
                         "ui.perfetto.dev; validate with "
                         "tools/check_trace.py)")
    args = ap.parse_args(argv)

    import jax

    from repro.gcn import obs, set_cache_budget

    if args.trace_out:
        obs.trace.configure(enabled=True)
    set_cache_budget(feature_bytes=args.feature_budget << 20)
    mesh_dims = tuple(int(d) for d in args.mesh.split("x"))
    layer_dims = [int(x) for x in args.layers.split(",")]
    plan_budget = (args.plan_budget_kb << 10 if args.plan_budget_kb
                   else args.plan_budget_mb << 20 if args.plan_budget_mb
                   else None)
    svc, graphs, featmap = build_service(
        mesh_dims, num_graphs=args.graphs, base_scale=args.scale,
        feat_in=args.feat, layer_dims=layer_dims, max_batch=args.batch,
        async_upload=not args.sync, plan_budget_bytes=plan_budget,
        admission=args.admission, chunk_size=args.chunk_size)
    done, wall = drive(svc, graphs, num_requests=args.requests,
                       feat_in=args.feat)
    st = svc.stats()
    # engine.stats() builds the session's full plan — exactly what an
    # over-budget layer-major session must never do, so the analytic
    # link-byte sum covers full-mode sessions only
    link_bytes = sum(
        int(svc.sessions[n].stats(feat_dim=args.feat)["link_bytes"])
        for n in svc.sessions if svc.session_mode(n) == "full")
    agg_backend = next(iter(svc.sessions.values())).agg_impl

    print(f"served {st['requests']} requests over {st['sessions']} graphs "
          f"in {wall:.2f}s ({st['requests'] / wall:.2f} req/s, "
          f"mean batch {st['mean_batch']:.1f})")
    print(f"agg backend: {agg_backend} (jax {jax.default_backend()}); "
          f"analytic link bytes: {link_bytes / 2**20:.1f} MiB")
    print(f"plan upload: {st['uploads']} uploads, {st['upload_s']:.2f}s, "
          f"overlap {fmt_pct(st['upload_overlap_fraction'])} "
          f"({'async' if st['async_upload'] else 'sync'})")
    fstats = st["cache"]["features"]
    print(f"feature store: hit rate {fstats['hit_rate']:.0%}, "
          f"{fstats['gathered_bytes'] / 2**20:.2f} MiB gathered vs "
          f"{fstats['dense_bytes'] / 2**20:.2f} MiB dense baseline "
          f"({fstats['pinned_entries']} pinned blocks)")
    # the recurring workload MUST hit the device tiers; a zero hit rate
    # means the storage tier stopped serving (regression)
    assert fstats["hit_rate"] > 0, "feature store served no hits"

    lm_sessions = st["sessions_layer_major"]
    if lm_sessions:
        print(f"layer-major: {lm_sessions}/{st['sessions']} sessions "
              f"(admission={st['admission']}, chunk {args.chunk_size}); "
              f"peak {st['peak_feature_bytes'] / 2**10:.0f} KiB vs "
              f"{st['dense_feature_bytes'] / 2**10:.0f} KiB dense, "
              f"prepare overlap "
              f"{fmt_pct(st['inference_overlap_fraction'])}, "
              f"chunk-bucket hit rate "
              f"{fmt_pct(st['chunk_bucket_hit_rate'])}")
    if args.verify_full:
        checked = verify_layer_major(svc, graphs, featmap, done)
        assert checked == lm_sessions, \
            f"verified {checked} of {lm_sessions} layer-major sessions"
        print(f"verify-full: {checked} layer-major session(s) "
              "bit-identical to unbudgeted full forward")

    if args.trace_out:
        spans = obs.trace.export(args.trace_out)
        print(f"wrote {args.trace_out} ({spans} spans; validate with "
              f"tools/check_trace.py)")

    if args.json:
        rec = {
            "suite": "serve",
            "mesh": list(mesh_dims),
            "graphs": {n: {"V": g.num_vertices, "E": g.num_edges}
                       for n, g in graphs.items()},
            "requests": st["requests"],
            "batches": st["batches"],
            "mean_batch": st["mean_batch"],
            "wall_s": round(wall, 4),
            "requests_per_sec": round(st["requests"] / wall, 3),
            "exec_s": round(st["exec_s"], 4),
            "upload_s": round(st["upload_s"], 4),
            "upload_overlap_fraction": rnd(
                st["upload_overlap_fraction"], 4),
            "async_upload": st["async_upload"],
            "agg_backend": agg_backend,
            "jax_backend": jax.default_backend(),
            "link_bytes": link_bytes,
            "feature_hit_rate": round(fstats["hit_rate"], 4),
            "feature_bytes_gathered": int(fstats["gathered_bytes"]),
            "feature_bytes_dense": int(fstats["dense_bytes"]),
            "admission": st["admission"],
            "sessions_layer_major": lm_sessions,
            "cache": {layer: {k: v for k, v in s.items()}
                      for layer, s in st["cache"].items()
                      if isinstance(s, dict)},
            # schema-versioned snapshot of the process-wide typed
            # metrics registry (repro.gcn.obs)
            "telemetry": obs.telemetry(),
        }
        if lm_sessions:
            rec["layer_major"] = {
                "sessions": lm_sessions,
                "chunk_size": args.chunk_size,
                "plan_budget_bytes": plan_budget,
                "requests_per_sec": round(st["requests"] / wall, 3),
                "peak_feature_bytes": int(st["peak_feature_bytes"]),
                "dense_feature_bytes": int(st["dense_feature_bytes"]),
                "inference_overlap_fraction": rnd(
                    st["inference_overlap_fraction"], 4),
                "chunk_bucket_hit_rate": rnd(
                    st["chunk_bucket_hit_rate"], 4),
                "verified_full_parity": bool(args.verify_full),
            }
        from repro.launch.bench_record import write_record

        write_record(args.json, "serve", rec)
        print(f"wrote {args.json} (serve suite)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
