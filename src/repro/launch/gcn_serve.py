"""Multi-graph GCN serving driver: a mixed RMAT workload through
``GCNService``.

Admits ``--graphs`` distinct RMAT graphs (sizes and message-passing
models cycle, so no two sessions share a plan), interleaves
``--requests`` feature-inference requests across them, and serves the
queue with per-step batching and async double-buffered plan upload.
Prints a summary and optionally records the machine-readable perf
trajectory (``--json BENCH_gcn.json``) that ``benchmarks/run.py
--suite serve`` checks in for future-PR comparisons.

    PYTHONPATH=src python -m repro.launch.gcn_serve \
        --mesh 2x2 --graphs 3 --requests 24 --batch 4 --json BENCH_gcn.json

``--sync`` selects the synchronous-upload fallback (same results — the
async path is fenced — but no upload/execute overlap; useful for
before/after measurements of the overlap win).
"""
from __future__ import annotations

import argparse
import os
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import dataclasses

import numpy as np

MODELS = ("gcn", "gin", "sage")


def build_service(mesh_dims, *, num_graphs: int, base_scale: int,
                  feat_in: int, layer_dims, max_batch: int,
                  async_upload: bool, plan_budget_bytes: int | None,
                  agg_buffer_bytes: int = 8 << 10):
    """Admit ``num_graphs`` mixed RMAT sessions (scale and model cycle)
    onto one service, each with store-registered vertex features (the
    recurring-workload setup: requests can then be store-backed);
    returns ``(service, {name: graph})``."""
    from repro.config import get_gcn_config
    from repro.core.rmat import rmat
    from repro.gcn import GCNService

    svc = GCNService(mesh_dims, max_batch=max_batch,
                     async_upload=async_upload,
                     plan_budget_bytes=plan_budget_bytes)
    graphs = {}
    for i in range(num_graphs):
        model = MODELS[i % len(MODELS)]
        scale = base_scale + i % 3
        name = f"rmat{scale}-{model}-{i}"
        g = rmat(scale, 1 << (scale + 3), seed=100 + i, name=name)
        cfg = dataclasses.replace(
            get_gcn_config(f"gcn-{model}-rd", "smoke"),
            agg_buffer_bytes=agg_buffer_bytes)
        feats = (np.random.default_rng(200 + i)
                 .normal(size=(g.num_vertices, feat_in))
                 .astype(np.float32))
        svc.admit(name, cfg, g, layer_dims=[feat_in, *layer_dims],
                  seed=i, features=feats)
        graphs[name] = g
    return svc, graphs


def drive(svc, graphs, *, num_requests: int, feat_in: int, seed: int = 0):
    """Interleave requests across sessions (worst case for plan
    residency: consecutive batches almost always switch graphs) and
    serve the whole queue. Requests are store-backed (the session's
    registered features), so repeated requests for one graph hit the
    feature store's device-resident blocks — the recurring hot-vertex
    workload the storage tier is for."""
    names = list(graphs)
    for k in range(num_requests):
        svc.submit(names[k % len(names)])
    t0 = time.perf_counter()
    done = svc.run()
    wall = time.perf_counter() - t0
    return done, wall


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--mesh", default="2x2",
                    help="torus dims, e.g. 2x2 or 4x2 (<= forced host "
                         "device count)")
    ap.add_argument("--graphs", type=int, default=3,
                    help="distinct RMAT sessions to admit")
    ap.add_argument("--scale", type=int, default=9,
                    help="base RMAT vertex scale (graph i uses "
                         "scale + i %% 3)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4,
                    help="max compatible requests per service step")
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--layers", default="16,8",
                    help="comma list of hidden/output widths")
    ap.add_argument("--sync", action="store_true",
                    help="disable async upload (reference behavior)")
    ap.add_argument("--plan-budget-mb", type=int, default=None,
                    help="byte budget for the shared plan cache")
    ap.add_argument("--feature-budget", type=int, default=64,
                    help="device byte budget for the feature store "
                         "(MiB; 0 = serve everything from host)")
    ap.add_argument("--json", default="",
                    help="write the perf record here (BENCH_gcn.json)")
    args = ap.parse_args(argv)

    import jax

    from repro.gcn import set_cache_budget

    set_cache_budget(feature_bytes=args.feature_budget << 20)
    mesh_dims = tuple(int(d) for d in args.mesh.split("x"))
    layer_dims = [int(x) for x in args.layers.split(",")]
    svc, graphs = build_service(
        mesh_dims, num_graphs=args.graphs, base_scale=args.scale,
        feat_in=args.feat, layer_dims=layer_dims, max_batch=args.batch,
        async_upload=not args.sync,
        plan_budget_bytes=(args.plan_budget_mb << 20
                           if args.plan_budget_mb else None))
    done, wall = drive(svc, graphs, num_requests=args.requests,
                       feat_in=args.feat)
    st = svc.stats()
    link_bytes = sum(
        int(svc.sessions[n].stats(feat_dim=args.feat)["link_bytes"])
        for n in svc.sessions)
    agg_backend = next(iter(svc.sessions.values())).agg_impl

    print(f"served {st['requests']} requests over {st['sessions']} graphs "
          f"in {wall:.2f}s ({st['requests'] / wall:.2f} req/s, "
          f"mean batch {st['mean_batch']:.1f})")
    print(f"agg backend: {agg_backend} (jax {jax.default_backend()}); "
          f"analytic link bytes: {link_bytes / 2**20:.1f} MiB")
    print(f"plan upload: {st['uploads']} uploads, {st['upload_s']:.2f}s, "
          f"overlap {st['upload_overlap_fraction']:.0%} "
          f"({'async' if st['async_upload'] else 'sync'})")
    fstats = st["cache"]["features"]
    print(f"feature store: hit rate {fstats['hit_rate']:.0%}, "
          f"{fstats['gathered_bytes'] / 2**20:.2f} MiB gathered vs "
          f"{fstats['dense_bytes'] / 2**20:.2f} MiB dense baseline "
          f"({fstats['pinned_entries']} pinned blocks)")
    # the recurring workload MUST hit the device tiers; a zero hit rate
    # means the storage tier stopped serving (regression)
    assert fstats["hit_rate"] > 0, "feature store served no hits"

    if args.json:
        rec = {
            "suite": "serve",
            "mesh": list(mesh_dims),
            "graphs": {n: {"V": g.num_vertices, "E": g.num_edges}
                       for n, g in graphs.items()},
            "requests": st["requests"],
            "batches": st["batches"],
            "mean_batch": st["mean_batch"],
            "wall_s": round(wall, 4),
            "requests_per_sec": round(st["requests"] / wall, 3),
            "exec_s": round(st["exec_s"], 4),
            "upload_s": round(st["upload_s"], 4),
            "upload_overlap_fraction": round(
                st["upload_overlap_fraction"], 4),
            "async_upload": st["async_upload"],
            "agg_backend": agg_backend,
            "jax_backend": jax.default_backend(),
            "link_bytes": link_bytes,
            "feature_hit_rate": round(fstats["hit_rate"], 4),
            "feature_bytes_gathered": int(fstats["gathered_bytes"]),
            "feature_bytes_dense": int(fstats["dense_bytes"]),
            "cache": {layer: {k: v for k, v in s.items()}
                      for layer, s in st["cache"].items()
                      if isinstance(s, dict)},
        }
        from repro.launch.bench_record import write_record

        write_record(args.json, "serve", rec)
        print(f"wrote {args.json} (serve suite)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
