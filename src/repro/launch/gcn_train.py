"""Distributed GCN training driver: full-batch node classification on a
partitioned RMAT graph, differentiated through the multicast exchange.

Trains each ``--models`` entry (paper-config GCN / GIN / SAGE smoke
presets) on one RMAT graph with synthetic teacher labels, on a >= 2-dim
torus mesh, and reports the loss trajectory, mean epoch wall time and
the MEASURED exchange bytes per training step (forward relay replays +
their transposed backward replays, counted from the traced jaxpr).
Optionally records the machine-readable perf trajectory under the
``"train"`` key of ``BENCH_gcn.json`` (``benchmarks/run.py --suite
train`` checks that in as the baseline future PRs diff against).

    PYTHONPATH=src python -m repro.launch.gcn_train \
        --mesh 2x2 --models gcn,gin,sage --scale 9 --epochs 20 \
        --json BENCH_gcn.json

``--sampler`` switches to neighbor-sampled mini-batch training
(``GCNTrainer.fit_sampled``): bounded-fanout subgraphs per seed batch,
each with its own cached+padded relay plan — the full-batch plan is
never built by training (asserted), and the record lands under the
``"train-sampled"`` key with the batch-plan cache hit rate (asserted
> 0 for fixed seed sets) and the exchange bytes of one sampled step.
``--pipeline-depth N`` (default 2) overlaps the whole host-side batch
chain with device execution (``repro.gcn.pipeline``); the first model
is additionally fit serially on a cold cache so the record carries a
serial-vs-pipelined epoch-wall pair plus the measured
``pipeline_overlap_fraction``, and the two loss trajectories are
asserted bit-identical. ``--variance-reduction`` adds the
historical-aggregation control variate (``--history-budget`` MiB for
the activation store): fanout can drop to 2 while the record keeps the
large-fanout accuracy — ``benchmarks/run.py --suite train-cv`` gates
that byte-vs-accuracy trade.

The trained parameters are handed straight to a ``GCNService`` at the
end (``service.adopt``) and one serving request is verified against the
session's single-device oracle — the train->serve handoff the
subsystem exists for, exercised on every bench run.
"""
from __future__ import annotations

import argparse
import os
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import dataclasses

import numpy as np


def synthetic_labels(graph, feat_in: int, classes: int, seed: int = 0):
    """Features + teacher labels for a graph: one mean-aggregation hop
    over random features through a random linear readout. The labels
    correlate with both the features and the topology, so a GCN can
    actually learn them (random labels would only measure
    memorization); loss starts near ``ln(classes)`` and falls fast.
    Returns ``(feats (V, F) f32, labels (V,) int64)``."""
    rng = np.random.default_rng(seed)
    V = graph.num_vertices
    feats = rng.normal(size=(V, feat_in)).astype(np.float32)
    agg = np.zeros_like(feats)
    np.add.at(agg, graph.dst, feats[graph.src])
    deg = np.maximum(graph.in_degrees(), 1).astype(np.float32)[:, None]
    teacher = feats + agg / deg
    w = rng.normal(size=(feat_in, classes)).astype(np.float32)
    return feats, np.argmax(teacher @ w, axis=1)


def train_one(model: str, graph, mesh_dims, *, feats, labels, mask,
              hidden: int, classes: int, epochs: int, lr: float,
              agg_impl: str | None, agg_buffer_bytes: int,
              log_every: int = 0, seed: int = 0,
              sampler: dict | None = None):
    """Build one session on ``mesh_dims``, fit, and return
    ``(engine, FitReport, eval dict)``. ``sampler`` (a dict of
    ``fit_sampled`` kwargs: batch_size, fanouts, reshuffle_each_epoch)
    switches to the neighbor-sampled mini-batch pipeline — the
    full-batch plan is then never built by training."""
    from repro.config import get_gcn_config
    from repro.gcn import GCNEngine, GCNTrainer
    from repro.train import optimizer as optlib

    cfg = dataclasses.replace(
        get_gcn_config(f"gcn-{model}-rd", "smoke"),
        agg_buffer_bytes=agg_buffer_bytes,
        **({"agg_impl": agg_impl} if agg_impl else {}))
    eng = GCNEngine.build(cfg, graph, mesh_dims)
    trainer = GCNTrainer(
        eng, labels, mask,
        opt=optlib.AdamWConfig(lr=lr, weight_decay=0.0, warmup_steps=0,
                               total_steps=max(epochs, 1), grad_clip=1.0))
    layer_dims = [feats.shape[1], hidden, classes]
    if sampler is not None:
        from repro.gcn import cache_stats

        plan_entries0 = cache_stats()["plan"]["entries"]
        report = trainer.fit_sampled(
            feats, epochs=epochs, seed=seed, log_every=log_every,
            layer_dims=layer_dims, **sampler)
        # scale proof: the sampled pipeline trains without ever
        # building the full-batch plan (the evaluate()/serve handoff
        # below builds it deliberately — serving is full-graph)
        assert cache_stats()["plan"]["entries"] == plan_entries0, \
            "fit_sampled must not build the full-batch plan"
    else:
        report = trainer.fit(
            feats, epochs=epochs, seed=seed, log_every=log_every,
            layer_dims=layer_dims)
    return eng, report, trainer.evaluate(feats)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--mesh", default="2x2",
                    help="torus dims, e.g. 2x2 or 4x2 (<= forced host "
                         "device count)")
    ap.add_argument("--models", default="gcn,gin,sage",
                    help="comma list of message-passing models to train")
    ap.add_argument("--scale", type=int, default=9,
                    help="RMAT vertex scale (V = 2^scale)")
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--train-frac", type=float, default=0.8,
                    help="fraction of vertices carrying a label")
    ap.add_argument("--agg", default="",
                    help="aggregation backend override (jnp|pallas|auto)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=0)
    ap.add_argument("--json", default="",
                    help="merge the perf record under 'train' (or "
                         "'train-sampled') here (BENCH_gcn.json)")
    ap.add_argument("--sampler", action="store_true",
                    help="neighbor-sampled mini-batch training "
                         "(GCNTrainer.fit_sampled): per-batch subgraph "
                         "plans, full-batch plan never built")
    ap.add_argument("--batch-size", type=int, default=128,
                    help="seed vertices per sampled batch")
    ap.add_argument("--fanout", default="8,8",
                    help="comma list of per-layer in-neighbor fanouts "
                         "(-1 = full)")
    ap.add_argument("--reshuffle", action="store_true",
                    help="re-shuffle seed sets every epoch (defeats the "
                         "batch-plan cache; default keeps them fixed)")
    ap.add_argument("--variance-reduction", action="store_true",
                    help="historical-aggregation (control-variate) "
                         "sampling: each layer adds the dropped-edge "
                         "aggregation over cached historical "
                         "activations, letting tiny fanouts (e.g. 2,2) "
                         "match large-fanout accuracy at a fraction of "
                         "the exchange bytes (requires --sampler)")
    ap.add_argument("--history-budget", type=int, default=64,
                    help="byte budget for the historical-activation "
                         "store (MiB; 0 = reject all write-backs, i.e. "
                         "degrade to plain sampling)")
    ap.add_argument("--feature-budget", type=int, default=64,
                    help="device byte budget for the feature store "
                         "(MiB; 0 = gather everything from host)")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="sampled-training look-ahead: builder threads "
                         "prepare up to this many batches ahead of the "
                         "train step (0 = serial; bit-identical either "
                         "way)")
    ap.add_argument("--pipeline-workers", type=int, default=2,
                    help="builder threads for the sampling pipeline")
    ap.add_argument("--trace-out", default="",
                    help="export a Chrome trace_event JSON of the whole "
                         "run here (load in chrome://tracing or "
                         "ui.perfetto.dev; validate with "
                         "tools/check_trace.py)")
    args = ap.parse_args(argv)

    import jax

    from repro.core.rmat import rmat
    from repro.gcn import GCNService, obs
    from repro.launch.bench_record import write_record

    from repro.gcn import set_cache_budget

    if args.trace_out:
        obs.trace.configure(enabled=True)
    set_cache_budget(feature_bytes=args.feature_budget << 20)
    mesh_dims = tuple(int(d) for d in args.mesh.split("x"))
    if len(mesh_dims) < 2:
        raise SystemExit("--mesh must have >= 2 dims (e.g. 2x2)")
    rng = np.random.default_rng(args.seed)
    graph = rmat(args.scale, 1 << (args.scale + 3), seed=100 + args.seed,
                 name=f"rmat{args.scale}")
    feats, labels = synthetic_labels(graph, args.feat, args.classes,
                                     seed=args.seed)
    mask = (rng.random(graph.num_vertices)
            < args.train_frac).astype(np.float32)

    if args.variance_reduction and not args.sampler:
        raise SystemExit("--variance-reduction requires --sampler")
    sampler_kw = None
    if args.sampler:
        fanouts = tuple(int(f) for f in args.fanout.split(","))
        sampler_kw = dict(batch_size=args.batch_size, fanouts=fanouts,
                          reshuffle_each_epoch=args.reshuffle,
                          pipeline_depth=args.pipeline_depth,
                          pipeline_workers=args.pipeline_workers,
                          variance_reduction=args.variance_reduction)
        if args.variance_reduction:
            set_cache_budget(history_bytes=args.history_budget << 20)
    suite = "train-sampled" if args.sampler else "train"

    svc = GCNService(mesh_dims)
    per_model = {}
    pipeline_rec = None
    t0 = time.perf_counter()
    for mi, model in enumerate(args.models.split(",")):
        model = model.strip()
        if sampler_kw is not None and args.pipeline_depth > 0 and mi == 0:
            # the serial-vs-pipelined epoch-wall pair: fit the FIRST
            # model serially on a cold cache, then clear everything so
            # the pipelined fit below starts equally cold. Both runs
            # include epoch 1 (plan builds + compiles) — the window the
            # pipeline exists to hide
            from repro.gcn import cache as _gcache

            _gcache.clear_all()
            _, rep_serial, _ = train_one(
                model, graph, mesh_dims, feats=feats, labels=labels,
                mask=mask, hidden=args.hidden, classes=args.classes,
                epochs=args.epochs, lr=args.lr,
                agg_impl=args.agg or None,
                agg_buffer_bytes=8 << 10, log_every=args.log_every,
                seed=args.seed,
                sampler={**sampler_kw, "pipeline_depth": 0})
            serial_wall = sum(h["epoch_s"] for h in rep_serial.history)
            _gcache.clear_all()
        eng, rep, ev = train_one(
            model, graph, mesh_dims, feats=feats, labels=labels,
            mask=mask, hidden=args.hidden, classes=args.classes,
            epochs=args.epochs, lr=args.lr,
            agg_impl=args.agg or None,
            agg_buffer_bytes=8 << 10, log_every=args.log_every,
            seed=args.seed, sampler=sampler_kw)
        print(f"[{model}] loss {rep.loss_first:.4f} -> {rep.loss_last:.4f} "
              f"over {rep.epochs} epochs "
              f"(epoch {rep.epoch_s * 1e3:.1f}ms, compile "
              f"{rep.compile_s:.2f}s, train acc {ev['accuracy']:.2%}); "
              f"exchange {rep.exchange_bytes_per_step / 2**10:.1f} KiB/step")
        rec = {
            "epochs": rep.epochs,
            "loss_first": round(rep.loss_first, 6),
            "loss_last": round(rep.loss_last, 6),
            "epoch_s": round(rep.epoch_s, 5),
            "compile_s": round(rep.compile_s, 4),
            "train_accuracy": round(ev["accuracy"], 4),
            "exchange_bytes_per_step": rep.exchange_bytes_per_step,
            "agg_backend": eng.agg_impl,
        }
        if args.sampler:
            rec.update(
                batch_size=rep.batch_size,
                fanouts=list(rep.fanouts),
                batches_per_epoch=rep.batches_per_epoch,
                batch_plan_hits=rep.batch_plan_hits,
                batch_plan_misses=rep.batch_plan_misses,
                batch_plan_hit_rate=round(rep.batch_plan_hit_rate, 4),
                vertex_buckets=rep.vertex_buckets,
                train_step_compiles=rep.train_step_compiles,
                feature_hit_rate=round(rep.feature_hit_rate, 4),
                feature_bytes_gathered=rep.feature_bytes_gathered,
                feature_bytes_dense=rep.feature_bytes_dense,
                pipeline_depth=rep.pipeline_depth,
                pipeline_overlap_fraction=round(
                    rep.pipeline_overlap_fraction, 4),
                variance_reduction=rep.variance_reduction,
            )
            if rep.variance_reduction:
                rec.update(
                    history_bytes=rep.history_bytes,
                    history_write_rows=rep.history_write_rows,
                    history_read_rows=rep.history_read_rows,
                    history_fallback_rows=rep.history_fallback_rows,
                    history_evictions=rep.history_evictions,
                )
                print(f"  history: {rep.history_bytes / 2**10:.1f} KiB "
                      f"resident, {rep.history_write_rows} rows written, "
                      f"{rep.history_read_rows} read / "
                      f"{rep.history_fallback_rows} fallback")
            print(f"  sampled: {rep.batches_per_epoch} batches/epoch, "
                  f"buckets {rep.vertex_buckets}, batch-plan hit rate "
                  f"{rep.batch_plan_hit_rate:.2f}, "
                  f"{rep.train_step_compiles} step compiles")
            if mi == 0 and args.pipeline_depth > 0:
                # bit-identity tripwire: the pipelined trajectory must
                # equal the serial reference exactly (the same contract
                # tests/test_gcn_pipeline.py property-tests in-process)
                assert [h["loss"] for h in rep.history] == \
                    [h["loss"] for h in rep_serial.history], \
                    "pipelined losses diverged from the serial run"
                pipelined_wall = sum(h["epoch_s"] for h in rep.history)
                pipeline_rec = {
                    "model": model,
                    "depth": args.pipeline_depth,
                    "workers": args.pipeline_workers,
                    "serial_wall_s": round(serial_wall, 4),
                    "pipelined_wall_s": round(pipelined_wall, 4),
                    "overlap_fraction": round(
                        rep.pipeline_overlap_fraction, 4),
                    "queue_occupancy": round(
                        rep.pipeline_queue_occupancy, 3),
                }
                print(f"  pipeline: depth {args.pipeline_depth}, "
                      f"overlap {rep.pipeline_overlap_fraction:.2f}, "
                      f"wall {serial_wall:.2f}s serial -> "
                      f"{pipelined_wall:.2f}s pipelined (bit-identical)")
            print(f"  features: hit rate {rep.feature_hit_rate:.2f}, "
                  f"{rep.feature_bytes_gathered / 2**10:.1f} KiB gathered "
                  f"vs {rep.feature_bytes_dense / 2**10:.1f} KiB dense "
                  f"baseline")
            if args.epochs >= 2 and not args.reshuffle:
                # regression tripwire for subgraph fingerprinting:
                # fixed seed sets must hit from epoch 2 on
                assert rep.batch_plan_hit_rate > 0, \
                    "recurring seed sets must hit the batch-plan cache"
                # the storage-tier tripwire: recurring batches must be
                # served from device-resident blocks, reading strictly
                # less from host than the dense-slice path would
                assert rep.feature_hit_rate > 0.5, \
                    "recurring batches must hit the feature store"
                assert rep.feature_bytes_gathered < \
                    rep.feature_bytes_dense, \
                    "store must read less than the dense-slice baseline"
        # the train->serve handoff: the trained session serves as-is
        svc.adopt(model, eng)
        out = svc.infer(model, feats)
        ref = eng.reference(feats)
        err = float(np.max(np.abs(out - ref))
                    / (np.max(np.abs(ref)) + 1e-9))
        assert err < 1e-4, f"served-vs-oracle mismatch for {model}: {err}"
        per_model[model] = rec
        assert rep.loss_last < rep.loss_first, \
            f"{model}: loss did not decrease"
    wall = time.perf_counter() - t0
    print(f"trained {len(per_model)} models on rmat{args.scale} "
          f"(V={graph.num_vertices}, E={graph.num_edges}) over mesh "
          f"{'x'.join(map(str, mesh_dims))} in {wall:.2f}s; all served "
          f"through GCNService without replanning "
          f"(jax {jax.default_backend()})")

    if args.trace_out:
        spans = obs.trace.export(args.trace_out)
        print(f"wrote {args.trace_out} ({spans} spans; validate with "
              f"tools/check_trace.py)")

    if args.json:
        rec = {
            "suite": suite,
            "mesh": list(mesh_dims),
            "graph": {"V": graph.num_vertices, "E": graph.num_edges},
            "feat_in": args.feat,
            "hidden": args.hidden,
            "classes": args.classes,
            "train_frac": args.train_frac,
            "lr": args.lr,
            "wall_s": round(wall, 4),
            "jax_backend": jax.default_backend(),
            "models": per_model,
            # schema-versioned snapshot of the process-wide typed
            # metrics registry (repro.gcn.obs)
            "telemetry": obs.telemetry(),
        }
        if args.sampler:
            rec["sampler"] = {"batch_size": args.batch_size,
                              "fanouts": [int(f) for f in
                                          args.fanout.split(",")],
                              "reshuffle_each_epoch": args.reshuffle,
                              "pipeline_depth": args.pipeline_depth,
                              "pipeline_workers": args.pipeline_workers,
                              "variance_reduction":
                                  args.variance_reduction,
                              "history_budget_mib": args.history_budget}
            if pipeline_rec is not None:
                rec["pipeline"] = pipeline_rec
        write_record(args.json, suite, rec)
        print(f"wrote {args.json} ({suite} suite)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
