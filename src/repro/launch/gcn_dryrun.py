"""GCN cells for the multi-pod dry-run.

Lowers one distributed GCN layer (TMM+SREM exchange + aggregation +
combination) on the production mesh, treated as a 2D/3D torus. A
``GCNEngine`` session owns the host-side mapping: the communication plan
is built for a degree-matched scaled twin (plan construction is
host-side Python, like the paper's one-time mapping) and lands in the
process-wide plan cache, so re-lowering the same cell replans nothing.
The round count is then scaled to the full graph in the record so the
roofline extrapolates per-round costs honestly (``round_scale``).
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.config import get_gcn_config
from repro.core import jax_compat
from repro.core.partition import make_partition
from repro.core.rmat import build_graph
from repro.gcn import GCNEngine

MAX_TWIN_V = 1 << 17
MAX_TWIN_E = 1 << 21


def lower_gcn_cell(arch: str, mesh_kind: str, mesh, *, bidir: bool = False,
                   buffer_mult: int = 1):
    bidir = bidir or os.environ.get("REPRO_GCN_BIDIR") == "1"
    buffer_mult = int(os.environ.get("REPRO_GCN_BUFMULT", buffer_mult))
    cfg = get_gcn_config(arch)
    g_full = cfg.graph
    scale = max(1, g_full.num_vertices // MAX_TWIN_V,
                g_full.num_edges // MAX_TWIN_E)
    twin = build_graph(g_full, scale_factor=scale)

    # pick the aggregation buffer so the twin still exercises rounds:
    # keep the paper's per-round slot count (2^x) but relative to twin |V|
    cfg2 = dataclasses.replace(
        cfg, agg_buffer_bytes=buffer_mult * max(
            64 << 10, cfg.agg_buffer_bytes // scale))
    # time the full host-side mapping (partition + edge weights + plan),
    # like the paper's one-time mapping; a cache hit legitimately reports
    # ~0 and is flagged so records stay comparable across runs
    t0 = time.time()
    eng = GCNEngine.build(cfg2, twin, mesh=mesh, bidir=bidir)
    plan_cached = eng.plan_cached
    plan = eng.plan
    t_plan = time.time() - t0

    # full-scale round count under the SAME buffer multiplier, so the
    # round_scale extrapolation is consistent across buffer experiments
    cfg_full = dataclasses.replace(
        cfg, agg_buffer_bytes=buffer_mult * cfg.agg_buffer_bytes)
    part_full = make_partition(cfg_full, eng.torus.num_nodes)
    round_scale = max(1.0, part_full.num_rounds / plan.num_rounds)

    # full configs request agg_impl="pallas"; the engine resolves "auto"
    # by backend, and the dry-run lowers whatever the config asks for —
    # through the ENGINE's own exchange closure, so the lowered cell can
    # never drift from what engine.forward compiles
    agg_impl = eng.agg_impl
    pdev = eng.plan_arrays()
    exchange = eng.exchange_fn()
    axis_names = eng.axis_names
    dims = eng.dims
    F_in, F_out = g_full.feat_in, g_full.feat_hidden
    Vp = plan.part.vertices_per_node()

    from jax.sharding import NamedSharding, PartitionSpec as P

    plan_spec = P(None, *axis_names)
    feat_spec = P(*axis_names)
    nd = len(dims)

    def step(pdev, feats, w, b):
        accs = exchange(pdev, feats)
        agg = accs.reshape(accs.shape[:nd] + (-1, accs.shape[-1]))
        return jax.nn.relu(agg @ w + b)

    pdev_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), pdev)
    feats_abs = jax.ShapeDtypeStruct(dims + (Vp, F_in), jnp.float32)
    w_abs = jax.ShapeDtypeStruct((F_in, F_out), jnp.float32)
    b_abs = jax.ShapeDtypeStruct((F_out,), jnp.float32)

    def ns(spec):
        return NamedSharding(mesh, spec)

    in_sh = (jax.tree.map(lambda _: ns(plan_spec), pdev),
             ns(feat_spec), ns(P()), ns(P()))

    t0 = time.time()
    with jax_compat.set_mesh(mesh):
        lowered = jax.jit(step, in_shardings=in_sh).lower(
            pdev_abs, feats_abs, w_abs, b_abs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = jax_compat.cost_analysis(compiled)
    hlo = compiled.as_text()

    from repro.launch.dryrun import collective_histogram

    rec = {
        "arch": arch, "shape": "graph", "mesh": mesh_kind,
        "kind": "gcn", "bidir": bidir, "buffer_mult": buffer_mult,
        "agg_impl": agg_impl,
        "graph": {"V": g_full.num_vertices, "E": g_full.num_edges,
                  "twin_V": twin.num_vertices, "twin_E": twin.num_edges,
                  "scale": scale},
        "num_devices": int(mesh.devices.size),
        "rounds_twin": plan.num_rounds,
        "rounds_full": part_full.num_rounds,
        "round_scale": round_scale,
        "plan_build_s": round(t_plan, 2),
        "plan_cached": plan_cached,
        "plan_stats": {k: int(v) for k, v in plan.stats.items()},
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        "cost": {k: ca.get(k) for k in ("flops", "bytes accessed")},
        "collectives": collective_histogram(hlo),
    }
    return rec, hlo
