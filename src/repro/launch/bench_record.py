"""Shared writer for the multi-suite ``BENCH_gcn.json`` perf baseline.

Since PR 4 the checked-in baseline holds one record PER SUITE
(``{"serve": {...}, "train": {...}}``) so the serving and training
drivers can refresh their halves independently (``make bench-json``
runs both). A pre-PR-4 flat single-suite file (it carried its suite
name in a top-level ``"suite"`` key) is absorbed under that key rather
than clobbered.
"""
from __future__ import annotations

import json
import os


def write_record(path: str, suite: str, rec: dict) -> None:
    """Merge ``rec`` under ``suite`` in the JSON file at ``path``."""
    data: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
    if "suite" in data:  # legacy flat single-suite record
        data = {data["suite"]: data}
    data[suite] = rec
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
