"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b \
        --variant smoke --steps 50

On a real TPU pod this launcher is invoked once per host (jax.distributed
initializes from the TPU environment); in this container it runs the same
code single-process. ``--variant full`` requires pod hardware; the
compile-only proof for full configs is ``repro.launch.dryrun``.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="smoke", choices=("smoke", "full"))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.config import get_lm_config
    from repro.train import optimizer as optlib
    from repro.train.loop import TrainConfig, train

    cfg = get_lm_config(args.arch, args.variant)
    print(f"[launch] {cfg.name}: {cfg.param_count() / 1e9:.2f}B params")
    tcfg = TrainConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir,
        opt=optlib.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                               total_steps=args.steps))
    out = train(cfg, tcfg, resume=not args.no_resume)
    print(f"[launch] final loss "
          f"{out['history'][-1]['loss'] if out['history'] else float('nan')}")


if __name__ == "__main__":
    main()
