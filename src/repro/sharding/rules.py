"""Logical-axis sharding rules.

Every parameter/activation in ``repro.nn`` is annotated with *logical* axis
names; a :class:`ShardingRules` table maps logical names to physical mesh
axes. Hillclimbing a sharding layout = editing one table, not the model.

Physical axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod. ``data`` (x ``pod``) is the FSDP/DP axis, ``model`` the TP axis.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Mapping, Sequence

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = str | tuple[str, ...] | None


@dataclass(frozen=True)
class ShardingRules:
    rules: Mapping[str, Axis]

    def spec(self, *logical: str | None) -> P:
        """Translate logical axis names to a PartitionSpec."""
        phys: list[Axis] = []
        used: set[str] = set()
        for name in logical:
            ax = self.rules.get(name) if name else None
            # one physical axis may appear at most once in a spec
            if ax is None:
                phys.append(None)
                continue
            axs = (ax,) if isinstance(ax, str) else tuple(ax)
            axs = tuple(a for a in axs if a not in used)
            used.update(axs)
            if not axs:
                phys.append(None)
            elif len(axs) == 1:
                phys.append(axs[0])
            else:
                phys.append(axs)
        while phys and phys[-1] is None:
            phys.pop()
        return P(*phys)

    def with_(self, **updates: Axis) -> "ShardingRules":
        d = dict(self.rules)
        d.update(updates)
        return ShardingRules(d)


def _base(batch_axes: Axis) -> dict[str, Axis]:
    return {
        # --- parameter logical axes ---
        "embed": "data",       # FSDP: shard d_model dim of weights over data
        "heads": "model",      # TP over attention heads
        "kv_heads": "model",
        "mlp": "model",        # TP over FFN hidden
        "vocab": "model",      # vocab-parallel embedding / lm head
        "expert": None,        # expert dim (EP maps it to "model")
        "kv_lora": None,
        "ssm_inner": "model",
        "layers": None,        # scan dim, never sharded
        "conv_w": None,
        # --- activation logical axes ---
        "act_batch": batch_axes,
        "act_seq": None,       # sequence parallelism maps this to "model"
        "act_embed": None,
        "act_heads": "model",
        "act_kv_seq": None,    # decode: KV-cache length sharding
        "act_mlp": "model",
        "act_expert": None,
        "act_vocab": "model",
        "act_state_heads": "model",  # SSM/WKV recurrent state heads
    }


def train_rules(multi_pod: bool, sequence_parallel: bool = True) -> ShardingRules:
    batch: Axis = ("pod", "data") if multi_pod else "data"
    r = _base(batch)
    if sequence_parallel:
        r["act_seq"] = "model"  # residual stream seq-sharded between blocks
    return ShardingRules(r)


def prefill_rules(multi_pod: bool) -> ShardingRules:
    batch: Axis = ("pod", "data") if multi_pod else "data"
    r = _base(batch)
    r["embed"] = None  # inference: keep weights resident, no FSDP regather
    r["act_seq"] = "model"
    return ShardingRules(r)


def decode_rules(multi_pod: bool) -> ShardingRules:
    batch: Axis = ("pod", "data") if multi_pod else "data"
    r = _base(batch)
    r["embed"] = None
    # decode attention: shard the KV cache along its length; partial-softmax
    # reductions become tiny all-reduces over "model" (works even when
    # kv_heads < model axis, e.g. glm4 kv=2)
    r["act_kv_seq"] = "model"
    r["act_heads"] = None
    r["heads"] = "model"
    return ShardingRules(r)


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def sanitize_spec(spec: P, shape: tuple[int, ...],
                  axis_sizes: Mapping[str, int]) -> P:
    """Drop mesh axes whose size does not divide the tensor dim (small
    archs — whisper-tiny heads=6 on a 16-wide model axis — replicate those
    dims instead of failing)."""
    out: list[Axis] = []
    for d, ax in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axs = (ax,) if isinstance(ax, str) else tuple(ax)
        keep: list[str] = []
        size = shape[d]
        for a in axs:
            n = axis_sizes.get(a, 1)
            if size % n == 0 and n > 1:
                keep.append(a)
                size //= n
            elif n == 1:
                keep.append(a)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def sanitize_tree(abs_tree, spec_tree, mesh) -> object:
    """tree-wise sanitize_spec for (ShapeDtypeStruct, PartitionSpec) pairs."""
    import jax

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree.map(
        lambda a, s: sanitize_spec(s, a.shape, sizes), abs_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, P))
