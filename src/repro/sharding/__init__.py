from .rules import (
    ShardingRules,
    decode_rules,
    named_sharding,
    prefill_rules,
    train_rules,
)

__all__ = [
    "ShardingRules",
    "decode_rules",
    "named_sharding",
    "prefill_rules",
    "train_rules",
]
