"""Synthetic token data pipeline: deterministic, shardable, prefetched.

Mirrors the structure of a production loader: an index-based sampler
(deterministic given (seed, step) — restart-safe, no loader state in the
checkpoint beyond the step counter), per-host sharding, and a background
prefetch thread with a bounded queue (straggler mitigation: the trainer
never blocks on data unless the pipeline falls an entire queue behind).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class TokenDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # multi-host sharding
    host_id: int = 0
    num_hosts: int = 1


class SyntheticLM:
    """Markov-ish synthetic stream so the loss actually decreases."""

    def __init__(self, cfg: TokenDataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        k = min(cfg.vocab_size, 97)
        self._next = rng.integers(0, cfg.vocab_size, size=(k,))

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        per_host = cfg.global_batch // cfg.num_hosts
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * cfg.num_hosts + cfg.host_id)
        toks = rng.integers(0, min(self.cfg.vocab_size, 97),
                            size=(per_host, cfg.seq_len + 1))
        # deterministic "grammar": next token often a function of current
        follow = self._next[toks[:, :-1] % len(self._next)]
        mask = rng.random((per_host, cfg.seq_len)) < 0.7
        toks[:, 1:] = np.where(mask, follow, toks[:, 1:])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


class PrefetchIterator:
    """Background-thread prefetcher with a bounded queue."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.step = start_step
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        s = self.step
        while not self._stop.is_set():
            b = self.source.batch_at(s)
            while not self._stop.is_set():
                try:
                    self.q.put((s, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
