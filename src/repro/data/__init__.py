from . import tokens

__all__ = ["tokens"]
