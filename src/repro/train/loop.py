"""Training loop: jit'd step + data prefetch + async checkpointing +
preemption handling + (optional) elastic resume. Works single-device
(CPU examples/tests) and on any mesh via the same step builders the
dry-run lowers.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.config import LMConfig
from repro.data.tokens import PrefetchIterator, SyntheticLM, TokenDataConfig
from repro.distributed.fault_tolerance import PreemptionGuard, StragglerPolicy
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.train import optimizer as optlib


@dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    opt: optlib.AdamWConfig = field(default_factory=optlib.AdamWConfig)


def train(cfg: LMConfig, tcfg: TrainConfig, *, rules=None, mesh=None,
          resume: bool = True, hooks: list[Callable] | None = None) -> dict:
    key = jax.random.PRNGKey(tcfg.seed)
    params = lm.lm_init(cfg, key)
    opt_state = optlib.init(params)
    start_step = 0

    if resume:
        try:
            (params, opt_state), start_step = ckpt.restore(
                tcfg.ckpt_dir, (params, opt_state))
            print(f"[train] resumed from step {start_step}")
        except FileNotFoundError:
            pass

    step_fn = jax.jit(make_train_step(cfg, rules, tcfg.opt), donate_argnums=(0, 1))

    data = SyntheticLM(TokenDataConfig(
        vocab_size=cfg.vocab_size, seq_len=256 if cfg.frontend == "none"
        and not cfg.is_encdec else 128, global_batch=8, seed=tcfg.seed))
    it = PrefetchIterator(data, start_step=start_step)
    straggler = StragglerPolicy(deadline_s=30.0)
    saver = ckpt.AsyncCheckpointer(tcfg.ckpt_dir)
    history = []

    with PreemptionGuard() as guard:
        t0 = time.time()
        for step in range(start_step, tcfg.steps):
            _, batch = straggler.fetch(it.q)
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            if cfg.is_encdec:
                jb["frames"] = 0.01 * jnp.ones(
                    (jb["tokens"].shape[0], cfg.frontend_seq_len, cfg.d_model),
                    jnp.bfloat16)
            if cfg.frontend == "patch_stub":
                jb["patches"] = 0.01 * jnp.ones(
                    (jb["tokens"].shape[0], cfg.frontend_seq_len, cfg.d_model),
                    jnp.bfloat16)
            params, opt_state, metrics = step_fn(params, opt_state, jb)
            if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                dt = time.time() - t0
                print(f"[train] step={step} loss={m['loss']:.4f} "
                      f"gnorm={m['grad_norm']:.3f} ({dt:.1f}s)")
                history.append({"step": step, **m})
            for h in hooks or []:
                h(step, params, metrics)
            if tcfg.ckpt_every and (step + 1) % tcfg.ckpt_every == 0:
                saver.save(step + 1, (params, opt_state))
            if guard.should_stop:
                print(f"[train] preemption at step {step}; checkpointing")
                saver.wait()
                ckpt.save(tcfg.ckpt_dir, step + 1, (params, opt_state))
                break
    saver.wait()
    it.close()
    return {"history": history, "params": params, "opt_state": opt_state,
            "straggler_reused": straggler.reused}
