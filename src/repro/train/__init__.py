from . import optimizer

__all__ = ["optimizer"]
