"""AdamW + schedules, implemented in-house (optax is not vendored here).

Memory layout: params stay bf16 (or f32 for small runs); Adam moments are
f32 and sharded exactly like the params (the spec tree mirrors the param
spec tree), giving ZeRO-style optimizer-state sharding under FSDP rules.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array  # () int32
    mu: Any  # pytree like params, f32
    nu: Any  # pytree like params, f32


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(jnp.zeros((), jnp.int32), zeros,
                     jax.tree.map(jnp.copy, zeros))


def abstract_state(params) -> AdamState:
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                     params)
    return AdamState(jax.ShapeDtypeStruct((), jnp.int32), z, z)


def state_specs(param_specs) -> AdamState:
    from jax.sharding import PartitionSpec as P

    return AdamState(P(), param_specs, jax.tree.map(lambda s: s, param_specs))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamState):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
