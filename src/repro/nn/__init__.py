from . import attention, layers, linear_attn, mixers, module, moe, ssm, transformer

__all__ = [
    "attention",
    "layers",
    "linear_attn",
    "mixers",
    "module",
    "moe",
    "ssm",
    "transformer",
]
