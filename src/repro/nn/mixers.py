"""Token mixers: GQA attention (with KV cache), MLA (DeepSeek-V2),
including cache layouts for prefill/decode.

Cache conventions
-----------------
GQA cache: dict(k=(B, S, Hkv, D), v=(B, S, Hkv, D), pos=()) where S is
``min(max_len, window)`` — sliding-window archs keep a ring buffer.
MLA cache: dict(ckv=(B, S, r), krope=(B, S, 1, rope_d), pos=()).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.config import LMConfig
from repro.nn import attention as attn
from repro.nn.layers import apply_rope
from repro.nn.module import fan_in_init, param, shard

# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_defs(cfg: LMConfig):
    d, h = cfg.d_model, cfg.head_dim
    return {
        "wq": param((d, cfg.num_heads, h), ("embed", "heads", None), fan_in_init(0)),
        "wk": param((d, cfg.num_kv_heads, h), ("embed", "kv_heads", None), fan_in_init(0)),
        "wv": param((d, cfg.num_kv_heads, h), ("embed", "kv_heads", None), fan_in_init(0)),
        "wo": param((cfg.num_heads, h, d), ("heads", None, "embed"), fan_in_init(0)),
    }


def gqa_cache_len(cfg: LMConfig, max_len: int) -> int:
    if cfg.sliding_window > 0:
        return min(max_len, cfg.sliding_window)
    return max_len


def gqa_init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    S = gqa_cache_len(cfg, max_len)
    shape = (batch, S, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_apply(cfg: LMConfig, p, x, *, positions, rules=None, cache=None,
              pos=None, cross_kv=None, causal=True, impl="auto"):
    """x: (B, S, D). Returns (out, new_cache).

    * train/prefill: cache is None, S = full sequence.
    * decode: cache holds past K/V; S == 1; pos = () scalar count of tokens
      already in cache (the new token goes to slot pos % cache_len).
    * cross attention (whisper): cross_kv = (k, v) precomputed from encoder.
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if cross_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = cross_kv

    new_cache = cache
    if cache is not None and cross_kv is None and S == 1:
        # decode: append to ring/linear cache; pos may be a scalar (all
        # sequences in lockstep) or a (B,) vector (serving slots)
        cache_len = cache["k"].shape[1]
        slot = pos % cache_len
        if jnp.ndim(slot) == 0:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, 1)
        else:
            bidx = jnp.arange(B)
            k_cache = cache["k"].at[bidx, slot].set(
                k[:, 0].astype(cache["k"].dtype))
            v_cache = cache["v"].at[bidx, slot].set(
                v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": k_cache, "v": v_cache}
        if rules is not None:
            k_cache = shard(k_cache, rules, "act_batch", "act_kv_seq", "act_heads", None)
            v_cache = shard(v_cache, rules, "act_batch", "act_kv_seq", "act_heads", None)
        ring = cfg.sliding_window > 0 and cache_len <= cfg.sliding_window
        out = attn.decode_attention(
            q, k_cache, v_cache, pos + 1,
            num_kv_heads=cfg.num_kv_heads,
            window=0 if ring else cfg.sliding_window,
        )
    elif cache is not None and cross_kv is None:
        # prefill-into-cache: bulk write (prompt starts at position 0),
        # attention runs over the freshly computed full-sequence K/V
        cache_len = cache["k"].shape[1]
        kw = k[:, -cache_len:] if S > cache_len else k  # ring keeps the tail
        vw = v[:, -cache_len:] if S > cache_len else v
        new_cache = {
            "k": _bulk_update(cache["k"], kw, 0),
            "v": _bulk_update(cache["v"], vw, 0),
        }
        out = attn.causal_attention(q, k, v, num_kv_heads=cfg.num_kv_heads,
                                    window=cfg.sliding_window, impl=impl)
    elif cross_kv is not None:
        out = attn.full_attention(q, k, v, num_kv_heads=cfg.num_kv_heads)
    elif causal:
        out = attn.causal_attention(q, k, v, num_kv_heads=cfg.num_kv_heads,
                                    window=cfg.sliding_window, impl=impl)
    else:
        out = attn.full_attention(q, k, v, num_kv_heads=cfg.num_kv_heads)

    if rules is not None:
        out = shard(out, rules, "act_batch", "act_seq", "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache


def _bulk_update(cache, new, pos):
    # prefill-into-cache: write S tokens starting at pos (no ring wrap;
    # bulk prefill always starts at 0 in this framework)
    return jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype), pos, 1)


def gqa_cross_kv(cfg: LMConfig, p, memory):
    """Precompute cross-attention K/V from encoder output (whisper)."""
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"].astype(memory.dtype))
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"].astype(memory.dtype))
    return k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank KV compression, rope/nope split heads
# ---------------------------------------------------------------------------


def mla_defs(cfg: LMConfig):
    d = cfg.d_model
    n = cfg.num_heads
    r = cfg.kv_lora_rank
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": param((d, n, qk), ("embed", "heads", None), fan_in_init(0)),
        "w_dkv": param((d, r + cfg.qk_rope_dim), ("embed", "kv_lora"), fan_in_init(0)),
        "w_uk": param((r, n, cfg.qk_nope_dim), ("kv_lora", "heads", None), fan_in_init(0)),
        "w_uv": param((r, n, cfg.v_head_dim), ("kv_lora", "heads", None), fan_in_init(0)),
        "wo": param((n, cfg.v_head_dim, d), ("heads", None, "embed"), fan_in_init(0)),
    }


def mla_init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, 1, cfg.qk_rope_dim), dtype),
    }


def mla_apply(cfg: LMConfig, p, x, *, positions, rules=None, cache=None,
              pos=None, impl="auto"):
    """MLA attention. Prefill/train: naive decompression (matmul-friendly).
    Decode: *absorbed* form — scores computed in the latent space against
    the compressed cache (the paper-intended memory win)."""
    B, S, D = x.shape
    n, r = cfg.num_heads, cfg.kv_lora_rank
    rope_d, nope_d, v_d = cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :nope_d], q[..., nope_d:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = x @ p["w_dkv"].astype(x.dtype)  # (B, S, r + rope_d)
    ckv, k_rope = dkv[..., :r], dkv[..., r:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)

    scale = 1.0 / math.sqrt(nope_d + rope_d)

    if cache is None or S > 1:
        # naive: decompress K/V, run blockwise attention with concat dims
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"].astype(x.dtype))
        v = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"].astype(x.dtype))
        qc = jnp.concatenate([q_nope, q_rope], axis=-1)
        kc = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, n, rope_d))], axis=-1)
        out = attn.causal_attention(qc, kc, v, num_kv_heads=n, scale=scale,
                                    impl=impl)
        new_cache = None
        if cache is not None:  # prefill-into-cache (latent cache only)
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice_in_dim(
                    cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, 1),
                "krope": jax.lax.dynamic_update_slice_in_dim(
                    cache["krope"], k_rope.astype(cache["krope"].dtype), 0, 1),
            }
    else:
        # absorbed decode: q_nope' = q_nope @ w_uk  -> latent space (r)
        if jnp.ndim(pos) == 0:
            ckv_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), pos, 1)
            krope_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["krope"], k_rope.astype(cache["krope"].dtype), pos, 1)
        else:
            bidx = jnp.arange(B)
            ckv_cache = cache["ckv"].at[bidx, pos].set(
                ckv[:, 0].astype(cache["ckv"].dtype))
            krope_cache = cache["krope"].at[bidx, pos].set(
                k_rope[:, 0].astype(cache["krope"].dtype))
        new_cache = {"ckv": ckv_cache, "krope": krope_cache}
        if rules is not None:
            ckv_cache = shard(ckv_cache, rules, "act_batch", "act_kv_seq", None)
            krope_cache = shard(krope_cache, rules, "act_batch", "act_kv_seq", None, None)

        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(x.dtype))
        s_lat = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                           ckv_cache.astype(jnp.float32))
        s_rope = jnp.einsum("bshk,btok->bhst", q_rope.astype(jnp.float32),
                            krope_cache.astype(jnp.float32))
        s = (s_lat + s_rope) * scale
        Smax = ckv_cache.shape[1]
        idx = jnp.arange(Smax)
        if jnp.ndim(pos) == 0:
            valid = (idx < (pos + 1))[None]
        else:
            valid = idx[None, :] < (pos + 1)[:, None]
        s = jnp.where(valid[:, None, None, :], s, attn.NEG_INF)
        pw = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", pw, ckv_cache.astype(jnp.float32))
        out = jnp.einsum("bshr,rhk->bshk", o_lat,
                         p["w_uv"].astype(jnp.float32)).astype(x.dtype)

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache
