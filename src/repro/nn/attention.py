"""Attention: blockwise (flash-style) causal attention with a custom
blockwise VJP, decode attention over KV caches, and the GQA wrapper.

The blockwise implementation is the portable XLA path (used by CPU tests
and the compile-only dry-run); on real TPUs ``repro.kernels.flash_attention``
provides the Pallas kernel with identical semantics. Both share the oracle
in ``repro.kernels.flash_attention.ref``.

Causality is exploited *structurally*: we scan over the statically-known
list of (q-block, kv-block) pairs that intersect the causal/sliding-window
band, so compiled FLOPs ~ S^2/2 (matching a real flash kernel), not S^2.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LMConfig
from repro.nn.module import fan_in_init, param

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Static block-pair schedule
# ---------------------------------------------------------------------------


def _block_pairs(n_q: int, n_kv: int, q_chunk: int, kv_chunk: int,
                 causal: bool, window: int, q_offset: int = 0):
    """Statically enumerate (i, j) block pairs intersecting the mask band.

    q block i covers absolute rows [q_offset + i*q_chunk, +q_chunk);
    kv block j covers cols [j*kv_chunk, +kv_chunk). Keep pair if some
    (r, c) with c <= r and (window == 0 or r - c < window) intersects.
    """
    pairs = []
    for i in range(n_q):
        r_lo = q_offset + i * q_chunk
        r_hi = r_lo + q_chunk - 1
        for j in range(n_kv):
            c_lo = j * kv_chunk
            c_hi = c_lo + kv_chunk - 1
            if causal and c_lo > r_hi:
                continue  # fully above diagonal
            if window > 0 and c_hi < r_lo - window + 1:
                continue  # fully outside the sliding window
            pairs.append((i, j))
    return np.asarray(pairs, dtype=np.int32)


def _band_mask(r0, c0, q_chunk, kv_chunk, causal, window):
    rows = r0 + jnp.arange(q_chunk)[:, None]
    cols = c0 + jnp.arange(kv_chunk)[None, :]
    m = jnp.ones((q_chunk, kv_chunk), jnp.bool_)
    if causal:
        m &= cols <= rows
    if window > 0:
        m &= cols > rows - window
    return m


# ---------------------------------------------------------------------------
# Blockwise attention with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8)
)
def blockwise_attention(q, k, v, scale: float, causal: bool, window: int,
                        q_chunk: int, kv_chunk: int, q_offset: int = 0):
    """q: (B, Sq, Hkv, G, D); k, v: (B, Skv, Hkv, D). Returns (B,Sq,Hkv,G,D)."""
    out, _ = _bw_attn_fwd_impl(q, k, v, scale, causal, window, q_chunk,
                               kv_chunk, q_offset)
    return out


def _bw_attn_fwd_impl(q, k, v, scale, causal, window, q_chunk, kv_chunk,
                      q_offset):
    with jax.named_scope("blockwise_attention"):
        return _bw_attn_fwd_scoped(q, k, v, scale, causal, window, q_chunk,
                                   kv_chunk, q_offset)


def _bw_attn_fwd_scoped(q, k, v, scale, causal, window, q_chunk, kv_chunk,
                        q_offset):
    B, Sq, Hkv, G, D = q.shape
    Dv = v.shape[-1]
    Skv = k.shape[1]
    n_q, n_kv = Sq // q_chunk, Skv // kv_chunk
    pairs = _block_pairs(n_q, n_kv, q_chunk, kv_chunk, causal, window, q_offset)

    acc = jnp.zeros((B, Sq, Hkv, G, Dv), jnp.float32)
    m = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l = jnp.zeros((B, Sq, Hkv, G), jnp.float32)

    def body(carry, ij):
        acc, m, l = carry
        i, j = ij[0], ij[1]
        qi = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, 1)
        kj = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, 1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, 1)
        # scores: (B, Hkv, G, qc, kc)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qi.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale
        mask = _band_mask(q_offset + i * q_chunk, j * kv_chunk, q_chunk,
                          kv_chunk, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)

        mi = jax.lax.dynamic_slice_in_dim(m, i * q_chunk, q_chunk, 1)
        li = jax.lax.dynamic_slice_in_dim(l, i * q_chunk, q_chunk, 1)
        acci = jax.lax.dynamic_slice_in_dim(acc, i * q_chunk, q_chunk, 1)
        # carried stats are (B, Sq, Hkv, G) -> block view (B, qc, Hkv, G)
        mi_ = jnp.moveaxis(mi, 1, 3)  # (B, Hkv, G, qc)
        li_ = jnp.moveaxis(li, 1, 3)
        m_new = jnp.maximum(mi_, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mi_ - m_new)
        l_new = li_ * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bqhgd", p, vj.astype(jnp.float32))
        acc_new = acci * jnp.moveaxis(corr, 3, 1)[..., None] + pv
        acc = jax.lax.dynamic_update_slice_in_dim(acc, acc_new, i * q_chunk, 1)
        m = jax.lax.dynamic_update_slice_in_dim(
            m, jnp.moveaxis(m_new, 3, 1), i * q_chunk, 1)
        l = jax.lax.dynamic_update_slice_in_dim(
            l, jnp.moveaxis(l_new, 3, 1), i * q_chunk, 1)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc, m, l), pairs)
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).astype(q.dtype)
    lse = m + jnp.log(l_safe)
    return out, lse


def _bw_attn_fwd(q, k, v, scale, causal, window, q_chunk, kv_chunk, q_offset):
    out, lse = _bw_attn_fwd_impl(q, k, v, scale, causal, window, q_chunk,
                                 kv_chunk, q_offset)
    return out, (q, k, v, out, lse)


def _bw_attn_bwd(scale, causal, window, q_chunk, kv_chunk, q_offset,
                 res, dout):
    with jax.named_scope("blockwise_attention"):
        return _bw_attn_bwd_scoped(scale, causal, window, q_chunk, kv_chunk,
                                   q_offset, res, dout)


def _bw_attn_bwd_scoped(scale, causal, window, q_chunk, kv_chunk, q_offset,
                        res, dout):
    q, k, v, out, lse = res
    B, Sq, Hkv, G, D = q.shape
    Skv = k.shape[1]
    n_q, n_kv = Sq // q_chunk, Skv // kv_chunk
    pairs = _block_pairs(n_q, n_kv, q_chunk, kv_chunk, causal, window, q_offset)

    dof = dout.astype(jnp.float32)
    # delta_i = rowsum(dO_i * O_i): (B, Sq, Hkv, G)
    delta = jnp.sum(dof * out.astype(jnp.float32), axis=-1)

    dq = jnp.zeros(q.shape, jnp.float32)
    dk = jnp.zeros(k.shape, jnp.float32)
    dv = jnp.zeros(v.shape, jnp.float32)

    def body(carry, ij):
        dq, dk, dv = carry
        i, j = ij[0], ij[1]
        qi = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, 1).astype(jnp.float32)
        kj = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, 1).astype(jnp.float32)
        vj = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, 1).astype(jnp.float32)
        doi = jax.lax.dynamic_slice_in_dim(dof, i * q_chunk, q_chunk, 1)
        lsei = jax.lax.dynamic_slice_in_dim(lse, i * q_chunk, q_chunk, 1)
        di = jax.lax.dynamic_slice_in_dim(delta, i * q_chunk, q_chunk, 1)

        s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj) * scale
        mask = _band_mask(q_offset + i * q_chunk, j * kv_chunk, q_chunk,
                          kv_chunk, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - jnp.moveaxis(lsei, 1, 3)[..., None])  # (B,Hkv,G,qc,kc)

        dvj = jnp.einsum("bhgqk,bqhgd->bkhd", p, doi)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", doi, vj)
        ds = p * (dp - jnp.moveaxis(di, 1, 3)[..., None]) * scale
        dqi = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kj)
        dkj = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qi)

        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, jax.lax.dynamic_slice_in_dim(dq, i * q_chunk, q_chunk, 1) + dqi,
            i * q_chunk, 1)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, j * kv_chunk, kv_chunk, 1) + dkj,
            j * kv_chunk, 1)
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, j * kv_chunk, kv_chunk, 1) + dvj,
            j * kv_chunk, 1)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(body, (dq, dk, dv), pairs)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


blockwise_attention.defvjp(_bw_attn_fwd, _bw_attn_bwd)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def causal_attention(q, k, v, *, num_kv_heads: int, window: int = 0,
                     q_chunk: int = 512, kv_chunk: int = 512,
                     q_offset: int = 0, scale: float | None = None,
                     impl: str = "auto"):
    """q: (B, Sq, Hq, D); k: (B, Skv, Hkv, D); v: (B, Skv, Hkv, Dv)
    -> (B, Sq, Hq, Dv). D and Dv may differ (MLA)."""
    B, Sq, Hq, D = q.shape
    Skv, Dv = k.shape[1], v.shape[-1]
    G = Hq // num_kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, num_kv_heads, G, D)

    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(qg, k, v, scale=scale, causal=True,
                                     window=window, q_offset=q_offset)
        return out.reshape(B, Sq, Hq, Dv)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    out = blockwise_attention(qg, k, v, scale, True, window, q_chunk,
                              kv_chunk, q_offset)
    return out.reshape(B, Sq, Hq, Dv)


def full_attention(q, k, v, *, num_kv_heads: int, q_chunk: int = 512,
                   kv_chunk: int = 512, scale: float | None = None):
    """Non-causal (encoder / cross) attention."""
    B, Sq, Hq, D = q.shape
    Dv = v.shape[-1]
    G = Hq // num_kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, num_kv_heads, G, D)
    out = blockwise_attention(qg, k, v, scale, False, 0,
                              min(q_chunk, Sq), min(kv_chunk, k.shape[1]), 0)
    return out.reshape(B, Sq, Hq, Dv)


def decode_attention(q, k_cache, v_cache, pos, *, num_kv_heads: int,
                     window: int = 0, scale: float | None = None):
    """Single-token decode. q: (B, 1, Hq, D); caches: (B, S, Hkv, D);
    pos: () current position (number of valid cached tokens incl. new one).

    Written as plain masked softmax so XLA SPMD can partition the length
    dim of the cache (seq-sharded KV) with small all-reduces over the
    softmax statistics — this is how glm4 (kv=2) shards 16-way.
    """
    B, _, Hq, D = q.shape
    S = k_cache.shape[1]
    G = Hq // num_kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, num_kv_heads, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    idx = jnp.arange(S)
    posv = jnp.reshape(pos, (-1, 1)) if jnp.ndim(pos) else pos  # (B,1) or ()
    valid = idx[None, :] < posv if jnp.ndim(pos) else (idx < pos)[None]
    if window > 0:
        # sliding window over absolute positions (non-ring caches)
        valid = valid & (idx[None, :] >= (posv if jnp.ndim(pos) else pos) - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, D).astype(q.dtype)
