"""Minimal functional parameter system (no flax dependency).

A *module* here is a plain function pair:

* ``init(key, cfg...) -> params``  — a pytree of ``jnp`` arrays
* ``apply(params, x, ...) -> out``

Parameter declaration goes through :class:`ParamDef` so that every array
carries (shape, dtype, logical axes, initializer) and the same declaration
drives three consumers: real init, ``jax.eval_shape`` abstract init for the
dry-run, and the sharding-spec pytree.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import ShardingRules

Initializer = Callable[[jax.Array, tuple[int, ...], Any], jax.Array]


def normal_init(stddev: float) -> Initializer:
    def f(key, shape, dtype):
        return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)

    return f


def fan_in_init(in_axis: int = -2) -> Initializer:
    def f(key, shape, dtype):
        fan_in = shape[in_axis] if len(shape) >= 2 else shape[0]
        std = 1.0 / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)

    return f


def zeros_init() -> Initializer:
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init() -> Initializer:
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


def const_init(value) -> Initializer:
    def f(key, shape, dtype):
        return jnp.broadcast_to(jnp.asarray(value, dtype), shape)

    return f


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical_axes: tuple[str | None, ...]
    init: Initializer
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (
            f"shape {self.shape} vs axes {self.logical_axes}"
        )


def param(shape: Sequence[int], axes: Sequence[str | None], init: Initializer,
          dtype=jnp.bfloat16) -> ParamDef:
    return ParamDef(tuple(shape), tuple(axes), init, dtype)


# ---------------------------------------------------------------------------
# Tree materialization
# ---------------------------------------------------------------------------

def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_tree(defs, key: jax.Array):
    """Materialize a pytree of ParamDefs into arrays with split keys."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    arrs = [d.init(k, d.shape, d.dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def abstract_tree(defs):
    """ShapeDtypeStruct pytree (dry-run init, no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=_is_def
    )


def spec_tree(defs, rules: ShardingRules):
    """PartitionSpec pytree matching the param pytree."""
    return jax.tree.map(lambda d: rules.spec(*d.logical_axes), defs, is_leaf=_is_def)


def param_count_tree(defs) -> int:
    leaves, _ = jax.tree.flatten(defs, is_leaf=_is_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


def shard(x: jax.Array, rules: ShardingRules | None, *axes: str | None) -> jax.Array:
    """Activation sharding constraint via logical axes.

    ``rules=None`` (single-device tests) makes this a no-op. Callers must
    trace under ``jax.sharding.set_mesh(mesh)`` so bare PartitionSpecs
    resolve. Axes that don't divide the tensor dim are dropped (tiny archs
    replicate instead of failing).
    """
    if rules is None:
        return x
    from repro.sharding.rules import sanitize_spec

    spec = rules.spec(*axes)
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        spec = sanitize_spec(spec, x.shape, sizes)
    except Exception:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
