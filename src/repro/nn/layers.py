"""Basic layers: norms, embeddings, positional encodings, FFNs."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.config import LMConfig
from repro.nn.module import (
    ParamDef,
    fan_in_init,
    normal_init,
    ones_init,
    param,
    zeros_init,
)

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_defs(cfg: LMConfig, d: int | None = None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": param((d,), ("embed",), ones_init(), jnp.float32)}
    return {
        "scale": param((d,), ("embed",), ones_init(), jnp.float32),
        "bias": param((d,), ("embed",), zeros_init(), jnp.float32),
    }


def norm_apply(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embedding_defs(cfg: LMConfig):
    d = {"table": param((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                        normal_init(1.0 / math.sqrt(cfg.d_model)))}
    return d


def embedding_apply(p, tokens):
    # vocab-parallel gather: one-hot matmul keeps the vocab dim sharded and
    # reduces with a small psum instead of all-gathering the table.
    return jnp.take(p["table"], tokens, axis=0)


def lm_head_defs(cfg: LMConfig):
    if cfg.tie_embeddings:
        return {}
    return {"w": param((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                       normal_init(1.0 / math.sqrt(cfg.d_model)))}


def lm_head_matrix(head_params, embed_params, cfg: LMConfig):
    if cfg.tie_embeddings:
        return embed_params["table"].T
    return head_params["w"]


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    if theta <= 0:
        return x
    dim = x.shape[-1]
    freqs = rope_frequencies(dim, theta)  # (dim/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, dim/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    """Whisper-style sinusoidal position embeddings."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d_model)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# FFN (dense)
# ---------------------------------------------------------------------------


def ffn_defs(cfg: LMConfig, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    if cfg.act == "swiglu":
        return {
            "w_gate": param((d, d_ff), ("embed", "mlp"), fan_in_init()),
            "w_up": param((d, d_ff), ("embed", "mlp"), fan_in_init()),
            "w_down": param((d_ff, d), ("mlp", "embed"), fan_in_init()),
        }
    return {
        "w_up": param((d, d_ff), ("embed", "mlp"), fan_in_init()),
        "w_down": param((d_ff, d), ("mlp", "embed"), fan_in_init()),
    }


def _act(cfg: LMConfig, h):
    if cfg.act == "gelu":
        return jax.nn.gelu(h)
    if cfg.act == "relu2":
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(cfg.act)


def ffn_apply(cfg: LMConfig, p, x):
    if cfg.act == "swiglu":
        g = x @ p["w_gate"]
        u = x @ p["w_up"]
        h = jax.nn.silu(g) * u
    else:
        h = _act(cfg, x @ p["w_up"])
    return h @ p["w_down"]
