"""Transformer stack: heterogeneous block layouts compiled into a minimal
set of ``lax.scan`` segments.

``cfg.blocks`` may be heterogeneous (deepseek: dense layer 0 + 26 MoE
layers; zamba2: period-6 mamba/shared-attn pattern). We run-length-encode
the layout, detect periodicity, and emit one scan per *segment* whose body
unrolls one period ("superblock"). HLO size therefore stays O(distinct
block kinds), not O(layers) — critical for 88-layer compile times.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.config import BlockSpec, LMConfig
from repro.nn import linear_attn, mixers, moe as moe_lib, ssm
from repro.nn.layers import ffn_defs, ffn_apply, norm_apply, norm_defs
from repro.nn.module import ParamDef, param, shard


# ---------------------------------------------------------------------------
# Layout segmentation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Segment:
    pattern: tuple[BlockSpec, ...]  # one superblock
    reps: int  # scan length

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.reps


def _rle(blocks: Sequence[BlockSpec]):
    runs: list[tuple[BlockSpec, int]] = []
    for b in blocks:
        if runs and runs[-1][0] == b:
            runs[-1] = (b, runs[-1][1] + 1)
        else:
            runs.append((b, 1))
    return runs


def segment_layout(cfg: LMConfig) -> list[Segment]:
    runs = _rle(cfg.blocks)
    # try run-level periodicity (zamba2: [(m,5),(ms,1)] x 9)
    n = len(runs)
    for p in range(1, n // 2 + 1):
        if n % p == 0 and all(runs[i] == runs[i % p] for i in range(n)):
            pattern: list[BlockSpec] = []
            for spec, cnt in runs[:p]:
                pattern.extend([spec] * cnt)
            return [Segment(tuple(pattern), n // p)]
    # fall back: one segment per run
    return [Segment((spec,), cnt) for spec, cnt in runs]


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------


def block_defs(cfg: LMConfig, bspec: BlockSpec, cross: bool = False):
    defs: dict[str, Any] = {"norm1": norm_defs(cfg)}
    if bspec.mixer == "gqa":
        defs["mixer"] = mixers.gqa_defs(cfg)
    elif bspec.mixer == "mla":
        defs["mixer"] = mixers.mla_defs(cfg)
    elif bspec.mixer == "mamba2":
        defs["mixer"] = ssm.mamba2_defs(cfg)
    elif bspec.mixer == "wkv6":
        defs["mixer"] = linear_attn.wkv6_defs(cfg)
    if cross:  # enc-dec decoder blocks: cross attention to encoder memory
        defs["norm_cross"] = norm_defs(cfg)
        defs["cross"] = mixers.gqa_defs(cfg)
    if bspec.ffn != "none":
        defs["norm2"] = norm_defs(cfg)
        defs["ffn"] = ffn_defs(cfg) if bspec.ffn == "dense" else moe_lib.moe_defs(cfg)
    return defs


def shared_attn_defs(cfg: LMConfig):
    """zamba2 shared transformer block: attention + MLP, one set of weights."""
    return {
        "norm1": norm_defs(cfg),
        "attn": mixers.gqa_defs(cfg),
        "norm2": norm_defs(cfg),
        "ffn": ffn_defs(cfg),
    }


def init_cache_for_block(cfg: LMConfig, bspec: BlockSpec, batch: int,
                         max_len: int, dtype=jnp.bfloat16):
    cache: dict[str, Any] = {}
    if bspec.mixer == "gqa":
        cache["mixer"] = mixers.gqa_init_cache(cfg, batch, max_len, dtype)
    elif bspec.mixer == "mla":
        cache["mixer"] = mixers.mla_init_cache(cfg, batch, max_len, dtype)
    elif bspec.mixer == "mamba2":
        cache["mixer"] = ssm.mamba2_init_cache(cfg, batch, dtype)
    elif bspec.mixer == "wkv6":
        cache["mixer"] = linear_attn.wkv6_init_cache(cfg, batch, dtype)
    if bspec.shared_attn:
        cache["shared"] = mixers.gqa_init_cache(cfg, batch, max_len, dtype)
    return cache


def block_apply(cfg: LMConfig, bspec: BlockSpec, p, x, *, positions,
                rules=None, cache=None, pos=None, shared_params=None,
                impl="auto", causal=True, memory=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(p["norm1"], x)
    new_cache: dict[str, Any] = {}
    mcache = cache.get("mixer") if cache else None

    if bspec.mixer == "gqa":
        y, c = mixers.gqa_apply(cfg, p["mixer"], h, positions=positions,
                                rules=rules, cache=mcache, pos=pos, impl=impl,
                                causal=causal)
    elif bspec.mixer == "mla":
        y, c = mixers.mla_apply(cfg, p["mixer"], h, positions=positions,
                                rules=rules, cache=mcache, pos=pos, impl=impl)
    elif bspec.mixer == "mamba2":
        y, c = ssm.mamba2_apply(cfg, p["mixer"], h, cache=mcache,
                                chunk=cfg.ssm_chunk)
    elif bspec.mixer == "wkv6":
        y, c = linear_attn.wkv6_apply(cfg, p["mixer"], h, cache=mcache,
                                      chunk=cfg.wkv_chunk)
    else:
        y, c = jnp.zeros_like(h), None
    if c is not None:
        new_cache["mixer"] = c
    x = x + y
    if rules is not None:
        x = shard(x, rules, "act_batch", "act_seq", "act_embed")

    if "cross" in p and memory is not None:
        h = norm_apply(p["norm_cross"], x)
        ckv = mixers.gqa_cross_kv(cfg, p["cross"], memory)
        y, _ = mixers.gqa_apply(cfg, p["cross"], h, positions=positions,
                                rules=rules, cross_kv=ckv, causal=False)
        x = x + y

    if bspec.ffn != "none":
        h = norm_apply(p["norm2"], x)
        if bspec.ffn == "dense":
            y = ffn_apply(cfg, p["ffn"], h)
        else:
            y, aux = moe_lib.moe_apply(cfg, p["ffn"], h, rules=rules)
        x = x + y
        if rules is not None:
            x = shard(x, rules, "act_batch", "act_seq", "act_embed")

    if bspec.shared_attn:
        assert shared_params is not None
        scache = cache.get("shared") if cache else None
        h = norm_apply(shared_params["norm1"], x)
        y, c = mixers.gqa_apply(cfg, shared_params["attn"], h,
                                positions=positions, rules=rules,
                                cache=scache, pos=pos, impl=impl)
        if c is not None:
            new_cache["shared"] = c
        x = x + y
        h = norm_apply(shared_params["norm2"], x)
        x = x + ffn_apply(cfg, shared_params["ffn"], h)
        if rules is not None:
            x = shard(x, rules, "act_batch", "act_seq", "act_embed")

    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# Stack = list of scanned segments
# ---------------------------------------------------------------------------


def _stack_defs(defs, reps: int):
    def f(d: ParamDef):
        return ParamDef((reps,) + d.shape, ("layers",) + d.logical_axes,
                        _vmap_init(d.init, reps), d.dtype)
    return jax.tree.map(f, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _vmap_init(init, reps):
    def f(key, shape, dtype):
        keys = jax.random.split(key, reps)
        return jax.vmap(lambda k: init(k, shape[1:], dtype))(keys)
    return f


def stack_defs(cfg: LMConfig, cross: bool = False):
    segs = segment_layout(cfg)
    out = []
    for seg in segs:
        sb = {f"b{i}": block_defs(cfg, bs, cross=cross)
              for i, bs in enumerate(seg.pattern)}
        out.append(_stack_defs(sb, seg.reps))
    return out, segs


def stack_cache(cfg: LMConfig, segs: list[Segment], batch: int, max_len: int,
                dtype=jnp.bfloat16):
    caches = []
    for seg in segs:
        one = {f"b{i}": init_cache_for_block(cfg, bs, batch, max_len, dtype)
               for i, bs in enumerate(seg.pattern)}
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (seg.reps,) + x.shape).copy(), one))
    return caches


def _cache_axes_for_block(cfg: LMConfig, bspec: BlockSpec):
    """Logical axes mirroring init_cache_for_block's structure."""
    kv = {"k": ("act_batch", "act_kv_seq", "act_heads", None),
          "v": ("act_batch", "act_kv_seq", "act_heads", None)}
    axes: dict[str, Any] = {}
    if bspec.mixer == "gqa":
        axes["mixer"] = kv
    elif bspec.mixer == "mla":
        axes["mixer"] = {"ckv": ("act_batch", "act_kv_seq", None),
                         "krope": ("act_batch", "act_kv_seq", None, None)}
    elif bspec.mixer == "mamba2":
        axes["mixer"] = {"conv": ("act_batch", None, "act_mlp"),
                         "ssm": ("act_batch", "act_state_heads", None, None)}
    elif bspec.mixer == "wkv6":
        axes["mixer"] = {"shift": ("act_batch", None),
                         "wkv": ("act_batch", "act_state_heads", None, None)}
    if bspec.shared_attn:
        axes["shared"] = kv
    return axes


def stack_cache_specs(cfg: LMConfig, segs: list[Segment], rules):
    """PartitionSpec pytree matching stack_cache (leading 'layers' dim)."""
    specs = []
    for seg in segs:
        one = {f"b{i}": _cache_axes_for_block(cfg, bs)
               for i, bs in enumerate(seg.pattern)}
        specs.append(jax.tree.map(
            lambda ax: rules.spec("layers", *ax), one,
            is_leaf=lambda x: isinstance(x, tuple)))
    return specs


def stack_abstract_cache(cfg: LMConfig, segs: list[Segment], batch: int,
                         max_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree matching stack_cache (no allocation)."""
    caches = jax.eval_shape(
        lambda: stack_cache(cfg, segs, batch, max_len, dtype))
    return caches


def stack_apply(cfg: LMConfig, segs: list[Segment], seg_params, x, *,
                positions, rules=None, caches=None, pos=None,
                shared_params=None, impl="auto", remat=True, causal=True,
                memory=None):
    """Run all segments. Returns (x, new_caches, total_aux)."""
    total_aux = jnp.zeros((), jnp.float32)
    new_caches = []
    layer_offset = 0

    for si, (seg, params) in enumerate(zip(segs, seg_params)):
        cache = caches[si] if caches is not None else None

        def superblock(x, params, cache, _seg=seg):
            aux = jnp.zeros((), jnp.float32)
            ncache = {}
            for i, bs in enumerate(_seg.pattern):
                ci = cache.get(f"b{i}") if cache else None
                x, nc, a = block_apply(
                    cfg, bs, params[f"b{i}"], x, positions=positions,
                    rules=rules, cache=ci, pos=pos,
                    shared_params=shared_params, impl=impl, causal=causal,
                    memory=memory)
                aux = aux + a
                if nc is not None:
                    ncache[f"b{i}"] = nc
            return x, ncache, aux

        if seg.reps == 1:
            x, ncache, aux = superblock(x, jax.tree.map(lambda t: t[0], params),
                                        cache and jax.tree.map(lambda t: t[0], cache))
            total_aux = total_aux + aux
            new_caches.append(ncache and jax.tree.map(lambda t: t[None], ncache))
        else:
            def body(carry, xs, _seg=seg):
                x, aux = carry
                if caches is not None:
                    par, ca = xs
                else:
                    par, ca = xs, None
                x, ncache, a = superblock(x, par, ca)
                return (x, aux + a), ncache

            if remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            xs = (params, cache) if caches is not None else params
            (x, total_aux), ncache = jax.lax.scan(body, (x, total_aux), xs)
            new_caches.append(ncache if ncache else None)
        layer_offset += seg.num_layers

    return x, (new_caches if caches is not None else None), total_aux
