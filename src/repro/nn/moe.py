"""Mixture-of-Experts FFN with capacity-based sort dispatch.

Baseline layout is *tensor-parallel MoE*: the expert dim is replicated and
each expert's hidden dim is sharded over "model" (works for any expert
count, e.g. mixtral's 8 experts on a 16-wide axis). Expert-parallel
dispatch with the paper's one-put-per-multicast deduplication lives in
``repro.core.moe_dispatch`` and is selected per-arch at launch time.

Dispatch avoids (T, E, C) one-hot tensors: ranks within an expert come from
one argsort over T*K entries (static shapes throughout; over-capacity
tokens are dropped, standard Switch/GShard semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import LMConfig
from repro.nn.layers import ffn_apply
from repro.nn.module import fan_in_init, normal_init, param


def moe_defs(cfg: LMConfig):
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    defs = {
        "router": param((d, E), ("embed", None), normal_init(0.02), jnp.float32),
        "w_gate": param((E, d, ff), ("expert", "embed", "mlp"), fan_in_init(1)),
        "w_up": param((E, d, ff), ("expert", "embed", "mlp"), fan_in_init(1)),
        "w_down": param((E, ff, d), ("expert", "mlp", "embed"), fan_in_init(1)),
    }
    if cfg.num_shared_experts > 0:
        sff = cfg.num_shared_experts * ff
        defs["shared"] = {
            "w_gate": param((d, sff), ("embed", "mlp"), fan_in_init(0)),
            "w_up": param((d, sff), ("embed", "mlp"), fan_in_init(0)),
            "w_down": param((sff, d), ("mlp", "embed"), fan_in_init(0)),
        }
    return defs


def capacity(cfg: LMConfig, num_tokens: int) -> int:
    c = int(cfg.capacity_factor * num_tokens * cfg.top_k / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def route(cfg: LMConfig, logits: jax.Array):
    """logits: (T, E) -> (gates (T,K), experts (T,K), aux_loss ())."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load balancing aux loss
    E = cfg.num_experts
    density = jnp.zeros((E,), jnp.float32).at[experts.reshape(-1)].add(1.0)
    density = density / density.sum()
    mean_prob = probs.mean(0)
    aux = E * jnp.sum(density * mean_prob)
    return gates, experts, aux


def dispatch_indices(experts: jax.Array, num_experts: int, cap: int):
    """experts: (T, K) int32 -> (dest_e, dest_r, keep) each (T*K,).

    Rank r of entry i within its expert comes from a single stable argsort;
    entries with r >= capacity are dropped.
    """
    TK = experts.size
    flat_e = experts.reshape(-1)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    starts = jnp.searchsorted(sorted_e, jnp.arange(num_experts), side="left")
    rank_sorted = jnp.arange(TK, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    rank = jnp.zeros((TK,), jnp.int32).at[sort_idx].set(rank_sorted)
    keep = rank < cap
    dest_e = jnp.where(keep, flat_e, num_experts)  # overflow row E
    dest_r = jnp.where(keep, rank, 0)
    return dest_e, dest_r, keep


def moe_apply(cfg: LMConfig, p, x, *, rules=None):
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    T = B * S
    K, E = cfg.top_k, cfg.num_experts
    xf = x.reshape(T, D)

    logits = xf.astype(jnp.float32) @ p["router"]
    gates, experts, aux = route(cfg, logits)
    cap = capacity(cfg, T)
    dest_e, dest_r, keep = dispatch_indices(experts, E, cap)

    tok_idx = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    buf = jnp.zeros((E + 1, cap, D), x.dtype).at[dest_e, dest_r].set(xf[tok_idx])
    buf = buf[:E]

    # expert FFN (batched einsum over the expert dim)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))

    out_pad = jnp.concatenate([out, jnp.zeros((1, cap, D), out.dtype)], axis=0)
    vals = out_pad[dest_e, dest_r]  # (T*K, D)
    w = (gates.reshape(-1) * keep).astype(jnp.float32)
    y = jnp.sum(vals.reshape(T, K, D).astype(jnp.float32)
                * w.reshape(T, K, 1), axis=1)
    y = y.astype(x.dtype)

    if "shared" in p:
        y = y + ffn_apply(cfg, p["shared"], xf)
    return y.reshape(B, S, D), aux
