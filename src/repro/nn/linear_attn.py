"""RWKV6 ("Finch") WKV mixer — linear attention with data-dependent
per-channel decay, in chunked (GLA-style) form plus the O(1) recurrence.

Recurrence per head (K = V = head dim):
    out_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
    S_t   = diag(w_t) S_{t-1} + k_t^T v_t
with w_t in (0,1)^K produced by a decay LoRA over the token-shifted input
(the data-dependent decay that defines RWKV6). Token-shift mixing uses the
static (RWKV-5 style) learned lerp; the per-token dynamic mix LoRA of the
full Finch release is an orthogonal refinement (noted in DESIGN.md).

Chunked form: all exponentials are differences of within-chunk cumulative
log-decays, arranged so every factor is <= 1.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config import LMConfig
from repro.nn.module import const_init, fan_in_init, normal_init, ones_init, param, zeros_init


def wkv_dims(cfg: LMConfig):
    K = cfg.wkv_head_dim
    H = cfg.d_model // K
    return H, K


def wkv6_defs(cfg: LMConfig):
    d = cfg.d_model
    H, K = wkv_dims(cfg)
    lora = max(32, d // 32)
    return {
        "mix_r": param((d,), ("embed",), const_init(0.5), jnp.float32),
        "mix_k": param((d,), ("embed",), const_init(0.5), jnp.float32),
        "mix_v": param((d,), ("embed",), const_init(0.5), jnp.float32),
        "mix_g": param((d,), ("embed",), const_init(0.5), jnp.float32),
        "mix_w": param((d,), ("embed",), const_init(0.5), jnp.float32),
        "w_r": param((d, d), ("embed", "heads"), fan_in_init(0)),
        "w_k": param((d, d), ("embed", "heads"), fan_in_init(0)),
        "w_v": param((d, d), ("embed", "heads"), fan_in_init(0)),
        "w_g": param((d, d), ("embed", "heads"), fan_in_init(0)),
        # decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
        "w0": param((d,), ("embed",), const_init(-1.0), jnp.float32),
        "w_lora_a": param((d, lora), ("embed", None), normal_init(0.02)),
        "w_lora_b": param((lora, d), (None, "heads"), zeros_init()),
        "u": param((H, K), (None, None), const_init(0.5), jnp.float32),
        "ln_scale": param((d,), ("embed",), ones_init(), jnp.float32),
        "w_o": param((d, d), ("heads", "embed"), fan_in_init(0)),
    }


def wkv6_init_cache(cfg: LMConfig, batch: int, dtype=jnp.bfloat16):
    H, K = wkv_dims(cfg)
    return {
        "shift": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, K, K), jnp.float32),
    }


def _token_shift(x, prev):
    """x: (B, S, D); prev: (B, D) last token of previous step/segment."""
    shifted = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    return shifted


def wkv_chunked(r, k, v, logw, u, chunk: int, S0=None):
    """r,k,v: (B, S, H, K); logw: (B, S, H, K) (<0); u: (H, K);
    S0: optional initial state (B, H, K, K).
    Returns y: (B, S, H, K), final state (B, H, K, K)."""
    Bn, S, H, K = r.shape
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)

    def resh(t):
        return jnp.moveaxis(t.reshape(Bn, nc, chunk, H, K), 1, 0)

    rs, ks, vs, lws = resh(r), resh(k), resh(v), resh(logw)

    def body(Sst, inp):
        with jax.named_scope("wkv_chunk"):
            return _wkv_chunk_body(Sst, inp, u, chunk)

    def _wkv_chunk_body(Sst, inp, u, chunk):
        rc, kc, vc, lwc = (t.astype(jnp.float32) for t in inp)  # (B, C, H, K)
        cl = jnp.cumsum(lwc, axis=1)  # inclusive cumulative log decay
        cl_prev = cl - lwc  # exclusive (decay before applying step t)
        # intra-chunk scores s_ti = sum_k r_tk k_ik exp(cl_prev_t - cl_i), i<t
        diff = cl_prev[:, :, None] - cl[:, None, :, :]  # (B, t, i, H, K) <= 0 for i<t
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        dec = jnp.where(mask[None, :, :, None, None], jnp.exp(diff), 0.0)
        s = jnp.einsum("bthk,bihk,btihk->bthi", rc, kc, dec)
        # diagonal bonus term
        diag = jnp.einsum("bthk,hk,bthk->bth", rc, u.astype(jnp.float32), kc)
        y = jnp.einsum("bthi,bihk->bthk", s, vc) + diag[..., None] * vc
        # inter-chunk
        y += jnp.einsum("bthk,bhkv->bthv", rc * jnp.exp(cl_prev), Sst)
        # state update: S = diag(exp(cl_C)) S + sum_i (k_i exp(cl_C - cl_i))^T v_i
        tail = jnp.exp(cl[:, -1:] - cl)  # (B, C, H, K) <= 1
        S_new = jnp.exp(cl[:, -1])[..., None] * Sst + jnp.einsum(
            "bihk,bihv->bhkv", kc * tail, vc)
        return S_new, y.astype(r.dtype)

    if S0 is None:
        S0 = jnp.zeros((Bn, H, K, K), jnp.float32)
    S_fin, ys = jax.lax.scan(body, S0, (rs, ks, vs, lws))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bn, S, H, K)
    return y, S_fin


def wkv_step(Sst, r1, k1, v1, logw1, u):
    """One-token recurrence. r1,k1,v1,logw1: (B, H, K); Sst: (B, H, K, K)."""
    r1, k1, v1 = (t.astype(jnp.float32) for t in (r1, k1, v1))
    kv = jnp.einsum("bhk,bhv->bhkv", k1, v1)
    y = jnp.einsum("bhk,bhkv->bhv", r1, Sst + u.astype(jnp.float32)[None, :, :, None] * kv)
    S_new = jnp.exp(logw1.astype(jnp.float32))[..., None] * Sst + kv
    return S_new, y


def wkv6_apply(cfg: LMConfig, p, x, *, cache=None, chunk: int = 64):
    """x: (B, S, D) -> (y, new_cache)."""
    B, S, d = x.shape
    H, K = wkv_dims(cfg)
    prev = cache["shift"].astype(x.dtype) if cache is not None else jnp.zeros((B, d), x.dtype)
    xs = _token_shift(x, prev)

    def mix(name):
        m = p[f"mix_{name}"].astype(x.dtype)
        return x * m + xs * (1 - m)

    r = (mix("r") @ p["w_r"].astype(x.dtype)).reshape(B, S, H, K)
    k = (mix("k") @ p["w_k"].astype(x.dtype)).reshape(B, S, H, K)
    v = (mix("v") @ p["w_v"].astype(x.dtype)).reshape(B, S, H, K)
    g = mix("g") @ p["w_g"].astype(x.dtype)
    wx = mix("w")
    lora = jnp.tanh(wx @ p["w_lora_a"].astype(x.dtype)) @ p["w_lora_b"].astype(x.dtype)
    logw = -jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))
    logw = logw.reshape(B, S, H, K)

    if cache is None:
        y, _ = wkv_chunked(r, k, v, logw, p["u"], min(chunk, S))
        new_cache = None
    elif S == 1:
        S_new, y1 = wkv_step(cache["wkv"], r[:, 0], k[:, 0], v[:, 0],
                             logw[:, 0], p["u"])
        y = y1[:, None].astype(x.dtype)
        new_cache = {"shift": x[:, -1].astype(cache["shift"].dtype), "wkv": S_new}
    else:  # prefill into cache
        y, S_new = wkv_chunked(r, k, v, logw, p["u"], min(chunk, S),
                               S0=cache["wkv"])
        new_cache = {"shift": x[:, -1].astype(cache["shift"].dtype), "wkv": S_new}

    # per-head group norm then gate
    y = y.reshape(B, S, H, K).astype(jnp.float32)
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 1e-5)
    y = (y.reshape(B, S, d) * p["ln_scale"]).astype(x.dtype)
    y = y * jax.nn.silu(g)
    return y @ p["w_o"].astype(x.dtype), new_cache
