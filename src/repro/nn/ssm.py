"""Mamba2 (SSD) mixer — chunked state-space dual form.

The chunked algorithm follows the SSD decomposition: within a chunk the
output is a masked (decay-weighted) attention-like matmul; across chunks a
scan carries the (H, P, N) state. All decay exponentials are differences of
cumulative log-decays within one chunk, hence <= 1 (numerically safe).

Decode is the O(1) recurrent update — this is what makes zamba2 runnable at
long_500k.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config import LMConfig
from repro.nn.layers import norm_apply
from repro.nn.module import const_init, fan_in_init, normal_init, ones_init, param, zeros_init


def mamba2_dims(cfg: LMConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads


def mamba2_defs(cfg: LMConfig):
    d = cfg.d_model
    d_in, nh = mamba2_dims(cfg)
    N = cfg.ssm_state
    conv_ch = d_in + 2 * N
    return {
        "w_in": param((d, 2 * d_in + 2 * N + nh), ("embed", "ssm_inner"), fan_in_init(0)),
        "conv_w": param((cfg.ssm_conv_width, conv_ch), ("conv_w", "ssm_inner"), normal_init(0.1)),
        "conv_b": param((conv_ch,), ("ssm_inner",), zeros_init()),
        "a_log": param((nh,), (None,), const_init(math.log(1.0)), jnp.float32),
        "d_skip": param((nh,), (None,), ones_init(), jnp.float32),
        "dt_bias": param((nh,), (None,), zeros_init(), jnp.float32),
        "norm_scale": param((d_in,), ("ssm_inner",), ones_init(), jnp.float32),
        "w_out": param((d_in, d), ("ssm_inner", "embed"), fan_in_init(0)),
    }


def mamba2_init_cache(cfg: LMConfig, batch: int, dtype=jnp.bfloat16):
    d_in, nh = mamba2_dims(cfg)
    N = cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, d_in + 2 * N), dtype),
        "ssm": jnp.zeros((batch, nh, cfg.ssm_head_dim, N), jnp.float32),
    }


def _split_proj(cfg, proj):
    d_in, nh = mamba2_dims(cfg)
    N = cfg.ssm_state
    z = proj[..., :d_in]
    xbc = proj[..., d_in:d_in + d_in + 2 * N]
    dt = proj[..., d_in + d_in + 2 * N:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv, width W. xbc: (B, S, C); state: (B, W-1, C)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(xbc[:, : W - 1])
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i : i + xbc.shape[1]] * w[i] for i in range(W)) + b
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, a_log, B, C, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (b, S, H, P); dt: (b, S, H) (post-softplus); B, C: (b, S, N);
    a_log: (H,); h0: optional initial state (b, H, P, N).
    Returns y: (b, S, H, P), final state (b, H, P, N).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)

    xs = x.reshape(b, nc, chunk, H, P)
    dts = dt.reshape(b, nc, chunk, H)
    Bs = B.reshape(b, nc, chunk, N)
    Cs = C.reshape(b, nc, chunk, N)

    A = -jnp.exp(a_log.astype(jnp.float32))  # (H,) negative

    def body(h, inp):
        with jax.named_scope("ssd_chunk"):
            return _ssd_chunk_body(h, inp)

    def _ssd_chunk_body(h, inp):
        xc, dtc, Bc, Cc = inp  # (b, chunk, H, P), (b, chunk, H), (b, chunk, N)
        la = dtc * A  # log decay per step (b, chunk, H), <= 0
        cl = jnp.cumsum(la, axis=1)  # inclusive (b, chunk, H)
        # intra-chunk: M[t, i] = exp(cl_t - cl_i) * (C_t . B_i) * dt_i, i <= t
        decay = jnp.exp(cl[:, :, None, :] - cl[:, None, :, :])  # (b, t, i, H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        cb = jnp.einsum("btn,bin->bti", Cc, Bc)
        M = jnp.where(mask[None, :, :, None], decay, 0.0) * cb[..., None]
        y_intra = jnp.einsum("btih,bihp->bthp", M * dtc[:, None, :, :], xc.astype(jnp.float32))
        # inter-chunk: y_t += C_t . (exp(cl_t) * h)
        h_dec = jnp.einsum("bth,bhpn->bthpn", jnp.exp(cl), h)
        y_inter = jnp.einsum("btn,bthpn->bthp", Cc, h_dec)
        # state update
        tail = jnp.exp(cl[:, -1:, :] - cl)  # (b, chunk, H) decay to chunk end
        dx = xc.astype(jnp.float32) * (dtc * tail)[..., None]
        h_new = jnp.exp(cl[:, -1])[:, :, None, None] * h + jnp.einsum(
            "bthp,btn->bhpn", dx, Bc)
        return h_new, (y_intra + y_inter).astype(x.dtype)

    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), jnp.float32)
    h_final, ys = jax.lax.scan(
        body, h0,
        (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(dts, 1, 0),
         jnp.moveaxis(Bs, 1, 0), jnp.moveaxis(Cs, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, S, H, P)
    return y, h_final


def ssd_step(h, x1, dt1, a_log, B1, C1):
    """One-token recurrence. h: (b,H,P,N); x1: (b,H,P); dt1: (b,H);
    B1, C1: (b,N)."""
    a = jnp.exp(dt1 * -jnp.exp(a_log.astype(jnp.float32)))  # (b,H)
    dx = x1.astype(jnp.float32) * dt1[..., None]
    h_new = a[:, :, None, None] * h + jnp.einsum("bhp,bn->bhpn", dx, B1)
    y = jnp.einsum("bhpn,bn->bhp", h_new, C1)
    return h_new, y.astype(x1.dtype)


def mamba2_apply(cfg: LMConfig, p, x, *, cache=None, chunk: int = 128):
    """x: (B, S, D) -> (y, new_cache)."""
    b, S, d = x.shape
    d_in, nh = mamba2_dims(cfg)
    N = cfg.ssm_state
    P = cfg.ssm_head_dim

    proj = x @ p["w_in"].astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, proj)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), conv_state)
    xs = xbc[..., :d_in].reshape(b, S, nh, P)
    Bv = xbc[..., d_in:d_in + N].astype(jnp.float32)
    Cv = xbc[..., d_in + N:].astype(jnp.float32)

    if cache is None:
        chunk = min(chunk, S)
        y, _ = ssd_chunked(xs, dt, p["a_log"], Bv, Cv, chunk)
        new_cache = None
    elif S == 1:
        h_new, y1 = ssd_step(cache["ssm"], xs[:, 0], dt[:, 0], p["a_log"],
                             Bv[:, 0], Cv[:, 0])
        y = y1[:, None]
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": h_new}
    else:  # prefill into cache
        y, h_new = ssd_chunked(xs, dt, p["a_log"], Bv, Cv, min(chunk, S),
                               h0=cache["ssm"])
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": h_new}

    y = y + xs * p["d_skip"][:, None].astype(y.dtype)
    y = y.reshape(b, S, d_in)
    y = norm_apply({"scale": p["norm_scale"]}, y * jax.nn.silu(z))
    return y @ p["w_out"].astype(x.dtype), new_cache
