"""Compiled-HLO text parser for the roofline analysis.

Why parse at all: ``compiled.cost_analysis()`` counts each while-loop body
ONCE (verified in tests), but every model here scans over layers/rounds/
chunks. This parser walks the HLO computation graph, infers while-loop
trip counts from the loop-condition constants, and accumulates:

  * dot FLOPs          — 2 * prod(result_shape) * contracted_dim, per dot
  * HBM bytes          — operand+result bytes at fusion/op granularity
                         (post-optimization fusions are the HBM-traffic
                         units on TPU)
  * collective bytes   — operand bytes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute

all scaled by the product of enclosing loop trip counts. Everything is
per-device (the HLO is already SPMD-partitioned).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\((.*)$")
_CALLED_RE = re.compile(
    r"(?:to_apply|body|condition|branch_computations|called_computations)="
    r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of possibly-tuple shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
    return n


@dataclass
class OpInfo:
    name: str
    shape: str
    opcode: str
    rest: str  # remainder of the line (operands + attributes)


@dataclass
class Computation:
    name: str
    ops: dict[str, OpInfo] = field(default_factory=dict)
    order: list[str] = field(default_factory=list)


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_hlo(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name: str | None = None
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)  # strip /*index=N*/ etc.
        s = line.strip()
        if not s:
            continue
        if not line.startswith(" ") and ("{" in s) and ("(" in s) and \
                not s.startswith("HloModule"):
            # computation header: "%name (args) -> shape {" or "ENTRY ..."
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", s)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if s.startswith("ENTRY"):
                    entry_name = cur.name
            continue
        if s.startswith("}"):
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            name, shape, opcode, rest = m.groups()
            cur.ops[name] = OpInfo(name, shape, opcode, rest)
            cur.order.append(name)
    return comps, entry_name


def _called_comps(op: OpInfo) -> list[str]:
    out = []
    for m in _CALLED_RE.finditer(op.rest):
        for nm in m.group(1).split(","):
            out.append(nm.strip().lstrip("%"))
    # fusions: calls=%name
    m = re.search(r"calls=%?([\w.\-]+)", op.rest)
    if m:
        out.append(m.group(1))
    return out


def _operand_names(op: OpInfo) -> list[str]:
    # ``rest`` starts just after the opcode's "(": operands run until the
    # matching close paren (depth starts at 1)
    depth = 1
    buf = []
    for ch in op.rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    inner = "".join(buf)
    return re.findall(r"%([\w.\-]+)", inner)


_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')


def while_trip_count(comps: dict[str, Computation], while_op: OpInfo,
                     cond_name: str | None) -> int:
    """Trip count: prefer XLA's known_trip_count backend_config annotation;
    fall back to the condition's compare-against-constant (including
    fusion-wrapped compares)."""
    m = _TRIP_RE.search(while_op.rest)
    if m:
        return max(1, int(m.group(1)))
    cond = comps.get(cond_name) if cond_name else None
    if cond is None:
        return 1
    const_vals = {}
    for nm in cond.order:
        op = cond.ops[nm]
        if op.opcode == "constant":
            mm = re.search(r"(-?\d+)", op.rest)
            if mm:
                const_vals[nm] = int(mm.group(1))
    for nm in cond.order:
        op = cond.ops[nm]
        if op.opcode in ("compare", "fusion"):
            for o in _operand_names(op):
                if o in const_vals and abs(const_vals[o]) > 0:
                    return abs(const_vals[o])
    return 1


COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclass
class RooflineCounts:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    # bytes inside named kernelizable scopes (e.g. blockwise_attention):
    # a Pallas kernel keeps these tiles in VMEM, so the achievable memory
    # term is hbm_bytes - kernelizable interior traffic + boundary reads
    scope_bytes: dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    collective_bytes: dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    loops: list[tuple[str, int]] = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


KERNEL_SCOPES = ("blockwise_attention", "wkv_chunk", "ssd_chunk")


def _op_scope(op: OpInfo) -> str | None:
    m = re.search(r'op_name="([^"]+)"', op.rest)
    if not m:
        return None
    for s in KERNEL_SCOPES:
        if s in m.group(1):
            return s
    return None


def _dot_flops(comp: Computation, op: OpInfo) -> float:
    """2 * prod(result) * K, K from contracting dims of operand 0."""
    out_elems = _shape_elems(op.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    operands = _operand_names(op)
    k = 1
    if m and operands:
        lhs = comp.ops.get(operands[0])
        if lhs is not None:
            sm = _SHAPE_RE.search(lhs.shape)
            if sm and sm.group(2):
                dims = [int(d) for d in sm.group(2).split(",") if d]
                for ci in m.group(1).split(","):
                    if ci != "" and int(ci) < len(dims):
                        k *= dims[int(ci)]
    return 2.0 * out_elems * k


def analyze_hlo(text: str) -> RooflineCounts:
    comps, entry = parse_hlo(text)
    counts = RooflineCounts()
    if entry is None:
        # fall back: a computation referenced by nobody
        called = set()
        for c in comps.values():
            for nm in c.order:
                for cc in _called_comps(c.ops[nm]):
                    called.add(cc)
        entries = [c for c in comps if c not in called]
        entry = entries[0] if entries else next(iter(comps))

    # fusion computations are costed at the fusion-op level, but dots
    # inside them still count FLOPs — track which comps are fusion bodies
    fusion_bodies = set()
    for c in comps.values():
        for nm in c.order:
            op = c.ops[nm]
            if op.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.rest)
                if m:
                    fusion_bodies.add(m.group(1))

    def walk(comp_name: str, mult: float, in_fusion: bool):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for nm in comp.order:
            op = comp.ops[nm]
            oc = op.opcode
            if oc == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", op.rest)
                trips = while_trip_count(comps, op,
                                         mc.group(1) if mc else None)
                counts.loops.append((nm, trips))
                if mb:
                    walk(mb.group(1), mult * trips, in_fusion)
                continue
            if oc in ("call", "conditional", "map", "reduce", "sort",
                      "reduce-window", "scatter", "select-and-scatter",
                      "custom-call"):
                for cc in _called_comps(op):
                    if cc in comps:
                        walk(cc, mult, in_fusion)
            def _add_hbm(nbytes):
                counts.hbm_bytes += mult * nbytes
                sc = _op_scope(op)
                if sc:
                    counts.scope_bytes[sc] += mult * nbytes

            def _io_bytes():
                ob = _shape_bytes(op.shape)
                ib = sum(_shape_bytes(comp.ops[o].shape)
                         for o in _operand_names(op) if o in comp.ops)
                return ob + ib

            if oc == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.rest)
                if m:
                    walk(m.group(1), mult, True)
                # HBM traffic at fusion granularity (TPU's HBM unit)
                _add_hbm(_io_bytes())
                continue
            if oc in ("dot", "convolution"):
                counts.dot_flops += mult * _dot_flops(comp, op)
                if not in_fusion:
                    _add_hbm(_io_bytes())
                continue
            for coll in COLLECTIVES:
                if oc == coll or oc == f"{coll}-start":
                    ib = sum(_shape_bytes(comp.ops[o].shape)
                             for o in _operand_names(op) if o in comp.ops)
                    if ib == 0:
                        ib = _shape_bytes(op.shape)
                    counts.collective_bytes[coll] += mult * ib
                    break
            else:
                if in_fusion:
                    continue
                ob = _shape_bytes(op.shape)
                if oc == "dynamic-update-slice":
                    # in-place on TPU: traffic ~ 2x the UPDATE, not the buffer
                    ops_ = _operand_names(op)
                    ub = _shape_bytes(comp.ops[ops_[1]].shape) \
                        if len(ops_) > 1 and ops_[1] in comp.ops else ob
                    _add_hbm(2 * min(ub, ob))
                elif oc in ("copy", "transpose", "slice", "dynamic-slice",
                            "gather", "concatenate", "pad", "reverse"):
                    _add_hbm(2 * ob)  # read + write of the result extent
                elif oc in ("scatter", "reduce", "sort", "reduce-window",
                            "select-and-scatter"):
                    _add_hbm(_io_bytes())
                elif oc in ("add", "multiply", "subtract", "divide",
                            "select", "maximum", "minimum", "exponential",
                            "tanh", "negate", "compare", "and", "or",
                            "power", "sqrt", "rsqrt", "log"):
                    # would fuse on TPU: charge the output once
                    _add_hbm(ob)

    walk(entry, 1.0, False)
    return counts
