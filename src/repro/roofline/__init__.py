from . import hlo

__all__ = ["hlo"]
