"""Three-term roofline analysis over the dry-run artifacts.

    compute    = HLO_dot_FLOPs_per_chip / peak_bf16
    memory     = HLO_HBM_bytes_per_chip / hbm_bw
    collective = HLO_collective_bytes_per_chip / ici_link_bw

All three come from the compiled, SPMD-partitioned HLO via
``repro.roofline.hlo`` (while-loop trip counts included — XLA's own
cost_analysis counts loop bodies once, verified in tests). MODEL_FLOPS
uses 6·N·D (train), 2·N·D (prefill), 2·N_active·B (decode) so the
useful-compute ratio exposes remat/redundancy waste.

Usage:
  python -m repro.roofline.analysis [--glob '*pod*'] [--out artifacts/roofline.md]
"""
from __future__ import annotations

import argparse
import gzip
import json
from dataclasses import dataclass
from pathlib import Path

from repro.config import DEFAULT_HW
from repro.roofline.hlo import analyze_hlo

ART = Path("artifacts") / "dryrun"


@dataclass
class CellRoofline:
    name: str
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    hbm_gb_per_chip: float
    coll_gb_per_chip: float
    loops: list
    collective_breakdown: dict

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap bound: the step can't be faster than the max term."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time over the bound — the score being pushed up."""
        hw = DEFAULT_HW
        t_useful = self.model_flops / hw.peak_bf16_flops
        return t_useful / self.step_time_s if self.step_time_s else 0.0


def model_flops_for(rec: dict) -> float:
    """Per-chip useful FLOPs for the step."""
    chips = rec["num_devices"]
    kind = rec.get("kind")
    if kind == "gcn":
        g = rec["graph"]
        F_in, F_out = 500, 128  # overridden below if present
        flops = 2.0 * g["E"] * F_in + 2.0 * g["V"] * F_in * F_out
        return flops / chips
    n = rec["active_param_count"]
    B = rec["global_batch"]
    S = rec["seq_len"]
    if kind == "train":
        return 6.0 * n * B * S / chips
    if kind == "prefill":
        return 2.0 * n * B * S / chips
    return 2.0 * n * B / chips  # decode: one token


def analyze_cell(json_path: Path, hw=DEFAULT_HW) -> CellRoofline | None:
    rec = json.loads(json_path.read_text())
    hlo_path = json_path.with_suffix("").with_suffix("")  # strip .json
    hlo_gz = json_path.parent / (json_path.stem + ".hlo.gz")
    if not hlo_gz.exists():
        return None
    with gzip.open(hlo_gz, "rt") as f:
        counts = analyze_hlo(f.read())

    scale = rec.get("round_scale", 1.0)  # GCN cells extrapolate rounds
    flops = counts.dot_flops * scale
    hbm = counts.hbm_bytes * scale
    coll = counts.total_collective_bytes * scale

    compute_s = flops / hw.peak_bf16_flops
    memory_s = hbm / hw.hbm_bandwidth
    collective_s = coll / hw.ici_link_bandwidth
    dom = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return CellRoofline(
        name=json_path.stem, arch=rec["arch"], shape=rec["shape"],
        mesh=rec["mesh"], compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dom,
        model_flops=model_flops_for(rec), hlo_flops=flops,
        hbm_gb_per_chip=hbm / 2**30, coll_gb_per_chip=coll / 2**30,
        loops=counts.loops,
        collective_breakdown={k: v * scale for k, v in
                              counts.collective_bytes.items()})


def render_table(cells: list[CellRoofline]) -> str:
    head = ("| cell | compute s | memory s | collective s | dominant | "
            "useful ratio | roofline frac |\n"
            "|---|---|---|---|---|---|---|\n")
    rows = []
    for c in sorted(cells, key=lambda c: (c.arch, c.shape, c.mesh)):
        rows.append(
            f"| {c.arch}/{c.shape}/{c.mesh} | {c.compute_s:.3e} | "
            f"{c.memory_s:.3e} | {c.collective_s:.3e} | {c.dominant} | "
            f"{c.useful_ratio:.2f} | {c.roofline_fraction:.2%} |")
    return head + "\n".join(rows) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--glob", default="*.json")
    ap.add_argument("--out", default="artifacts/roofline.md")
    args = ap.parse_args()

    cells = []
    for p in sorted(ART.glob(args.glob)):
        if p.name.endswith(".fail.txt"):
            continue
        try:
            c = analyze_cell(p)
        except Exception as e:
            print(f"[warn] {p.name}: {type(e).__name__}: {e}")
            continue
        if c:
            cells.append(c)
            print(f"{c.name}: comp={c.compute_s:.2e}s mem={c.memory_s:.2e}s "
                  f"coll={c.collective_s:.2e}s dom={c.dominant} "
                  f"useful={c.useful_ratio:.2f} frac={c.roofline_fraction:.1%}")
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(render_table(cells))
    js = [c.__dict__ | {"useful_ratio": c.useful_ratio,
                        "roofline_fraction": c.roofline_fraction,
                        "step_time_s": c.step_time_s} for c in cells]
    Path(str(out) + ".json").write_text(json.dumps(js, indent=1, default=str))
    print(f"wrote {out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
