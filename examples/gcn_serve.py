"""Multi-graph GCN serving quickstart: three RMAT graphs (different
sizes AND different message-passing models) served through one
``GCNService`` on a 2x2 torus — per-step request batching, shared
byte-bounded caches, and async double-buffered plan upload, with the
async path asserted bit-identical to the synchronous fallback.

    PYTHONPATH=src python examples/gcn_serve.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.launch.gcn_serve import build_service, drive

F = 16


def serve_workload(async_upload: bool):
    # the exact mixed workload the serve benchmark drives (models and
    # RMAT scales cycle: gcn@9, gin@10, sage@11; interleaved requests),
    # on a 2x2 torus
    svc, graphs = build_service((2, 2), num_graphs=3, base_scale=9,
                                feat_in=F, layer_dims=[16, 8],
                                max_batch=4, async_upload=async_upload,
                                plan_budget_bytes=None)
    done, _ = drive(svc, graphs, num_requests=9, feat_in=F, seed=0)
    return svc, sorted(done, key=lambda r: r.rid)


def main():
    svc, reqs = serve_workload(async_upload=True)
    assert len(reqs) == 9 and all(r.done for r in reqs)

    # every request matches its session's single-device oracle
    # (requests are store-backed — r.feats is None — so the oracle
    # input is the session's registered features, gathered through the
    # feature store's device cache)
    for r in reqs:
        eng = svc.sessions[r.session]
        ref = eng.reference(svc.session_features(r.session).gather_all())
        err = np.max(np.abs(r.out - ref)) / (np.max(np.abs(ref)) + 1e-9)
        assert err < 1e-4, (r.session, err)
    st = svc.stats()
    print(f"{st['requests']} requests / {st['sessions']} graphs: "
          f"{st['requests_per_sec']:.2f} req/s, mean batch "
          f"{st['mean_batch']:.1f}, upload overlap "
          f"{st['upload_overlap_fraction']:.0%}")

    # the async double-buffered upload path is bit-identical to the
    # synchronous fallback (the fence runs before any consumer)
    _, sync_reqs = serve_workload(async_upload=False)
    for ra, rs in zip(reqs, sync_reqs):
        assert ra.session == rs.session
        np.testing.assert_array_equal(ra.out, rs.out)
    print("async double-buffered upload == sync fallback (bit-identical); "
          f"all {len(reqs)} outputs match the single-device oracle")


if __name__ == "__main__":
    main()
