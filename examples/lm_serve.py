"""Serve a small model with batched requests: continuous-batching engine,
prefill + lockstep decode over slot pool, per-request completion.

    PYTHONPATH=src python examples/lm_serve.py
"""
import time

import jax
import numpy as np

from repro.config import get_lm_config
from repro.models import lm
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_lm_config("minitron-8b", "smoke")
    params = lm.lm_init(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=4, max_len=96)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=4 + i % 5),
                    max_new=8)
            for i in range(10)]
    for r in reqs:
        engine.submit(r)

    t0 = time.perf_counter()
    ticks = 0
    while engine.queue or any(engine.active):
        engine.step()
        ticks += 1
        if ticks > 500:
            raise RuntimeError("engine did not drain")
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests / {tokens} tokens in {dt:.2f}s "
          f"({ticks} ticks, {tokens / dt:.1f} tok/s on CPU)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt={r.prompt.tolist()} -> {r.out}")
    assert all(len(r.out) >= r.max_new for r in reqs)
    print("OK")


if __name__ == "__main__":
    main()
