"""Quickstart: MultiGCN inference on a synthetic graph, single process.

Builds a small RMAT graph and a ``GCNEngine`` session on a (1,1) "torus"
(single device — the same engine scales to the 512-chip dry-run mesh),
runs the TMM+SREM distributed pipeline, and checks the result against
the engine's dense single-device oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import numpy as np

from repro.config import get_gcn_config
from repro.core.rmat import rmat
from repro.gcn import GCNEngine


def main():
    cfg = get_gcn_config("gcn-gcn-rd", "smoke")
    cfg = dataclasses.replace(cfg, agg_buffer_bytes=8 << 10)
    graph = rmat(10, 1 << 14, seed=1, name="quickstart")
    print(f"graph: |V|={graph.num_vertices} |E|={graph.num_edges} "
          f"d̄={graph.avg_degree:.1f}")

    engine = GCNEngine.build(cfg, graph, (1, 1))
    print(f"plan: rounds={engine.plan.num_rounds} "
          f"replica_rows={engine.plan.replica_rows} "
          f"multicast items={engine.plan.stats['items']}")

    F = cfg.graph.feat_in
    engine.init_params(jax.random.PRNGKey(0), [F, 64, 16])
    feats = np.random.default_rng(0).normal(size=(graph.num_vertices, F)) \
        .astype(np.float32)

    out = engine.forward(feats)  # global (V, F) in -> global (V, 16) out
    ref = engine.reference(feats)
    err = np.max(np.abs(out - ref)) / np.max(np.abs(ref))
    print(f"2-layer GCN inference done; max rel err vs oracle = {err:.2e}")
    assert err < 1e-4

    # same network through the Pallas blocked-ELL aggregation kernel
    # (interpret mode off-TPU) — switching backends reuses the CommPlan
    out_pl = engine.forward(feats, agg_impl="pallas")
    err_pl = np.max(np.abs(out_pl - ref)) / np.max(np.abs(ref))
    st = engine.stats()
    print(f"agg backends: default={st['agg_impl']} "
          f"(cfg {cfg.agg_impl!r}); pallas rel err = {err_pl:.2e}")
    print(f"agg traffic estimate: dense {st['agg_dense_bytes'] / 2**10:.0f} "
          f"KiB vs ELL {st['agg_ell_bytes'] / 2**10:.0f} KiB "
          f"(reduction {st['agg_traffic_reduction']:+.0%})")
    assert err_pl < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
