"""Quickstart: MultiGCN inference on a synthetic graph, single process.

Builds a small RMAT graph, partitions it with the paper's bit-field round
partition, runs the TMM+SREM distributed pipeline on a (1,1) "torus"
(single device — the same code scales to the 512-chip dry-run mesh), and
checks the result against the dense single-device oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_gcn_config
from repro.core import gcn_models as gm
from repro.core.partition import TorusMesh
from repro.core.plan import build_plan
from repro.core.message_passing import shard_features, unshard_features
from repro.core.rmat import rmat


def main():
    cfg = get_gcn_config("gcn-gcn-rd", "smoke")
    cfg = dataclasses.replace(cfg, agg_buffer_bytes=8 << 10)
    graph = rmat(10, 1 << 14, seed=1, name="quickstart")
    print(f"graph: |V|={graph.num_vertices} |E|={graph.num_edges} "
          f"d̄={graph.avg_degree:.1f}")

    mesh = jax.make_mesh((1, 1), ("x", "y"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    tor = TorusMesh((1, 1))
    plan = gm.build_gcn_plan(cfg, graph, tor)
    print(f"plan: rounds={plan.num_rounds} replica_rows={plan.replica_rows} "
          f"multicast items={plan.stats['items']}")

    F = cfg.graph.feat_in
    params = gm.gcn_params(cfg, jax.random.PRNGKey(0), [F, 64, 16])
    feats = np.random.default_rng(0).normal(size=(graph.num_vertices, F)) \
        .astype(np.float32)
    fs = jnp.asarray(shard_features(plan, feats))

    out = gm.distributed_forward(cfg, params, plan, mesh, ("x", "y"), fs)
    out_g = unshard_features(plan, np.asarray(out), graph.num_vertices)
    ref = np.asarray(gm.reference_forward(cfg, params, graph,
                                          jnp.asarray(feats)))
    err = np.max(np.abs(out_g - ref)) / np.max(np.abs(ref))
    print(f"2-layer GCN inference done; max rel err vs oracle = {err:.2e}")
    assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
