"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
on CPU with the full production substrate — config registry, synthetic
data pipeline with prefetch, AdamW, async checkpointing, preemption-safe
resume.

    PYTHONPATH=src python examples/lm_train.py [--steps 200] [--arch glm4-9b]

(The arch's *smoke-family* config is widened to ~100M params; the same
driver lowers the full config on the 512-chip mesh via the dry-run.)
"""
import argparse
import dataclasses

from repro.config import get_lm_config
from repro.train import optimizer as optlib
from repro.train.loop import TrainConfig, train


def hundred_m(arch: str):
    cfg = get_lm_config(arch, "smoke")
    return dataclasses.replace(
        cfg, name=cfg.name.replace("smoke", "100m"),
        num_layers=4, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=50_304, blocks=())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    cfg = hundred_m(args.arch)
    print(f"model: {cfg.name} params={cfg.param_count() / 1e6:.0f}M")
    tcfg = TrainConfig(
        steps=args.steps, log_every=10, ckpt_every=50, ckpt_dir=args.ckpt,
        opt=optlib.AdamWConfig(lr=1e-3, warmup_steps=20,
                               total_steps=args.steps))
    out = train(cfg, tcfg)
    h = out["history"]
    if not h:
        print(f"checkpoint already at/past step {args.steps}; nothing to do "
              f"(use --steps higher or a fresh --ckpt dir)")
        return
    print(f"loss: {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} "
          f"over {args.steps} steps")
    assert h[-1]["loss"] < h[0]["loss"]
    print("OK")


if __name__ == "__main__":
    main()
