"""Distributed GCN training quickstart: full-batch node classification
on a partitioned RMAT graph over a 2x2 torus, differentiated THROUGH
the multicast exchange (the VJP is a reversed relay replay), ending in
the train->serve handoff — the trained session is adopted by a
``GCNService`` and serves without replanning — plus the
neighbor-sampled mini-batch pipeline (``fit_sampled``) that trains the
same graph through per-batch subgraph plans.

    PYTHONPATH=src python examples/gcn_train.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import numpy as np

from repro.config import get_gcn_config
from repro.core.rmat import rmat
from repro.gcn import (GCNEngine, GCNService, GCNTrainer, cache_stats,
                       reference_loss_and_grad)
from repro.launch.gcn_train import synthetic_labels

F, C = 16, 8


def main():
    graph = rmat(9, 1 << 12, seed=3)
    feats, labels = synthetic_labels(graph, F, C, seed=0)
    mask = (np.random.default_rng(0).random(graph.num_vertices)
            < 0.8).astype(np.float32)
    cfg = dataclasses.replace(get_gcn_config("gcn-gcn-rd", "smoke"),
                              agg_buffer_bytes=8 << 10)

    eng = GCNEngine.build(cfg, graph, (2, 2))
    trainer = GCNTrainer(eng, labels, mask)
    report = trainer.fit(feats, epochs=20, layer_dims=[F, 16, C],
                         log_every=5)
    assert report.loss_last < report.loss_first
    print(f"loss {report.loss_first:.4f} -> {report.loss_last:.4f}; "
          f"train acc {trainer.evaluate(feats)['accuracy']:.2%}; "
          f"exchange {report.exchange_bytes_per_step / 2**10:.1f} KiB per "
          f"training step (forward + transposed backward replays)")

    # distributed gradients match the dense single-node oracle
    loss_d, grads_d = eng.loss_and_grad(feats, labels, mask)
    loss_r, grads_r = reference_loss_and_grad(eng, feats, labels, mask)
    err = max(
        float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
        / (float(np.max(np.abs(np.asarray(b)))) + 1e-9)
        for a, b in zip(jax.tree.leaves(grads_d), jax.tree.leaves(grads_r)))
    assert err < 1e-4, err
    print(f"grad parity vs single-node dense reference: "
          f"max rel err {err:.1e}")

    # train->serve handoff: the trained session serves as-is
    svc = GCNService((2, 2))
    misses0 = cache_stats()["plan"]["misses"]
    svc.adopt("trained", eng)
    out = svc.infer("trained", feats)
    assert cache_stats()["plan"]["misses"] == misses0, "no replanning"
    ref = eng.reference(feats)
    rel = np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert rel < 1e-4, rel
    print("served trained params through GCNService without replanning "
          f"(oracle rel err {rel:.1e})")

    # scale past the mesh: neighbor-sampled mini-batches train through
    # per-batch subgraph plans — the full-batch plan is never needed
    eng_s = GCNEngine.build(cfg, graph, (2, 2))
    trainer_s = GCNTrainer(eng_s, labels, mask)
    rep = trainer_s.fit_sampled(feats, epochs=8, batch_size=128,
                                fanouts=(8, 8), layer_dims=[F, 16, C])
    assert rep.loss_last < rep.loss_first
    assert rep.batch_plan_hit_rate > 0
    print(f"sampled: loss {rep.loss_first:.4f} -> {rep.loss_last:.4f} "
          f"({rep.batches_per_epoch} batches/epoch, vertex buckets "
          f"{rep.vertex_buckets}, batch-plan hit rate "
          f"{rep.batch_plan_hit_rate:.2f}, "
          f"{rep.exchange_bytes_per_step / 2**10:.1f} KiB exchanged per "
          f"sampled step)")


if __name__ == "__main__":
    main()
