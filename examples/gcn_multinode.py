"""Multi-node MultiGCN: the paper's three message-passing models executed
on an 8-device (4x2) torus via the ``GCNEngine`` session API, with live
byte accounting — the executable version of Table 6.

    PYTHONPATH=src python examples/gcn_multinode.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import numpy as np

from repro.config import get_gcn_config
from repro.core.rmat import rmat
from repro.gcn import GCNEngine, plan_cache_stats

F = 64


def main():
    graph = rmat(11, 1 << 15, seed=2, name="multinode")
    feats = np.random.default_rng(1).normal(
        size=(graph.num_vertices, F)).astype(np.float32)
    cfg = get_gcn_config("gcn-gcn-rd", "smoke")
    cfg = dataclasses.replace(cfg, use_rounds=True, agg_buffer_bytes=8 << 10)

    base = GCNEngine.build(cfg, graph, (4, 2))
    params = base.init_params(jax.random.PRNGKey(0), [F, 16])
    print(f"aggregation backend: {cfg.agg_impl!r} -> {base.agg_impl} "
          f"(jax backend={jax.default_backend()})")

    results = {}
    bytes_moved = {}
    engines = {}
    for mpm in ("oppe", "oppr", "oppm"):
        eng = base.with_config(message_passing=mpm)
        engines[mpm] = eng
        results[mpm] = eng.forward(feats, params)
        st = eng.stats(feat_dim=F)
        # the executor's ACTUAL ppermute payload — counted from the
        # traced exchange, independent of the plan's bookkeeping — must
        # match the planner's analytic count (the plan docstring promise:
        # "every byte the executor moves is countable analytically")
        measured = eng.measured_link_bytes(feat_dim=F)
        assert measured == st["plan_executor_link_bytes"], (
            measured, st["plan_executor_link_bytes"])
        bytes_moved[mpm] = st["link_bytes"]
        print(f"{mpm:5s}: rounds={eng.plan.num_rounds:3d} "
              f"link-bytes={bytes_moved[mpm] / 2**20:8.1f} MiB "
              f"(multicast items={st['items']})")

    # all three models compute the SAME aggregation
    for mpm in ("oppr", "oppm"):
        err = np.max(np.abs(results[mpm] - results["oppe"]))
        assert err < 1e-3, (mpm, err)

    # ...and so does the Pallas blocked-ELL aggregation backend, reusing
    # the oppm engine's CommPlan (backend switches never replan)
    out_pl = engines["oppm"].forward(feats, params, agg_impl="pallas")
    err = np.max(np.abs(out_pl - results["oppm"]))
    assert err < 1e-3, err
    print(f"pallas aggregation backend matches (max abs err {err:.1e})")

    # switching ONLY the message-passing model back is a plan-cache hit:
    # the host-side mapping is reused, not rebuilt
    before = plan_cache_stats()
    again = base.with_config(message_passing="oppr")
    assert again.plan is engines["oppr"].plan, "expected plan-cache hit"
    after = plan_cache_stats()
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]
    print(f"plan cache: {after['hits']} hits / {after['misses']} misses "
          f"({after['entries']} plans) — re-selecting oppr replanned nothing")

    saving = 1 - bytes_moved["oppm"] / bytes_moved["oppe"]
    print(f"numerics identical across models; OPPM moves {saving:.0%} "
          f"fewer link-bytes than OPPE (the paper's trade)")


if __name__ == "__main__":
    main()
