"""Multi-node MultiGCN: the paper's three message-passing models executed
on an 8-device (4x2) torus, with live byte accounting — the executable
version of Table 6.

    PYTHONPATH=src python examples/gcn_multinode.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_gcn_config
from repro.core import gcn_models as gm
from repro.core.message_passing import shard_features, unshard_features
from repro.core.partition import TorusMesh
from repro.core.rmat import rmat


def main():
    mesh = jax.make_mesh((4, 2), ("x", "y"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    tor = TorusMesh((4, 2))
    graph = rmat(11, 1 << 15, seed=2, name="multinode")
    feats = np.random.default_rng(1).normal(
        size=(graph.num_vertices, 64)).astype(np.float32)

    results = {}
    bytes_moved = {}
    for mpm in ("oppe", "oppr", "oppm"):
        cfg = get_gcn_config("gcn-gcn-rd", "smoke")
        cfg = dataclasses.replace(cfg, message_passing=mpm, use_rounds=True,
                                  agg_buffer_bytes=8 << 10)
        plan = gm.build_gcn_plan(cfg, graph, tor)
        params = gm.gcn_params(cfg, jax.random.PRNGKey(0), [64, 16])
        fs = jnp.asarray(shard_features(plan, feats))
        out = gm.distributed_forward(cfg, params, plan, mesh, ("x", "y"), fs)
        results[mpm] = unshard_features(plan, np.asarray(out),
                                        graph.num_vertices)
        bytes_moved[mpm] = plan.stats["link_feat_hops"] * 64 * 4
        print(f"{mpm:5s}: rounds={plan.num_rounds:3d} "
              f"link-bytes={bytes_moved[mpm] / 2**20:8.1f} MiB "
              f"(multicast items={plan.stats['items']})")

    # all three models compute the SAME aggregation
    for mpm in ("oppr", "oppm"):
        err = np.max(np.abs(results[mpm] - results["oppe"]))
        assert err < 1e-3, (mpm, err)
    saving = 1 - bytes_moved["oppm"] / bytes_moved["oppe"]
    print(f"numerics identical across models; OPPM moves {saving:.0%} "
          f"fewer link-bytes than OPPE (the paper's trade)")


if __name__ == "__main__":
    main()
