"""Table 6: network transmissions and DRAM accesses of MultiGCN
configurations, normalized to the OPPE baseline. All five variants per
workload derive from one ``GCNEngine`` session (``suite_for``), sharing
its vertex partition.

Paper GM: TMM 13%/75%, SREM 100%/66%, TMM+SREM 68%/27%."""
from __future__ import annotations

from benchmarks.common import MESH_4X4, gm, load, suite_for, timed


def run():
    rows = []
    agg = {k: {"t": [], "d": []} for k in ("tmm", "srem", "tmm+srem")}
    for model in ("gcn", "gin", "sage"):
        for gname in ("rd", "or", "lj"):
            cfg, g = load(gname, model)
            suite, us = timed(lambda: suite_for(cfg, g, MESH_4X4))
            base = suite["oppe"].totals()
            for k in agg:
                t = suite[k].totals()
                nt = t["net_bytes"] / base["net_bytes"]
                nd = t["dram_bytes"] / base["dram_bytes"]
                agg[k]["t"].append(nt)
                agg[k]["d"].append(nd)
                rows.append((f"table6.{model}.{gname}.{k}", us,
                             f"trans={nt:.1%};dram={nd:.1%}"))
    paper = {"tmm": "13%/75%", "srem": "100%/66%", "tmm+srem": "68%/27%"}
    for k, v in agg.items():
        rows.append((f"table6.GM.{k}", 0.0,
                     f"trans={gm(v['t']):.1%};dram={gm(v['d']):.1%}"
                     f" (paper GM {paper[k]})"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
