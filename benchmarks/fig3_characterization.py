"""Fig. 3: characterization of the OPPE baseline — redundancy ratios and
bandwidth/latency sensitivity (the two observations motivating MultiGCN).
Variants derive from one ``GCNEngine`` session per graph (``suite_for``).

Paper: redundant transmissions 78–96 %; redundant DRAM 25–99.9 %;
bandwidth-bound (linear speedup with net BW when DRAM BW sufficient);
latency-tolerant (flat up to ~20 µs)."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import MESH_4X4, load, suite_for
from repro.config import PAPER_NODE


def run():
    rows = []
    for gname in ("rd", "or", "lj"):
        cfg, g = load(gname, "gcn")
        suite = suite_for(cfg, g, MESH_4X4)
        base = suite["oppe"].totals()
        dedup = suite["tmm"].totals()
        red_trans = 1.0 - dedup["net_bytes"] / base["net_bytes"]
        spill = suite["oppe"].dram_rand_bytes.sum()
        red_dram = spill / max(base["dram_bytes"], 1e-9)
        rows.append((f"fig3.redundancy.{gname}", 0.0,
                     f"red_trans={red_trans:.0%};red_dram={red_dram:.0%}"
                     " (paper 78-96% / 25-99.9%)"))

        # bandwidth sweep (paper Fig 3c-e): speedup vs net bandwidth
        rep = suite["oppe"]
        t_ref = None
        for bw_gbs in (150, 300, 600, 1200):
            hw = dataclasses.replace(PAPER_NODE, net_bandwidth=bw_gbs * 1e9)
            t = rep.time_model(hw)["time_s"]
            t_ref = t_ref or t
            rows.append((f"fig3.bw{bw_gbs}.{gname}", 0.0,
                         f"speedup={t_ref / t:.2f}"))
        # latency sweep (paper Fig 3f): flat until ~20k ns
        t0 = rep.time_model(PAPER_NODE)["time_s"]
        for lat_ns in (500, 5_000, 20_000, 80_000):
            hw = dataclasses.replace(PAPER_NODE,
                                     net_latency_cycles=lat_ns)
            t = rep.time_model(hw)["time_s"]
            rows.append((f"fig3.lat{lat_ns}ns.{gname}", 0.0,
                         f"norm_time={t / t0:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
