"""Benchmark harness: one module per paper table/figure + framework
microbenches. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig8,table6]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "fig3_characterization",
    "fig8_speedup",
    "table6_comm",
    "table7_reduction",
    "fig11_sensitivity",
    "moe_dispatch_bench",
    "lm_step_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma list of module stems")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    print("name,us_per_call,derived")
    failures = 0
    for stem in MODULES:
        if only and not any(stem.startswith(o) or o in stem for o in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{stem}", fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us},{derived}", flush=True)
            print(f"# {stem} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {stem} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
