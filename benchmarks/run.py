"""Benchmark harness: one module per paper table/figure + framework
microbenches. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig8,table6]
  PYTHONPATH=src python -m benchmarks.run --suite smoke   # engine example
                                                          # + tier-1 tests
                                                          # on 8 host devices
  PYTHONPATH=src python -m benchmarks.run --suite serve   # multi-graph
                                                          # GCNService bench,
                                                          # writes the
                                                          # "serve" record
  PYTHONPATH=src python -m benchmarks.run --suite train   # distributed GCN
                                                          # training bench,
                                                          # writes the
                                                          # "train" record
  PYTHONPATH=src python -m benchmarks.run --suite train-sampled
                                                          # neighbor-sampled
                                                          # mini-batch bench,
                                                          # writes the
                                                          # "train-sampled"
                                                          # record
  PYTHONPATH=src python -m benchmarks.run --suite train-cv
                                                          # control-variate
                                                          # fanout-2 vs plain
                                                          # fanout-8 gate,
                                                          # writes the
                                                          # "train-cv" record

``BENCH_gcn.json`` holds one record per suite (serve + train +
train-sampled + train-cv); each suite refreshes only its own slot, so
``make bench-json`` (all suites) rebuilds the full checked-in baseline.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import time
import traceback
from pathlib import Path

MODULES = [
    "fig3_characterization",
    "fig8_speedup",
    "table6_comm",
    "table7_reduction",
    "fig11_sensitivity",
    "moe_dispatch_bench",
    "lm_step_bench",
]


def _forced_host_env(root: Path) -> dict:
    """Subprocess environment every suite benchmarks under: 8 forced
    host devices (set before jax initializes) and src on PYTHONPATH."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (str(root / "src") + os.pathsep
                         + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    return env


def _assert_telemetry(rec: dict, suite: str) -> None:
    """Every suite record must embed a schema-versioned snapshot of the
    process-wide metrics registry (``repro.gcn.obs``) — the machine-
    readable counters future PRs diff perf claims against. A missing or
    version-skewed snapshot means a launcher stopped embedding it (or
    obs changed shape without bumping the schema)."""
    from repro.gcn.obs import TELEMETRY_SCHEMA_VERSION

    t = rec.get("telemetry")
    assert isinstance(t, dict), \
        f"{suite} record carries no telemetry snapshot: {sorted(rec)}"
    assert t.get("schema_version") == TELEMETRY_SCHEMA_VERSION, \
        (f"{suite} telemetry schema {t.get('schema_version')!r} != "
         f"expected {TELEMETRY_SCHEMA_VERSION}")
    assert isinstance(t.get("metrics"), dict) and t["metrics"], \
        f"{suite} telemetry snapshot has no metrics"
    print(f"# {suite} telemetry gate: schema v{t['schema_version']}, "
          f"{len(t['metrics'])} metric(s)", flush=True)


def run_smoke() -> int:
    """One-command multi-device smoke: the GCNEngine example (8 forced
    host devices) plus the tier-1 test suite. Each step runs in its own
    subprocess so the XLA device-count flag is set before jax initializes
    (tests that need a different view re-exec themselves; see
    tests/conftest.py)."""
    root = Path(__file__).resolve().parent.parent
    env = _forced_host_env(root)
    # report which aggregation backend "auto" resolves to in this
    # environment, so the perf numbers below are attributable (probed in
    # a subprocess with the same env/flags the steps run under)
    probe = subprocess.run(
        [sys.executable, "-c",
         "import jax; from repro.gcn import resolve_agg_impl; "
         "print(resolve_agg_impl('auto'), jax.default_backend())"],
        env=env, cwd=root, capture_output=True, text=True)
    tokens = probe.stdout.split()
    if probe.returncode == 0 and len(tokens) >= 2:
        # last two tokens: anything before them is stray import chatter
        impl, backend = tokens[-2:]
        print(f"# smoke:agg_impl: auto -> {impl} (jax backend={backend})",
              flush=True)
    else:
        print(f"# smoke:agg_impl: probe failed (rc={probe.returncode}):\n"
              f"{probe.stdout}{probe.stderr}", flush=True)
    steps = [
        ("engine-example", [sys.executable,
                            str(root / "examples" / "gcn_multinode.py")]),
        ("tier1-tests", [sys.executable, "-m", "pytest", "-q",
                         str(root / "tests")]),
    ]
    rc = 0
    for name, cmd in steps:
        print(f"# smoke:{name}: {' '.join(cmd)}", flush=True)
        r = subprocess.run(cmd, env=env, cwd=root)
        print(f"# smoke:{name} -> {'OK' if r.returncode == 0 else 'FAIL'}",
              flush=True)
        rc = rc or r.returncode
    return rc


def run_serve(json_path: str) -> int:
    """Multi-graph serving benchmark: the mixed-RMAT GCNService workload
    (3 graphs x 3 models, interleaved requests, async double-buffered
    plan upload) on 8 forced host devices, recording the machine-
    readable perf trajectory to ``json_path`` — suite, wall time,
    requests/sec, aggregation backend, link bytes, upload-overlap
    fraction, feature-store hit rate (requests are store-backed under a
    64 MiB device budget; hit rate asserted > 0) — so future PRs can
    diff serving perf against a baseline. Runs in a subprocess so the
    device-count flag precedes jax init.

    A second pass re-serves the same workload under a 64 KiB plan
    budget (two of the three graphs' plans provably exceed it):
    ``admission=auto`` must route those sessions layer-major,
    ``--verify-full`` pins their outputs bit-exactly against an
    unbudgeted full forward inside the driver, and this gate checks
    the recorded ``layer_major`` sub-record — ``peak_feature_bytes``
    below the dense full-forward bytes and
    ``inference_overlap_fraction`` > 0 — before merging it into the
    main serve record."""
    import json

    root = Path(__file__).resolve().parent.parent
    env = _forced_host_env(root)
    cmd = [sys.executable, "-m", "repro.launch.gcn_serve",
           "--mesh", "2x2", "--graphs", "3", "--requests", "24",
           "--batch", "4", "--feature-budget", "64",
           "--json", json_path]
    print(f"# serve: {' '.join(cmd)}", flush=True)
    r = subprocess.run(cmd, env=env, cwd=root)
    print(f"# serve -> {'OK' if r.returncode == 0 else 'FAIL'}", flush=True)
    if r.returncode:
        return r.returncode

    with tempfile.TemporaryDirectory() as td:
        lm_json = str(Path(td) / "serve_lm.json")
        cmd = [sys.executable, "-m", "repro.launch.gcn_serve",
               "--mesh", "2x2", "--graphs", "3", "--requests", "24",
               "--batch", "4", "--feature-budget", "64",
               "--plan-budget-kb", "64", "--admission", "auto",
               "--chunk-size", "128", "--verify-full",
               "--json", lm_json]
        print(f"# serve layer-major: {' '.join(cmd)}", flush=True)
        r = subprocess.run(cmd, env=env, cwd=root)
        print(f"# serve layer-major -> "
              f"{'OK' if r.returncode == 0 else 'FAIL'}", flush=True)
        if r.returncode:
            return r.returncode
        lm = json.loads(Path(lm_json).read_text())["serve"] \
            .get("layer_major")
    assert lm is not None, "over-budget pass served no layer-major session"
    assert lm["sessions"] > 0
    assert lm["verified_full_parity"], "bit-parity oracle did not run"
    assert lm["peak_feature_bytes"] < lm["dense_feature_bytes"], \
        f"layer-major peak not bounded: {lm}"
    assert lm["inference_overlap_fraction"] is not None \
        and lm["inference_overlap_fraction"] > 0, \
        f"no chunk-prepare time was hidden: {lm}"
    print(f"# serve layer-major gate: {lm['sessions']} sessions, "
          f"{lm['requests_per_sec']} req/s, peak "
          f"{lm['peak_feature_bytes']}B < dense "
          f"{lm['dense_feature_bytes']}B, overlap "
          f"{lm['inference_overlap_fraction']:.2f}", flush=True)

    # merge the gated sub-record into the checked-in serve record
    from repro.launch.bench_record import write_record

    rec = json.loads(Path(json_path).read_text())["serve"]
    _assert_telemetry(rec, "serve")
    rec["layer_major"] = lm
    write_record(json_path, "serve", rec)
    return 0


def run_train(json_path: str) -> int:
    """Distributed GCN training benchmark: full-batch node
    classification for GCN/GIN/SAGE on one partitioned RMAT graph
    (8 forced host devices, 2x2 torus), differentiated through the
    multicast exchange, ending in the train->serve handoff
    (``GCNService.adopt`` + one oracle-checked request per model).
    Records loss trajectory, epoch wall time and measured exchange
    bytes per step under the ``"train"`` key of ``json_path``."""
    root = Path(__file__).resolve().parent.parent
    env = _forced_host_env(root)
    cmd = [sys.executable, "-m", "repro.launch.gcn_train",
           "--mesh", "2x2", "--models", "gcn,gin,sage",
           "--scale", "9", "--epochs", "12", "--json", json_path]
    print(f"# train: {' '.join(cmd)}", flush=True)
    r = subprocess.run(cmd, env=env, cwd=root)
    print(f"# train -> {'OK' if r.returncode == 0 else 'FAIL'}", flush=True)
    if r.returncode:
        return r.returncode
    import json

    _assert_telemetry(json.loads(Path(json_path).read_text())["train"],
                      "train")
    return 0


def run_train_sampled(json_path: str, pipeline_depth: int = 2) -> int:
    """Neighbor-sampled mini-batch training benchmark: bounded-fanout
    subgraph batches over one RMAT graph on a 2x2 torus (8 forced host
    devices), each batch on its own cached+padded relay plan — the
    full-batch plan is never built by training (the driver asserts it),
    and fixed seed sets must hit the batch-plan cache from epoch 2 on
    (asserted > 0: the smoke-level tripwire for subgraph-fingerprint
    regressions). Features flow through the process-wide feature store
    under a 64 MiB device budget (hit rate asserted > 0.5, gathered
    bytes asserted below the dense-slice baseline). The sampling
    pipeline runs at depth 2: the driver fits the first model serially
    AND pipelined (bit-identical, asserted in-driver) and this gate
    checks the recorded pair — overlap fraction > 0 and pipelined
    epoch wall <= serial epoch wall. The run exports a Chrome trace
    (``--trace-out``) which ``tools/check_trace.py`` validates with
    ``--require-overlap``: well-formed B/E events AND a gcn-pipe
    worker's ``pipe_prepare`` span visibly concurrent with a
    main-thread ``execute`` span. Records epoch wall, batch-plan
    cache hit rate, feature-store hit rate/bytes, the pipeline pair
    and the exchange bytes of one sampled step under
    ``"train-sampled"``."""
    import json

    root = Path(__file__).resolve().parent.parent
    env = _forced_host_env(root)
    with tempfile.TemporaryDirectory() as td:
        trace_path = str(Path(td) / "train_sampled_trace.json")
        cmd = [sys.executable, "-m", "repro.launch.gcn_train",
               "--mesh", "2x2", "--models", "gcn,gin,sage",
               "--scale", "9", "--epochs", "12", "--sampler",
               "--batch-size", "128", "--fanout", "8,8",
               "--feature-budget", "64",
               "--pipeline-depth", str(pipeline_depth),
               "--trace-out", trace_path,
               "--json", json_path]
        print(f"# train-sampled: {' '.join(cmd)}", flush=True)
        r = subprocess.run(cmd, env=env, cwd=root)
        print(f"# train-sampled -> {'OK' if r.returncode == 0 else 'FAIL'}",
              flush=True)
        if r.returncode:
            return r.returncode
        check = [sys.executable, str(root / "tools" / "check_trace.py"),
                 trace_path]
        if pipeline_depth > 0:
            check.append("--require-overlap")
        print(f"# train-sampled trace gate: {' '.join(check)}", flush=True)
        r = subprocess.run(check, env=env, cwd=root)
        if r.returncode:
            return r.returncode
    rec = json.loads(Path(json_path).read_text())["train-sampled"]
    _assert_telemetry(rec, "train-sampled")
    if pipeline_depth <= 0:
        return 0  # serial run: no pair to gate
    # the pipeline gate reads the record the driver just wrote: host-
    # side latency must actually hide behind device execution, and
    # hiding it must never cost wall time
    pipe = rec.get("pipeline")
    assert pipe is not None, "train-sampled record lost its pipeline pair"
    assert pipe["overlap_fraction"] is not None \
        and pipe["overlap_fraction"] > 0, \
        f"no prepare time was hidden: {pipe}"
    assert pipe["pipelined_wall_s"] <= pipe["serial_wall_s"], \
        f"pipelining must not slow the epoch wall: {pipe}"
    print(f"# train-sampled pipeline gate: overlap "
          f"{pipe['overlap_fraction']:.2f}, wall "
          f"{pipe['serial_wall_s']:.2f}s -> {pipe['pipelined_wall_s']:.2f}s",
          flush=True)
    return 0


def run_train_cv(json_path: str) -> int:
    """Control-variate sampled-training benchmark: the byte-vs-accuracy
    trade the historical-aggregation sampler exists for. Two launcher
    runs on the SAME graph/labels/seed/epochs (2x2 torus, 8 forced host
    devices):

      * plain neighbor sampling at fanout 8,8 — the accuracy baseline
        and its measured per-step exchange bytes;
      * control-variate sampling at fanout 2,2
        (``--variance-reduction``) — each layer adds the dropped-edge
        aggregation over cached historical activations, so the tiny
        fanout keeps the baseline's accuracy while the sampled exchange
        shrinks with the edge count. The CV run exports a Chrome trace
        (tracing ON) and the driver's in-run serial-vs-pipelined pair
        asserts the pipelined CV trajectory is bit-identical to serial.

    The gate — the record is only written if it holds:

      * ``exchange_bytes_per_step`` (CV, fanout 2) strictly below the
        plain fanout-8 baseline;
      * train accuracy within 2 percentage points of the baseline.

    The merged ``"train-cv"`` record carries both sub-records plus the
    byte-reduction ratio — the repo-level, machine-checked analog of
    the paper's transmission-reduction claim."""
    import json

    root = Path(__file__).resolve().parent.parent
    env = _forced_host_env(root)
    common = ["--mesh", "2x2", "--models", "gcn", "--scale", "9",
              "--epochs", "12", "--sampler", "--batch-size", "128",
              "--feature-budget", "64", "--pipeline-depth", "2"]
    with tempfile.TemporaryDirectory() as td:
        plain_json = str(Path(td) / "plain.json")
        cv_json = str(Path(td) / "cv.json")
        trace_path = str(Path(td) / "train_cv_trace.json")
        runs = [
            ("train-cv baseline (fanout 8, plain)",
             common + ["--fanout", "8,8", "--json", plain_json]),
            ("train-cv candidate (fanout 2, CV)",
             common + ["--fanout", "2,2", "--variance-reduction",
                       "--history-budget", "64",
                       "--trace-out", trace_path, "--json", cv_json]),
        ]
        for name, extra in runs:
            cmd = [sys.executable, "-m", "repro.launch.gcn_train"] + extra
            print(f"# {name}: {' '.join(cmd)}", flush=True)
            r = subprocess.run(cmd, env=env, cwd=root)
            print(f"# {name} -> {'OK' if r.returncode == 0 else 'FAIL'}",
                  flush=True)
            if r.returncode:
                return r.returncode
        check = [sys.executable, str(root / "tools" / "check_trace.py"),
                 trace_path, "--require-overlap"]
        print(f"# train-cv trace gate: {' '.join(check)}", flush=True)
        r = subprocess.run(check, env=env, cwd=root)
        if r.returncode:
            return r.returncode
        plain = json.loads(Path(plain_json).read_text())["train-sampled"]
        cv = json.loads(Path(cv_json).read_text())["train-sampled"]

    pm, cm = plain["models"]["gcn"], cv["models"]["gcn"]
    assert cm["variance_reduction"] and not pm["variance_reduction"]
    # THE gate: fewer bytes at matched accuracy
    assert cm["exchange_bytes_per_step"] < pm["exchange_bytes_per_step"], \
        (f"CV fanout-2 must move strictly fewer bytes than plain "
         f"fanout-8: {cm['exchange_bytes_per_step']} vs "
         f"{pm['exchange_bytes_per_step']}")
    acc_gap = abs(cm["train_accuracy"] - pm["train_accuracy"])
    assert acc_gap <= 0.02, \
        (f"CV fanout-2 accuracy {cm['train_accuracy']} strays "
         f"{acc_gap:.4f} (> 0.02) from plain fanout-8 "
         f"{pm['train_accuracy']}")
    assert cm["history_write_rows"] > 0, \
        "CV run never wrote history back"
    _assert_telemetry(cv, "train-cv")
    ratio = (cm["exchange_bytes_per_step"]
             / max(pm["exchange_bytes_per_step"], 1))
    print(f"# train-cv gate: {pm['exchange_bytes_per_step']}B/step "
          f"(fanout 8, acc {pm['train_accuracy']:.2%}) -> "
          f"{cm['exchange_bytes_per_step']}B/step (fanout 2 CV, acc "
          f"{cm['train_accuracy']:.2%}); {(1 - ratio):.0%} fewer bytes",
          flush=True)

    from repro.launch.bench_record import write_record

    rec = {
        "suite": "train-cv",
        "gate": {"bytes_ratio": round(ratio, 4),
                 "accuracy_gap": round(acc_gap, 4),
                 "max_accuracy_gap": 0.02},
        "plain_fanout8": pm,
        "cv_fanout2": cm,
        "sampler_plain": plain["sampler"],
        "sampler_cv": cv["sampler"],
        "telemetry": cv["telemetry"],
    }
    write_record(json_path, "train-cv", rec)
    print(f"# wrote {json_path} (train-cv suite)", flush=True)
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma list of module stems")
    ap.add_argument("--suite", default="",
                    help="'smoke' = engine example + tier-1 tests "
                         "(8 host devices); 'serve' = multi-graph "
                         "GCNService bench; 'train' = distributed GCN "
                         "training bench; 'train-sampled' = neighbor-"
                         "sampled mini-batch bench; 'train-cv' = "
                         "control-variate fanout-2 vs plain fanout-8 "
                         "byte/accuracy gate (all merge into "
                         "BENCH_gcn.json)")
    ap.add_argument("--json", default="BENCH_gcn.json",
                    help="perf-record path for --suite "
                         "serve/train/train-sampled")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="sampling-pipeline look-ahead for --suite "
                         "train-sampled (0 = serial, skips the "
                         "overlap gate)")
    args = ap.parse_args()
    if args.suite == "smoke":
        sys.exit(run_smoke())
    elif args.suite == "serve":
        sys.exit(run_serve(args.json))
    elif args.suite == "train":
        sys.exit(run_train(args.json))
    elif args.suite == "train-sampled":
        sys.exit(run_train_sampled(args.json, args.pipeline_depth))
    elif args.suite == "train-cv":
        sys.exit(run_train_cv(args.json))
    elif args.suite:
        sys.exit(f"unknown suite {args.suite!r} (expected 'smoke', "
                 "'serve', 'train', 'train-sampled' or 'train-cv')")
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    print("name,us_per_call,derived")
    failures = 0
    for stem in MODULES:
        if only and not any(stem.startswith(o) or o in stem for o in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{stem}", fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us},{derived}", flush=True)
            print(f"# {stem} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {stem} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
