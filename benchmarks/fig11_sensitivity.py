"""Fig. 11: hardware & graph-characteristic sensitivity of MultiGCN.

(a) speedup vs node count (paper: linear to 32 on RD/OR; LJ flattens)
(b) transmissions/DRAM vs round count (paper: transmissions grow with R)
(c) execution time vs feature length (paper: superlinear, >2x per 2x)
(d) execution time vs vertex scale (paper: >2x per 2x)"""
from __future__ import annotations

import dataclasses

from benchmarks.common import gm, load, suite_for
from repro.core import cost_model as cm
from repro.core.partition import TorusMesh, make_partition


def run():
    rows = []
    # (a) node scaling
    for gname in ("rd", "lj"):
        cfg, g = load(gname, "gcn")
        t_base = None
        for dims in ((2, 2), (4, 2), (4, 4), (8, 4)):
            mesh = TorusMesh(dims)
            part = make_partition(cfg, mesh.num_nodes,
                                  num_vertices=g.num_vertices)
            c = dataclasses.replace(cfg, message_passing="oppm",
                                    use_rounds=True)
            rep = cm.analyze(c, g, mesh, part=part)
            t = rep.time_model()["time_s"]
            t_base = t_base or t
            rows.append((f"fig11a.{gname}.n{mesh.num_nodes}", 0.0,
                         f"speedup={t_base / t:.2f}"))
    # (b) round count: shrink the aggregation buffer to force more rounds
    cfg, g = load("lj", "gcn")
    mesh = TorusMesh((4, 4))
    base_t = base_d = None
    for frac in (4, 2, 1):
        c = dataclasses.replace(cfg, message_passing="oppm", use_rounds=True,
                                agg_buffer_bytes=cfg.agg_buffer_bytes // frac)
        part = make_partition(c, 16, num_vertices=g.num_vertices)
        rep = cm.analyze(c, g, mesh, part=part)
        t = rep.totals()
        base_t = base_t or t["net_bytes"]
        base_d = base_d or t["dram_bytes"]
        rows.append((f"fig11b.lj.R{rep.num_rounds}", 0.0,
                     f"trans={t['net_bytes'] / base_t:.2f};"
                     f"dram={t['dram_bytes'] / base_d:.2f}"))
    # (c) feature length: 2x features -> >2x time (network superlinear)
    cfg, g = load("rm19", "gcn")
    t_prev = None
    for mult in (1, 2):
        f = cfg.graph.feat_in * mult
        c = dataclasses.replace(cfg, message_passing="oppm", use_rounds=True)
        part = make_partition(c, 16, num_vertices=g.num_vertices)
        rep = cm.analyze(c, g, mesh, part=part, feat_in=f)
        t = rep.time_model()["time_s"]
        if t_prev:
            rows.append((f"fig11c.rm19.h{f}", 0.0,
                         f"time_ratio={t / t_prev:.2f} (paper >2x)"))
        t_prev = t
    # (d) vertex scale: RM19 -> RM20 at same degree (same twin scale -> 2x V)
    t_prev = None
    for gname in ("rm19", "rm20"):
        cfg, g = load(gname, "gcn", scale=8)
        c = dataclasses.replace(cfg, message_passing="oppm", use_rounds=True)
        part = make_partition(c, 16, num_vertices=g.num_vertices)
        rep = cm.analyze(c, g, TorusMesh((4, 4)), part=part)
        t = rep.time_model()["time_s"]
        if t_prev:
            rows.append((f"fig11d.{gname}", 0.0,
                         f"time_ratio={t / t_prev:.2f} per 2x vertices"))
        t_prev = t
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
