"""Beyond-paper: OPPM deduplication applied to MoE expert-parallel
dispatch — replica savings for the two assigned MoE archs across EP shard
counts (deepseek 64e top-6 benefits most, as predicted in DESIGN.md)."""
from __future__ import annotations

from repro.config import get_lm_config
from repro.core.moe_dispatch import dispatch_stats


def run():
    rows = []
    for arch, shards in (("deepseek-v2-lite-16b", (4, 8, 16, 32)),
                         ("mixtral-8x7b", (2, 4, 8))):
        cfg = get_lm_config(arch)
        for s in shards:
            st = dispatch_stats(cfg, s, tokens=8192)
            rows.append((f"moe_oppm.{arch}.ep{s}", 0.0,
                         f"replica_savings={st['savings']:.1%};"
                         f"a2a_bytes_ratio={1 - st['savings']:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
