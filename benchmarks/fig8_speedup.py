"""Fig. 8: speedup of MultiGCN-TMM / -SREM / -TMM+SREM over the
OPPE-based MultiAccSys across GCN/GIN/SAGE x RD/OR/LJ (twins), via one
``GCNEngine`` session per workload (``suite_for``).

Paper: TMM 2.9x GM, SREM 1.9x GM, TMM+SREM 4~12x (GM 5.8x)."""
from __future__ import annotations

from benchmarks.common import MESH_4X4, gm, load, suite_for, timed


def run():
    rows = []
    speedups = {"tmm": [], "srem": [], "tmm+srem": []}
    for model in ("gcn", "gin", "sage"):
        for gname in ("rd", "or", "lj"):
            cfg, g = load(gname, model)
            (suite), us = timed(lambda: suite_for(cfg, g, MESH_4X4))
            t = {k: v.time_model()["time_s"] for k, v in suite.items()}
            for k in speedups:
                sp = t["oppe"] / t[k]
                speedups[k].append(sp)
                rows.append((f"fig8.{model}.{gname}.{k}", us,
                             f"speedup_vs_oppe={sp:.2f}"))
    for k, v in speedups.items():
        rows.append((f"fig8.GM.{k}", 0.0, f"gm_speedup={gm(v):.2f}"
                     f" (paper: tmm 2.9 / srem 1.9 / both 5.8)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
