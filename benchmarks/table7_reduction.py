"""Table 7: reduction of REDUNDANT transmissions / DRAM accesses of
TMM+SREM vs OPPE, plus the two overheads (extra transmission latency from
packet headers; round-partition preprocessing time). Variants derive
from one ``GCNEngine`` session per workload (``suite_for``); the direct
``make_partition`` call below deliberately bypasses the engine to time
the partition step itself.

Paper GM: -32% redundant transmissions, -100% redundant DRAM accesses,
+0.21% transmission latency, +6.1% preprocessing."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import MESH_4X4, gm, load, suite_for
from repro.core.partition import make_partition


def run():
    rows = []
    red_t, red_d, hdr_overhead, prep = [], [], [], []
    for model in ("gcn", "gin", "sage"):
        for gname in ("rd", "or", "lj"):
            cfg, g = load(gname, model)
            suite = suite_for(cfg, g, MESH_4X4)
            base, ours = suite["oppe"], suite["tmm+srem"]

            # redundant transmissions: hop-bytes above the dedup'd minimum
            # (one replica per (v, dst-node) at min hops = the oppm count)
            min_bytes = suite["tmm"].totals()["net_bytes"]
            red_base = base.totals()["net_bytes"] - min_bytes
            red_ours = max(ours.totals()["net_bytes"] - min_bytes, 0.0)
            rt = (red_base - red_ours) / max(red_base, 1e-9)
            # redundant DRAM: spill traffic (the rand component) — SREM
            # eliminates it entirely by construction
            rd_base = base.dram_rand_bytes.sum() + 0.0
            rd_ours = ours.dram_rand_bytes.sum() + 0.0
            rdm = (rd_base - rd_ours) / max(rd_base, 1e-9)

            # header/list bytes = extra transmission latency share
            hdr = 1.0 - min_bytes / max(ours.totals()["net_bytes"], 1e-9)

            # round partition preprocessing time (host) vs total mapping
            t0 = time.perf_counter()
            make_partition(cfg, 16, num_vertices=g.num_vertices)
            part_t = time.perf_counter() - t0
            t0 = time.perf_counter()
            _ = np.argsort(g.dst, kind="stable")  # the mapping sort itself
            map_t = time.perf_counter() - t0
            pp = part_t / max(map_t + part_t, 1e-9)

            red_t.append(max(rt, 1e-3))
            red_d.append(max(rdm, 1e-3))
            prep.append(pp)
            rows.append((f"table7.{model}.{gname}", 0.0,
                         f"red_trans=-{rt:.0%};red_dram=-{rdm:.0%};"
                         f"prep=+{pp:.1%}"))
    rows.append(("table7.GM", 0.0,
                 f"red_trans=-{gm(red_t):.0%};red_dram=-{gm(red_d):.0%};"
                 f"prep=+{np.mean(prep):.1%}"
                 " (paper GM -32%/-100%/+6.1%)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
