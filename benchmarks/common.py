"""Shared helpers for the paper-table benchmarks.

Graphs are degree-matched scaled twins (SNAP data is not redistributable
offline; see DESIGN.md §5.6). ``SCALE`` trades fidelity for runtime; the
fig11 vertex-scale sweep demonstrates the reported ratios are stable in
scale, which is what makes the twin methodology sound.

Each (cfg, graph, mesh) workload becomes one ``GCNEngine`` session;
``suite_for`` derives the five paper configurations from it with
``engine.analyze`` (the analytical cost model — no plan construction, so
paper-scale graphs are tractable), sharing the engine's one vertex
partition across all variants.
"""
from __future__ import annotations

import time

import numpy as np

from repro.config import GCNConfig, get_gcn_config
from repro.core.rmat import build_graph
from repro.gcn import GCNEngine

SCALES = {"rd": 20, "or": 40, "lj": 40, "rm19": 8, "rm20": 16, "rm21": 32}
MESH_4X4 = (4, 4)


def load(gname: str, model: str = "gcn", scale: int | None = None):
    cfg = get_gcn_config(f"gcn-{model}-{gname}")
    g = build_graph(cfg.graph, scale_factor=scale or SCALES.get(gname, 32))
    return cfg, g


def engine_for(cfg: GCNConfig, g, mesh_dims) -> GCNEngine:
    return GCNEngine.build(cfg, g, tuple(mesh_dims))


def suite_for(cfg: GCNConfig, g, mesh_dims):
    eng = engine_for(cfg, g, mesh_dims)

    def an(mpm, rounds, name):
        return eng.analyze(message_passing=mpm, use_rounds=rounds, name=name)

    return {
        "oppe": an("oppe", False, "oppe"),
        "oppr": an("oppr", False, "oppr"),
        "tmm": an("oppm", False, "tmm"),
        "srem": an("oppe", True, "srem"),
        "tmm+srem": an("oppm", True, "tmm+srem"),
    }


def timed(fn, *args, reps: int = 1):
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / reps
    return out, dt * 1e6  # us


def gm(xs):
    xs = np.asarray(list(xs), np.float64)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))
