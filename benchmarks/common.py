"""Shared helpers for the paper-table benchmarks.

Graphs are degree-matched scaled twins (SNAP data is not redistributable
offline; see DESIGN.md §5.6). ``SCALE`` trades fidelity for runtime; the
fig11 vertex-scale sweep demonstrates the reported ratios are stable in
scale, which is what makes the twin methodology sound.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.config import GCNConfig, get_gcn_config
from repro.core import cost_model as cm
from repro.core.partition import TorusMesh, make_partition
from repro.core.rmat import build_graph

SCALES = {"rd": 20, "or": 40, "lj": 40, "rm19": 8, "rm20": 16, "rm21": 32}
MESH_4X4 = TorusMesh((4, 4))


def load(gname: str, model: str = "gcn", scale: int | None = None):
    cfg = get_gcn_config(f"gcn-{model}-{gname}")
    g = build_graph(cfg.graph, scale_factor=scale or SCALES.get(gname, 32))
    return cfg, g


def suite_for(cfg: GCNConfig, g, mesh: TorusMesh):
    part = make_partition(cfg, mesh.num_nodes, num_vertices=g.num_vertices)

    def an(mpm, rounds, name):
        c = dataclasses.replace(cfg, message_passing=mpm, use_rounds=rounds)
        return cm.analyze(c, g, mesh, part=part, name=name)

    return {
        "oppe": an("oppe", False, "oppe"),
        "oppr": an("oppr", False, "oppr"),
        "tmm": an("oppm", False, "tmm"),
        "srem": an("oppe", True, "srem"),
        "tmm+srem": an("oppm", True, "tmm+srem"),
    }


def timed(fn, *args, reps: int = 1):
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / reps
    return out, dt * 1e6  # us


def gm(xs):
    xs = np.asarray(list(xs), np.float64)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))
