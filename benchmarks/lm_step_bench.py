"""Framework overhead microbench: wall time of jit'd train / prefill /
decode steps on reduced configs (CPU — measures the framework, not the
TPU; TPU projections live in the roofline analysis)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.config import get_lm_config
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.train import optimizer as optlib


def _time(fn, *args, reps=3):
    out = fn(*args)  # compile + warm
    jax.block_until_ready(jax.tree.leaves(out)[0])
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    for arch in ("glm4-9b", "mixtral-8x7b", "rwkv6-1.6b", "zamba2-2.7b"):
        cfg = get_lm_config(arch, "smoke")
        params = lm.lm_init(cfg, jax.random.PRNGKey(0))
        B, S = 2, 64
        batch = {"tokens": jnp.zeros((B, S), jnp.int32),
                 "labels": jnp.zeros((B, S), jnp.int32)}
        step = jax.jit(make_train_step(cfg, None))
        opt_state = optlib.init(params)
        us = _time(step, params, opt_state, batch)
        rows.append((f"lm_step.train.{arch}", round(us, 1),
                     f"tokens_per_s={B * S / (us / 1e6):.0f}"))

        st = lm.init_decode_state(cfg, B, 128)
        dec = jax.jit(lambda p, s, t: lm.decode_step(cfg, p, t, s))
        tok = jnp.zeros((B, 1), jnp.int32)
        us = _time(dec, params, st, tok)
        rows.append((f"lm_step.decode.{arch}", round(us, 1),
                     f"tokens_per_s={B / (us / 1e6):.0f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
