"""Analytical cost model: cross-check against the executable planner and
assert the paper's mechanism-level trends (Fig. 8 / Table 6 directions)."""
import dataclasses

import numpy as np
import pytest

from repro.config import get_gcn_config
from repro.core import cost_model as cm
from repro.core.graph import erdos
from repro.core.partition import TorusMesh, make_partition
from repro.core.plan import build_plan


@pytest.fixture(scope="module")
def setup():
    cfg = get_gcn_config("gcn-gcn-rd", "smoke")
    g = erdos(2048, 32768, seed=9)
    mesh = TorusMesh((4, 4))
    part = make_partition(cfg, 16, num_vertices=g.num_vertices)
    return cfg, g, mesh, part


def _suite(cfg, g, mesh, part):
    out = {}
    for name, (mpm, rounds) in {
        "oppe": ("oppe", False), "oppr": ("oppr", False),
        "tmm": ("oppm", False), "srem": ("oppe", True),
        "tmm+srem": ("oppm", True),
    }.items():
        c = dataclasses.replace(cfg, message_passing=mpm, use_rounds=rounds)
        out[name] = cm.analyze(c, g, mesh, part=part, name=name)
    return out


def test_planner_and_cost_model_agree_on_multicast_hops(setup):
    """The executable plan's hop count must equal the analytical count —
    the strongest consistency check between the two layers."""
    cfg, g, mesh, part = setup
    for mpm in ("oppe", "oppr", "oppm"):
        c = dataclasses.replace(cfg, message_passing=mpm, use_rounds=True)
        plan = build_plan(c, g, mesh, part)
        rep = cm.analyze(c, g, mesh, part=part)
        # analytical hop count ~ payload bytes / (Bf + HDR) for tree part
        Bf = cfg.graph.feat_in * 4
        if mpm == "oppm":
            analytic_hops = rep.packets.sum()
        else:
            analytic_hops = rep.packets.sum()
        assert plan.stats["link_feat_hops"] == pytest.approx(
            float(analytic_hops), rel=1e-6), mpm


def test_paper_trends(setup):
    cfg, g, mesh, part = setup
    s = _suite(cfg, g, mesh, part)
    tot = {k: v.totals() for k, v in s.items()}
    tm = {k: v.time_model() for k, v in s.items()}

    # Table 6 directions
    assert tot["tmm"]["net_bytes"] < 0.5 * tot["oppe"]["net_bytes"]
    assert tot["oppr"]["net_bytes"] < tot["oppe"]["net_bytes"]
    assert tot["tmm"]["net_bytes"] < tot["oppr"]["net_bytes"]
    assert tot["srem"]["net_bytes"] == pytest.approx(
        tot["oppe"]["net_bytes"], rel=0.01)  # SREM alone: trans unchanged
    assert tot["srem"]["dram_bytes"] < tot["oppe"]["dram_bytes"]
    assert tot["tmm+srem"]["dram_bytes"] < tot["oppe"]["dram_bytes"]

    # Fig. 8 direction: combined beats both single mechanisms and OPPE
    t = {k: v["time_s"] for k, v in tm.items()}
    assert t["tmm+srem"] < t["oppe"]
    speedup = t["oppe"] / t["tmm+srem"]
    assert speedup > 1.5, speedup

    # energy: MultiGCN uses less (Fig. 9)
    e_base = s["oppe"].energy_model()["energy_j"]
    e_ours = s["tmm+srem"].energy_model()["energy_j"]
    assert e_ours < e_base


def test_executor_padding_overhead_bounded(setup):
    """SPMD padding (static L_h) must not blow up executor bytes vs the
    analytic count by more than ~3x on a random graph."""
    cfg, g, mesh, part = setup
    c = dataclasses.replace(cfg, message_passing="oppm", use_rounds=True)
    plan = build_plan(c, g, mesh, part)
    exec_slots = plan.stats["executor_feat_slots"]
    true_hops = plan.stats["link_feat_hops"]
    assert exec_slots >= true_hops
    assert exec_slots < 3.5 * true_hops + 1000
