"""Chunked Mamba2-SSD and WKV6 vs their step-recurrence oracles:
chunk-size invariance and prefill->decode continuation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_lm_config
from repro.nn import linear_attn as la
from repro.nn import ssm
from repro.nn.module import init_tree

KEY = jax.random.PRNGKey(3)


def _ssd_scan_oracle(x, dt, a_log, B, C):
    """Token-by-token recurrence as ground truth."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    h = jnp.zeros((b, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        h, y = ssm.ssd_step(h, x[:, t], dt[:, t], a_log, B[:, t], C[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1), h


@pytest.mark.parametrize("chunk", [1, 4, 8, 32])
def test_ssd_chunk_invariance(chunk):
    b, S, H, P, N = 2, 32, 3, 8, 4
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    B = jax.random.normal(ks[2], (b, S, N)) * 0.5
    C = jax.random.normal(ks[3], (b, S, N)) * 0.5
    a_log = jnp.zeros((H,))
    y, h = ssm.ssd_chunked(x, dt, a_log, B, C, chunk)
    y_ref, h_ref = _ssd_scan_oracle(x, dt, a_log, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=2e-4, rtol=2e-4)


def _wkv_scan_oracle(r, k, v, logw, u):
    b, S, H, K = r.shape
    Sst = jnp.zeros((b, H, K, K), jnp.float32)
    ys = []
    for t in range(S):
        Sst, y = la.wkv_step(Sst, r[:, t], k[:, t], v[:, t], logw[:, t], u)
        ys.append(y)
    return jnp.stack(ys, axis=1), Sst


@pytest.mark.parametrize("chunk", [1, 4, 16])
def test_wkv_chunk_invariance(chunk):
    b, S, H, K = 2, 32, 2, 8
    ks = jax.random.split(KEY, 4)
    r = jax.random.normal(ks[0], (b, S, H, K)) * 0.5
    k = jax.random.normal(ks[1], (b, S, H, K)) * 0.5
    v = jax.random.normal(ks[2], (b, S, H, K)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (b, S, H, K)))
    u = 0.3 * jnp.ones((H, K))
    y, S_fin = la.wkv_chunked(r, k, v, logw, u, chunk)
    y_ref, S_ref = _wkv_scan_oracle(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(S_fin), np.asarray(S_ref),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "rwkv6-1.6b"])
def test_prefill_then_decode_matches_full(arch):
    """State continuation: prefill S-1 then one decode step == full fwd."""
    from repro.models import lm

    cfg = get_lm_config(arch, "smoke")
    params = lm.lm_init(cfg, KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    st = lm.init_decode_state(cfg, B, 32)
    _, st = lm.prefill(cfg, params, toks[:, :S - 1], st)
    logits, _ = lm.decode_step(cfg, params, toks[:, S - 1:], st)
    hid, _, _ = lm.forward_hidden(cfg, params, toks, remat=False)
    W = lm.lm_head_matrix(params.get("head", {}), params["embed"], cfg)
    ref = (hid[:, -1] @ W.astype(hid.dtype)).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               atol=0.15, rtol=0.1)  # bf16 stack
