"""The observability layer (``repro.gcn.obs``): span tracing, the typed
metrics registry, Chrome-trace export, and the design constraints the
instrumented stack hangs off it:

  * spans nest per thread and are attributed to the ``gcn-pipe`` worker
    that ran them; a worker exception still closes its spans (the
    record carries ``error=True``) and the pipeline's fail-fast drain
    contract survives tracing;
  * :meth:`Tracer.export` writes trace_event JSON that
    ``tools/check_trace.py`` validates — balanced B/E, monotonic
    per-track timestamps, only KNOWN_PHASES names;
  * registry counters are exact: feature hit/miss rows match the
    store's own ledger, ``train.exchange_bytes`` is the per-step
    payload times executed steps;
  * disabled mode is free: one shared no-op span singleton, no
    retained allocation on the guarded hot path, empty buffer;
  * tracing observes, never synchronizes: a pipelined ``fit_sampled``
    trajectory is bit-identical with tracing on vs off;
  * the shared ``ratio``/``overlap_fraction`` helpers are THE one
    definition (regression-pinned against the hand-rolled formulas
    they replaced), and unmeasured engine telemetry reads ``None``,
    never a silent ``0.0``.

Runs in-process on the 1-CPU view (mesh ``(1, 1)``).
"""
import json
import sys
import threading
import time
import tracemalloc
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))

import check_trace  # noqa: E402  (tools/check_trace.py, path above)

V, E, F, C = 256, 2048, 8, 4


@pytest.fixture
def obs_reset():
    """The process-wide tracer/registry, saved+restored around the
    test: tracing off, buffer/ledger cleared, wall clock and default
    ring capacity reinstated on both sides (tests inject deterministic
    clocks and shrink the buffer)."""
    from repro.gcn import obs

    capacity = obs.trace._buf.maxlen
    obs.trace.configure(enabled=False, capacity=capacity,
                        clock=time.perf_counter)
    obs.trace.clear()
    obs.metrics.reset()
    yield obs
    obs.trace.configure(enabled=False, capacity=capacity,
                        clock=time.perf_counter)
    obs.trace.clear()
    obs.metrics.reset()


def _trainer(gcn_setup, **kw):
    from repro.gcn import GCNTrainer

    eng, feats, labels, mask = gcn_setup(**kw)
    return GCNTrainer(eng, labels, mask), eng, feats, labels, mask


# ---------------------------------------------------------------------------
# spans: nesting, attribution, exceptions
# ---------------------------------------------------------------------------


def test_span_nesting_attrs_and_injectable_clock(obs_reset):
    """Nested spans record inner-first with correct begin/end ticks
    from the injected clock; ``set()`` merges late attrs; both spans
    land on the recording thread's ident."""
    obs = obs_reset
    ticks = iter(float(t) for t in range(100))
    obs.trace.configure(enabled=True, clock=lambda: next(ticks))  # epoch=0
    with obs.trace.span("plan_build", scope="batch") as sp:
        with obs.trace.span("pad_plan"):
            pass
        sp.set(nodes=128)
    evs = obs.trace.events()
    assert [e["name"] for e in evs] == ["pad_plan", "plan_build"]
    inner, outer = evs
    assert (outer["t0"], inner["t0"], inner["t1"], outer["t1"]) == \
        (1.0, 2.0, 3.0, 4.0)
    assert outer["attrs"] == {"scope": "batch", "nodes": 128}
    assert inner["attrs"] is None and inner["ok"] and outer["ok"]
    me = threading.current_thread()
    assert {e["tid"] for e in evs} == {me.ident}
    assert {e["thread"] for e in evs} == {me.name}


def test_worker_spans_attributed_and_exception_closes_span(obs_reset):
    """SamplePipeline worker spans carry the ``gcn-pipe`` thread name;
    a prepare that raises still closes its ``pipe_prepare`` span (with
    ``error``/``ok=False``) and the exception surfaces in-order on the
    consumer — tracing does not weaken the fail-fast drain contract."""
    from repro.gcn.pipeline import SamplePipeline

    obs = obs_reset
    obs.trace.configure(enabled=True)

    def prepare(task):
        if task == 2:
            raise RuntimeError("boom")
        return task * 10

    pipe = SamplePipeline(list(range(4)), prepare, depth=2, workers=2)
    try:
        assert pipe.get(0) == 0 and pipe.get(1) == 10
        with pytest.raises(RuntimeError, match="boom"):
            pipe.get(2)
    finally:
        pipe.close()
    prep = [e for e in obs.trace.events() if e["name"] == "pipe_prepare"]
    assert prep and all(e["thread"].startswith("gcn-pipe")
                        for e in prep)
    failed = [e for e in prep if e["attrs"]["task"] == 2]
    assert len(failed) == 1 and failed[0]["ok"] is False
    assert all(e["ok"] for e in prep if e["attrs"]["task"] != 2)
    # consumer-side spans stay on the consuming thread
    gets = [e for e in obs.trace.events() if e["name"] == "pipe_get"]
    assert gets and {e["tid"] for e in gets} == \
        {threading.current_thread().ident}
    assert not any(t.name.startswith("gcn-pipe")
                   for t in threading.enumerate())


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------


def test_export_is_valid_chrome_trace(obs_reset, tmp_path):
    """Exported JSON passes the full tools/check_trace.py validation
    (balanced LIFO B/E, monotonic per-track ts, KNOWN_PHASES only,
    thread_name metadata) even with spans from concurrent worker
    threads, and the error span's args carry ``error: true``."""
    from repro.gcn.pipeline import SamplePipeline

    obs = obs_reset
    obs.trace.configure(enabled=True)

    def prepare(task):
        with obs.trace.span("sample", seeds=task):
            time.sleep(0.001)
        if task == 5:
            raise RuntimeError("boom")
        return task

    pipe = SamplePipeline(list(range(6)), prepare, depth=3, workers=2)
    try:
        for i in range(5):
            with obs.trace.span("execute", what="consume"):
                assert pipe.get(i) == i
        with pytest.raises(RuntimeError):
            pipe.get(5)
    finally:
        pipe.close()
    path = tmp_path / "trace.json"
    n = obs.trace.export(str(path))
    assert n == len(obs.trace.events()) > 0
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    stats = check_trace.validate(doc)  # raises TraceError on violation
    assert stats["spans"] == n
    names = set(stats["threads"].values())
    assert any(t.startswith("gcn-pipe") for t in names), names
    errs = [ev for ev in doc["traceEvents"]
            if ev["ph"] == "B" and ev.get("args", {}).get("error")]
    assert len(errs) == 1 and errs[0]["name"] == "pipe_prepare"


def test_export_ring_buffer_bounds_and_clear(obs_reset, tmp_path):
    """The buffer keeps only the newest ``capacity`` spans; ``clear``
    empties it; re-export after clear writes metadata only."""
    obs = obs_reset
    obs.trace.configure(enabled=True, capacity=8)
    for i in range(20):
        with obs.trace.span("sample", seeds=i):
            pass
    evs = obs.trace.events()
    assert len(evs) == 8
    assert [e["attrs"]["seeds"] for e in evs] == list(range(12, 20))
    obs.trace.clear()
    path = tmp_path / "empty.json"
    assert obs.trace.export(str(path)) == 0
    doc = json.loads(path.read_text())
    assert all(ev["ph"] == "M" for ev in doc["traceEvents"])
    check_trace.validate(doc)


# ---------------------------------------------------------------------------
# registry exactness
# ---------------------------------------------------------------------------


def test_registry_typing_and_conflicts(obs_reset):
    """Declare-or-get is idempotent; redeclaring under a different
    kind or unit is a hard error; snapshot carries the schema version
    plus type/unit/help per metric."""
    obs = obs_reset
    c = obs.metrics.counter("t.rows", unit="rows", help="h")
    assert obs.metrics.counter("t.rows", unit="rows", help="h") is c
    c.add(3)
    c.add(2)
    assert obs.metrics.value("t.rows") == 5
    with pytest.raises(ValueError):
        obs.metrics.gauge("t.rows", unit="rows")
    with pytest.raises(ValueError):
        obs.metrics.counter("t.rows", unit="bytes")
    obs.metrics.gauge("t.depth", unit="tasks").set(4)
    h = obs.metrics.histogram("t.lat", unit="s")
    for v in (0.25, 0.75):
        h.observe(v)
    snap = obs.metrics.snapshot()
    assert snap["schema_version"] == obs.TELEMETRY_SCHEMA_VERSION
    m = snap["metrics"]
    assert m["t.rows"] == {"type": "counter", "unit": "rows",
                           "help": "h", "value": 5}
    assert m["t.depth"]["type"] == "gauge" and m["t.depth"]["value"] == 4
    assert m["t.lat"]["count"] == 2
    assert m["t.lat"]["sum"] == pytest.approx(1.0)
    assert obs.metrics.value("t.nope", default=None) is None


def test_feature_counters_match_store_ledger(obs_reset, feature_store):
    """The process-wide ``feature.*`` counters advance by EXACTLY the
    per-graph deltas the store's own ledger records for the same
    gathers — two views of one measurement, not two measurements."""
    obs = obs_reset
    store, g, feats, handle = feature_store(V=V, E=E, F=F,
                                            block_vertices=32)
    fp = handle.graph_fp
    before = dict(store.graph_stats(fp))
    rng = np.random.default_rng(3)
    for _ in range(4):
        nodes = rng.integers(0, V, size=40)
        np.testing.assert_array_equal(handle.gather(nodes), feats[nodes])
    after = dict(store.graph_stats(fp))
    d = {k: after[k] - before[k] for k in
         ("hit_rows", "miss_rows", "gathered_bytes", "dense_bytes")}
    assert d["hit_rows"] + d["miss_rows"] == 4 * 40
    assert obs.metrics.value("feature.hit_rows") == d["hit_rows"]
    assert obs.metrics.value("feature.miss_rows") == d["miss_rows"]
    assert obs.metrics.value("feature.gathered_bytes") == \
        d["gathered_bytes"]
    assert obs.metrics.value("feature.dense_bytes") == d["dense_bytes"]


def test_train_counters_exact(obs_reset, fresh_caches, gcn_setup):
    """``train.steps`` counts exactly the executed sampled steps and
    ``train.exchange_bytes`` is the measured per-step payload times
    that count (the machine-readable side of the paper's transmission-
    reduction claim)."""
    obs = obs_reset
    tr, eng, feats, _, _ = _trainer(gcn_setup)
    rep = tr.fit_sampled(feats, epochs=3, batch_size=64, fanouts=(4, 4))
    steps = 3 * rep.batches_per_epoch
    assert obs.metrics.value("train.steps") == steps
    assert obs.metrics.value("train.exchange_bytes") == \
        rep.exchange_bytes_per_step * steps
    assert obs.metrics.value("train.exchange_bytes_per_step") == \
        rep.exchange_bytes_per_step
    # fixed seed sets sample once; epochs 2..3 hit the batch-plan cache
    assert obs.metrics.value("sample.batches") == rep.batches_per_epoch
    snap = eng.telemetry()
    assert snap["schema_version"] == obs.TELEMETRY_SCHEMA_VERSION
    assert snap["metrics"]["train.steps"]["value"] == steps


def test_sample_memo_hit_accounting_is_exact(obs_reset, fresh_caches,
                                             gcn_setup):
    """``sample.memo_hits`` + ``sample.batches`` (misses, i.e. actual
    samples) == every ``sample_memoized`` call the fit made — the memo
    ledger closes exactly, so cache-efficiency claims about the sampled
    path are measured, not inferred. With fixed seed sets only epoch 0
    samples; every later epoch is all hits."""
    obs = obs_reset
    tr, _, feats, _, _ = _trainer(gcn_setup)
    epochs = 4
    rep = tr.fit_sampled(feats, epochs=epochs, batch_size=64,
                         fanouts=(4, 4))
    B = rep.batches_per_epoch
    assert obs.metrics.value("sample.batches") == B
    assert obs.metrics.value("sample.memo_hits") == (epochs - 1) * B
    assert (obs.metrics.value("sample.memo_hits")
            + obs.metrics.value("sample.batches")) == epochs * B

    # reshuffling defeats the memo: every epoch samples, zero hits
    obs.metrics.reset()
    tr2, _, feats2, _, _ = _trainer(gcn_setup)
    rep2 = tr2.fit_sampled(feats2, epochs=2, batch_size=64,
                           fanouts=(4, 4), reshuffle_each_epoch=True)
    assert obs.metrics.value("sample.batches") == \
        2 * rep2.batches_per_epoch
    assert obs.metrics.value("sample.memo_hits") == 0


# ---------------------------------------------------------------------------
# disabled mode
# ---------------------------------------------------------------------------


def test_disabled_mode_is_free(obs_reset):
    """Disabled tracing returns ONE shared no-op singleton, records
    nothing, and the guarded hot-path pattern (featurestore.gather's)
    retains zero bytes per call."""
    obs = obs_reset
    tr = obs.trace
    assert not tr.enabled
    assert tr.span("feature_gather") is obs.NULL_SPAN
    with tr.span("feature_gather") as sp:
        assert sp is obs.NULL_SPAN
        sp.set(rows=1)  # no-op, no state
    assert tr.events() == []

    def guarded():
        sp = (tr.span("feature_gather", rows=128) if tr.enabled
              else obs.NULL_SPAN)
        with sp:
            pass

    guarded()  # warm up bytecode caches before measuring
    tracemalloc.start()
    try:
        base = tracemalloc.get_traced_memory()[0]
        # stay in the interned small-int range: the loop variable must
        # not itself be the one allocation this pin is measuring
        for _ in range(256):
            guarded()
        grown = tracemalloc.get_traced_memory()[0] - base
    finally:
        tracemalloc.stop()
    assert grown == 0, f"disabled span path retained {grown} bytes"


# ---------------------------------------------------------------------------
# bit-identity with tracing on
# ---------------------------------------------------------------------------


def test_pipelined_fit_bit_identical_tracing_on_vs_off(
        obs_reset, fresh_caches, gcn_setup):
    """Spans observe, never synchronize: the pipelined sampled
    trajectory (losses, params, consumed fingerprint order) is
    bit-identical with tracing enabled, and the traced run captured
    pipeline spans from gcn-pipe workers."""
    import jax

    obs = obs_reset
    runs = []
    for enabled in (False, True):
        fresh_caches.clear_all()
        obs.trace.configure(enabled=enabled)
        obs.trace.clear()
        tr, _, feats, _, _ = _trainer(gcn_setup)
        rep = tr.fit_sampled(feats, epochs=3, batch_size=64,
                             fanouts=(4, 4), pipeline_depth=2,
                             pipeline_workers=2)
        runs.append(([h["loss"] for h in rep.history],
                     [np.asarray(a) for a in jax.tree.leaves(rep.params)],
                     rep.batch_fingerprints))
    (loss_off, leaves_off, fp_off), (loss_on, leaves_on, fp_on) = runs
    assert loss_on == loss_off
    assert fp_on == fp_off
    for a, b in zip(leaves_on, leaves_off):
        np.testing.assert_array_equal(a, b)
    names = {e["name"] for e in obs.trace.events()}
    assert {"pipe_prepare", "pipe_get", "batch_prepare", "execute",
            "sample"} <= names, names
    workers = {e["thread"] for e in obs.trace.events()
               if e["name"] == "pipe_prepare"}
    assert workers and all(w.startswith("gcn-pipe") for w in workers)


# ---------------------------------------------------------------------------
# shared fraction helpers + silent-zero fix
# ---------------------------------------------------------------------------


def test_shared_helpers_match_hand_rolled_formulas(obs_reset):
    """Regression pin for the dedupe: ``obs.ratio`` /
    ``obs.overlap_fraction`` reproduce the three hand-rolled
    expressions they replaced (pipeline stats, inference overlap,
    service upload overlap) bit-for-bit, including the den==0 legacy
    default — and ``default=None`` flags the never-measured case."""
    obs = obs_reset
    cases = [(0.0, 0.0), (0.0, 2.0), (0.5, 2.0), (2.0, 2.0),
             (1e-9, 3.0), (7.25, 0.5)]
    for hidden, total in cases:
        legacy = (hidden / total) if total else 0.0  # the old inline form
        assert obs.overlap_fraction(hidden, total) == legacy, (hidden,
                                                               total)
        assert obs.ratio(hidden, total) == legacy
    assert obs.overlap_fraction(1.0, 0.0, default=None) is None
    assert obs.ratio(5, 0, default=None) is None
    assert obs.ratio(3, 4) == 0.75


def test_pipeline_stats_still_use_shared_helper_values(obs_reset):
    """End-to-end: SamplePipeline.stats() computes its fractions
    through the shared helpers with the legacy 0.0 default (raw stats
    keep their meaning; the None semantics live on engine surfaces)."""
    from repro.gcn.pipeline import SamplePipeline

    obs = obs_reset
    pipe = SamplePipeline([0, 1, 2], lambda t: t, depth=2, workers=1)
    try:
        for i in range(3):
            pipe.get(i)
    finally:
        pipe.close()
    st = pipe.stats()
    assert st["overlap_fraction"] == obs.overlap_fraction(
        st["overlap_s"], st["prepare_s"])
    legacy = (st["overlap_s"] / st["prepare_s"]) if st["prepare_s"] \
        else 0.0
    assert st["overlap_fraction"] == legacy
    assert 0.0 <= st["queue_occupancy_mean"] <= st["depth"]


def test_engine_stats_none_before_measured_after(
        obs_reset, fresh_caches, gcn_setup):
    """The silent-zero fix: unmeasured ratios on ``engine.stats()`` /
    ``inference_stats()`` read ``None``; after a sampled fit the same
    fields are measured floats (a serial run reports a genuine 0.0
    overlap, not None — nothing was hidden, and that was measured)."""
    tr, eng, feats, _, _ = _trainer(gcn_setup)
    st = eng.stats(feat_dim=F)
    assert st["batch_bucket_hit_rate"] is None
    assert st["pipeline_overlap_fraction"] is None
    assert st["pipeline_queue_occupancy"] is None
    assert st["feature_hit_rate"] is None
    assert st["feature_byte_reduction"] is None
    inf = eng.inference_stats()
    assert inf["inference_overlap_fraction"] is None
    assert inf["chunk_bucket_hit_rate"] is None
    # counts (not ratios) stay plain zeros — they ARE measured
    assert st["batch_bucket_calls"] == 0
    assert inf["inference_chunks"] == 0

    tr.fit_sampled(feats, epochs=2, batch_size=64, fanouts=(4, 4))
    st = eng.stats(feat_dim=F)
    assert isinstance(st["pipeline_overlap_fraction"], float)
    assert st["pipeline_overlap_fraction"] == 0.0  # serial: measured 0
    assert isinstance(st["feature_hit_rate"], float)
    assert isinstance(st["feature_byte_reduction"], float)
