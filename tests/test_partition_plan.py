"""Property tests (hypothesis) for the paper's §4.3 partition and the
communication planner's conservation invariants."""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.config import get_gcn_config
from repro.core.graph import Graph, erdos
from repro.core.partition import TorusMesh, make_partition
from repro.core.plan import build_plan


@settings(max_examples=30, deadline=None)
@given(
    n_bits=st.integers(0, 4),
    x_bits=st.integers(0, 6),
    v=st.integers(1, 1 << 16),
)
def test_bitfield_partition_invariants(n_bits, x_bits, v):
    from repro.core.partition import RoundPartition

    N = 1 << n_bits
    part = RoundPartition(N, n_bits, x_bits, num_rounds=1 << 10,
                          num_vertices=1 << 16)
    node, slot, rnd = part.node_of(v), part.slot_of(v), part.round_of(v)
    # the bit fields must reconstruct the vID exactly
    assert (int(rnd) << (n_bits + x_bits)) | (int(slot) << n_bits) | int(node) == v
    assert 0 <= node < N
    assert 0 <= slot < part.slots_per_round
    # local index is round-major and bijective per node
    assert part.local_index(v) == (int(rnd) << x_bits) | int(slot)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_round_capacity_respects_buffer(seed):
    cfg = get_gcn_config("gcn-gcn-rd", "smoke")
    part = make_partition(cfg, 8, num_vertices=4096)
    # paper rule: per-round per-node vertices * feature bytes <= alpha * M
    S = cfg.graph.feat_in * 4
    assert part.slots_per_round * S <= cfg.alpha * cfg.agg_buffer_bytes
    assert part.slots_per_round * 2 * S > cfg.alpha * cfg.agg_buffer_bytes \
        or part.x_bits == 0


@pytest.mark.parametrize("model", ["oppe", "oppr", "oppm"])
@pytest.mark.parametrize("rounds", [True, False])
def test_plan_conservation(model, rounds):
    """Every edge appears exactly once in the aggregation COO; every
    remote replica is deposited exactly once; OPPM never moves more
    hop-bytes than OPPR unicast."""
    cfg = get_gcn_config("gcn-gcn-rd", "smoke")
    cfg = dataclasses.replace(cfg, message_passing=model, use_rounds=rounds,
                              agg_buffer_bytes=8 << 10)
    g = erdos(512, 4096, seed=11)
    mesh = TorusMesh((2, 4))
    part = make_partition(cfg, 8, num_vertices=g.num_vertices)
    plan = build_plan(cfg, g, mesh, part)

    # edge conservation: COO entries == |E|
    assert int((plan.edge_w != 0).sum()) == g.num_edges
    # each (round, node, slot) in the COO belongs to that round/node
    for r in range(plan.num_rounds):
        for n in range(plan.num_nodes):
            sl = plan.edge_slot[r, n][plan.edge_w[r, n] != 0]
            assert (sl < part.slots_per_round).all()

    # deposits: every allocated replica row receives exactly one deposit
    # (from relay or local copy)
    R, N = plan.num_rounds, plan.num_nodes
    filled = np.zeros((R, N, plan.replica_rows), np.int32)
    last = plan.phases[-1]
    for r in range(R):
        for n in range(N):
            for h in range(last.dep.shape[2]):
                rows = last.dep_slot[r, n, h][last.dep[r, n, h]]
                np.add.at(filled[r, n], rows, 1)
            rows = last.lc_dst[r, n][last.lc_valid[r, n]]
            np.add.at(filled[r, n], rows, 1)
            rows = plan.repl_lc_dst[r, n][plan.repl_lc_valid[r, n]]
            np.add.at(filled[r, n], rows, 1)
    used = np.zeros((R, N, plan.replica_rows), bool)
    for r in range(R):
        for n in range(N):
            used[r, n][plan.edge_repl[r, n][plan.edge_w[r, n] != 0]] = True
    assert (filled[used] == 1).all(), "each used replica row deposited once"
    assert (filled <= 1).all(), "no double deposits"


def test_multicast_cheaper_than_unicast():
    cfg = get_gcn_config("gcn-gcn-rd", "smoke")
    g = erdos(1024, 16384, seed=3)
    mesh = TorusMesh((4, 4))
    part = make_partition(cfg, 16, num_vertices=g.num_vertices)
    stats = {}
    for model in ("oppe", "oppr", "oppm"):
        c = dataclasses.replace(cfg, message_passing=model)
        plan = build_plan(c, g, mesh, part)
        stats[model] = plan.stats["link_feat_hops"]
    assert stats["oppm"] < stats["oppr"] < stats["oppe"]


def test_bidirectional_rings_reduce_hops():
    """The §Perf bidir iteration: shorter-way routing must strictly cut
    hop-weighted traffic, agree with the analytical model, and preserve
    the plan conservation invariants."""
    from repro.core import cost_model as cm

    cfg = get_gcn_config("gcn-gcn-lj", "smoke")
    g = erdos(2048, 16384, seed=7)
    mesh = TorusMesh((8, 2))
    part = make_partition(cfg, 16, num_vertices=g.num_vertices)
    c = dataclasses.replace(cfg, message_passing="oppm", use_rounds=True)
    uni = build_plan(c, g, mesh, part)
    bi = build_plan(c, g, mesh, part, bidir=True)
    assert bi.stats["link_feat_hops"] < uni.stats["link_feat_hops"]
    # executable plan and analytical model agree in both modes
    for bidir, plan in ((False, uni), (True, bi)):
        rep = cm.analyze(c, g, mesh, part=part, bidir=bidir)
        assert plan.stats["link_feat_hops"] == int(rep.packets.sum())
    # conservation: every edge still lands exactly once
    assert int((bi.edge_w != 0).sum()) == g.num_edges
