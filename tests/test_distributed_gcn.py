"""Distributed MultiGCN executor == single-device oracle, on 8 host
devices (2D and 3D torus), across message-passing models and rounds.

Runs in a subprocess because the device count must be set before jax
initializes (the main pytest process keeps the real 1-CPU view)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest


@pytest.mark.slow
def test_distributed_equivalence_8dev():
    script = Path(__file__).parent / "_distributed_gcn_main.py"
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ALL_OK" in r.stdout
