"""Neighbor-sampled mini-batch training (``GCNTrainer.fit_sampled``):
parity against full-batch training under full fanout, bounded working
sets under byte budgets, batch-plan caching by subgraph fingerprint,
and the power-of-two plan padding that lets same-bucket batches share
one jitted train step.

Runs in-process on the 1-CPU view (mesh ``(1, 1)``); the 8-device
variants live in ``_gcn_train_main.py``.
"""
import numpy as np
import pytest

V, E, F, C = 256, 2048, 8, 4


def _trainer(gcn_setup, **kw):
    from repro.gcn import GCNTrainer

    eng, feats, labels, mask = gcn_setup(**kw)
    return GCNTrainer(eng, labels, mask), eng, feats, labels, mask


def test_sampled_full_fanout_parity_both_backends(fresh_caches, gcn_setup):
    """With full fanout (depth = network depth) and seeds = every
    labeled vertex, one sampled batch's loss/grads equal the full-batch
    ``loss_and_grad`` to fp32 tolerance — on BOTH aggregation backends.
    The subgraph runs on its own padded plan with parent-derived edge
    weights, so this is the end-to-end correctness pin of the whole
    sampled pipeline."""
    import jax

    tr, eng, feats, labels, mask = _trainer(gcn_setup)
    seeds = np.flatnonzero(mask > 0)
    for impl in ("jnp", "pallas"):
        loss_f, grads_f = eng.loss_and_grad(feats, labels, mask,
                                            agg_impl=impl)
        loss_s, grads_s = tr.sampled_loss_and_grad(
            feats, seeds, fanouts=(-1, -1), agg_impl=impl)
        assert abs(float(loss_s) - float(loss_f)) < 1e-5, impl
        for gs, gf in zip(jax.tree.leaves(grads_s),
                          jax.tree.leaves(grads_f)):
            err = float(np.max(np.abs(np.asarray(gs) - np.asarray(gf)))
                        / (np.max(np.abs(np.asarray(gf))) + 1e-9))
            assert err < 1e-4, (impl, err)


def test_fit_sampled_matches_fit_trajectory(fresh_caches, gcn_setup):
    """Full fanout + one batch covering all labeled vertices: the
    sampled loop IS full-batch training — per-epoch losses and final
    params match ``fit`` to tight tolerance."""
    import jax

    from repro.gcn import GCNTrainer

    tr_f, _, feats, _, _ = _trainer(gcn_setup)
    rep_f = tr_f.fit(feats, epochs=5)
    tr_s, _, feats, _, _ = _trainer(gcn_setup)
    rep_s = tr_s.fit_sampled(feats, epochs=5, batch_size=V,
                             fanouts=(-1, -1))
    for hf, hs in zip(rep_f.history, rep_s.history):
        assert hs["loss"] == pytest.approx(hf["loss"], abs=1e-5)
    for a, b in zip(jax.tree.leaves(rep_s.params),
                    jax.tree.leaves(rep_f.params)):
        err = float(np.max(np.abs(np.asarray(a) - np.asarray(b)))
                    / (np.max(np.abs(np.asarray(b))) + 1e-9))
        assert err < 1e-4, err
    del GCNTrainer


def test_fit_sampled_decreases_loss_and_caches_batch_plans(
        fresh_caches, gcn_setup):
    """Bounded fanout: the loss decreases strictly across epochs, seed
    sets fixed across epochs hit the batch-plan cache from epoch 2 on,
    the full-batch plan store is never touched, and bucketed batches
    share compiled train steps (compiles == buckets, not batches)."""
    cache = fresh_caches
    tr, eng, feats, _, _ = _trainer(gcn_setup)
    rep = tr.fit_sampled(feats, epochs=4, batch_size=64, fanouts=(4, 4))
    losses = [h["loss"] for h in rep.history]
    assert all(b < a for a, b in zip(losses, losses[1:])), losses
    assert rep.batches_per_epoch == 4  # ~205 train nodes / 64
    # epoch 1 misses once per distinct batch; epochs 2..4 are pure hits
    assert rep.batch_plan_misses == rep.batches_per_epoch
    assert rep.batch_plan_hits == rep.batches_per_epoch * 3
    assert rep.batch_plan_hit_rate == pytest.approx(0.75)
    # power-of-two bucketing: distinct subgraph sizes collapse into few
    # buckets, and compiled train steps are shared within a bucket
    assert rep.vertex_buckets and all(
        b & (b - 1) == 0 for b in rep.vertex_buckets)
    assert rep.train_step_compiles == len(rep.vertex_buckets)
    # the whole point: the full-batch plan was never built
    st = cache.cache_stats()
    assert st["plan"]["entries"] == 0 and st["plan"]["misses"] == 0
    assert st["batch"]["entries"] == rep.batches_per_epoch
    assert not eng.plan_cached


def test_fit_sampled_deterministic(fresh_caches, gcn_setup):
    """Two identical sampled runs (fresh engines, cleared caches in
    between) produce bit-identical parameters and loss histories."""
    import jax

    reports = []
    for _ in range(2):
        fresh_caches.clear_all()
        tr, _, feats, _, _ = _trainer(gcn_setup)
        reports.append(tr.fit_sampled(feats, epochs=3, batch_size=64,
                                      fanouts=(4, 4)))
    ra, rb = reports
    assert [h["loss"] for h in ra.history] == \
        [h["loss"] for h in rb.history]
    for a, b in zip(jax.tree.leaves(ra.params), jax.tree.leaves(rb.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fit_sampled_under_budget_that_evicts_full_batch_state(
        fresh_caches, gcn_setup, erdos_graph):
    """The acceptance scenario: full-batch state is evicted under a
    byte budget (releasing the live session's memos), yet sampled
    training keeps going — batch plans live in their own store, so the
    graph trains with a bounded working set the full-batch path could
    not satisfy. The full plan is never rebuilt."""
    from repro.gcn import GCNEngine

    cache = fresh_caches
    tr, eng, feats, _, _ = _trainer(gcn_setup)
    tr.fit(feats, epochs=2)  # builds + uses the full-batch plan
    full_bytes = cache.cache_stats()["plan"]["bytes"]
    assert full_bytes > 0 and eng.plan_cached

    # a second graph's plan + a budget below two plans evicts the
    # full-batch plan (LRU) and releases the live session's memos
    other = GCNEngine.build(eng.cfg, erdos_graph(V, E, seed=99), (1, 1))
    cache.set_cache_budget(plan_bytes=int(full_bytes * 1.5))
    _ = other.plan
    assert not eng.plan_cached and eng._plan is None
    assert not eng.plan_uploaded()

    # sampled training proceeds under the same budget, never replans
    # the full graph, and still learns
    misses0 = cache.cache_stats()["plan"]["misses"]
    rep = tr.fit_sampled(feats, epochs=4, batch_size=64, fanouts=(4, 4))
    losses = [h["loss"] for h in rep.history]
    assert all(b < a for a, b in zip(losses, losses[1:])), losses
    assert cache.cache_stats()["plan"]["misses"] == misses0, \
        "sampled training must not rebuild the full-batch plan"
    assert rep.batch_plan_hit_rate > 0
    assert not eng.plan_cached


def test_batch_store_byte_budget_evicts_and_recovers(
        fresh_caches, gcn_setup):
    """The batch layer is itself byte-bounded: a budget holding ~one
    batch plan forces evictions (recurring seed sets re-miss instead of
    hitting), but training stays correct — identical losses to the
    unbounded run."""
    cache = fresh_caches
    tr, _, feats, _, _ = _trainer(gcn_setup)
    rep_free = tr.fit_sampled(feats, epochs=2, batch_size=64,
                              fanouts=(4, 4))
    per_batch = cache.cache_stats()["batch"]["bytes"] \
        // max(cache.cache_stats()["batch"]["entries"], 1)
    cache.clear_all()

    cache.set_cache_budget(batch_bytes=int(per_batch * 1.5))
    tr2, _, feats2, _, _ = _trainer(gcn_setup)
    rep_tight = tr2.fit_sampled(feats2, epochs=2, batch_size=64,
                                fanouts=(4, 4))
    st = cache.cache_stats()["batch"]
    assert st["evictions"] > 0 and st["entries"] <= 2
    assert [h["loss"] for h in rep_tight.history] == \
        [h["loss"] for h in rep_free.history], \
        "eviction must change cost, never results"


def test_pad_plan_pow2_is_execution_invariant(fresh_caches, gcn_setup):
    """Unit contract of the plan padding: every capacity becomes a
    power of two, and a session over the padded plan computes exactly
    what the unpadded engine computes."""
    from repro.core.plan import pad_plan_pow2
    from repro.gcn.engine import GCNEngine

    eng, feats, labels, mask = gcn_setup()
    ref = eng.forward(feats)
    padded = pad_plan_pow2(eng.plan)
    for ph in padded.phases:
        assert ph.capacity & (ph.capacity - 1) == 0
        for L in ph.hop_len:
            assert L == 0 or (L & (L - 1)) == 0
    assert padded.replica_rows & (padded.replica_rows - 1) == 0
    sub = GCNEngine.from_plan(eng.cfg, padded, eng.dims,
                              graph_fp="padded:" + eng.graph_fp)
    out = sub.forward(feats, params=eng.params)
    np.testing.assert_allclose(out, ref, rtol=0, atol=1e-7)
    # gradients ride the padded plan identically too
    lf, _ = eng.loss_and_grad(feats, labels, mask)
    tr_labels = np.asarray(labels)
    ls, _ = sub.loss_and_grad(feats, tr_labels, mask, params=eng.params)
    assert float(ls) == pytest.approx(float(lf), abs=1e-6)


def test_donation_argnums_resolve_per_backend(monkeypatch):
    """Params/opt-state donation (ROADMAP item): requested on backends
    that implement it, skipped on cpu (XLA would warn per compile).
    Numerics are covered by the bit-identical double-fit test."""
    import jax

    from repro.gcn import train as trn

    assert trn._donation_argnums() == ()  # CI runs on cpu
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert trn._donation_argnums() == (1, 2)
    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
    assert trn._donation_argnums() == (1, 2)


def test_batch_cache_keys_on_parent_graph(fresh_caches, gcn_setup):
    """Regression: the batch-plan key folds in the PARENT graph's
    fingerprint. Two trainers on different graphs with the same vertex
    count and coinciding seed sets must NOT share batch sessions —
    each computes its own graph's loss."""
    from repro.gcn import GCNTrainer

    cache = fresh_caches
    tr_a, eng_a, feats, _, _ = _trainer(gcn_setup, seed=7)
    tr_b, eng_b, _, labels_b, mask_b = _trainer(gcn_setup, seed=8)
    seeds = np.arange(0, 64)
    la, _ = tr_a.sampled_loss_and_grad(feats, seeds, fanouts=(0, 0))
    hits0 = cache.cache_stats()["batch"]["hits"]
    lb, _ = tr_b.sampled_loss_and_grad(feats, seeds, fanouts=(0, 0))
    assert cache.cache_stats()["batch"]["hits"] == hits0, \
        "a different parent graph must be a batch-cache MISS"
    # clean-cache reference for graph B: values must match exactly
    cache.clear_all()
    tr_b2 = GCNTrainer(eng_b, labels_b, mask_b)
    lb2, _ = tr_b2.sampled_loss_and_grad(feats, seeds, fanouts=(0, 0))
    assert float(lb) == float(lb2)
    assert float(la) != float(lb)  # different graphs, different losses


def test_fit_sampled_zero_epochs_returns_empty_report(
        fresh_caches, gcn_setup):
    """epochs=0 mirrors fit(): a valid (empty) report, no crash, no
    batch sessions built."""
    tr, _, feats, _, _ = _trainer(gcn_setup)
    rep = tr.fit_sampled(feats, epochs=0, batch_size=64, fanouts=(2, 2))
    assert rep.history == [] and np.isnan(rep.loss_last)
    assert rep.exchange_bytes_per_step == 0
    assert fresh_caches.cache_stats()["batch"]["entries"] == 0


def test_fit_sampled_rejects_bad_inputs(fresh_caches, gcn_setup):
    tr, eng, feats, _, _ = _trainer(gcn_setup)
    with pytest.raises(ValueError):
        tr.fit_sampled(feats[:100], epochs=1)  # wrong |V|
    with pytest.raises(ValueError):
        tr.fit_sampled(np.stack([feats, feats]), epochs=1)  # not (V, F)
