"""Substrate tests: checkpoint roundtrip/async/reshard, gradient
compression + error feedback, optimizer, data pipeline, fault tolerance."""
import queue
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.tokens import PrefetchIterator, SyntheticLM, TokenDataConfig
from repro.distributed import compression as comp
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    PreemptionGuard,
    StragglerPolicy,
)
from repro.train import optimizer as optlib


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (8, 16)),
            "nested": {"b": jax.random.normal(k2, (32,)),
                       "c": jnp.zeros((3, 3), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    ckpt.save(tmp_path, 7, t, meta={"note": "x"})
    assert ckpt.latest_step(tmp_path) == 7
    restored, step = ckpt.restore(tmp_path, t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_async_and_latest_pointer(tmp_path):
    t = _tree(jax.random.PRNGKey(1))
    saver = ckpt.AsyncCheckpointer(tmp_path)
    saver.save(1, t)
    saver.save(2, t)  # waits for the first
    saver.wait()
    assert ckpt.latest_step(tmp_path) == 2
    # atomicity: no tmp dirs left behind
    assert not list(Path(tmp_path).glob(".tmp_*"))


def test_checkpoint_reshard_on_load(tmp_path):
    """Elastic resume: restore onto a (1-device) mesh with explicit specs."""
    from jax.sharding import PartitionSpec as P

    t = _tree(jax.random.PRNGKey(2))
    ckpt.save(tmp_path, 3, t)
    from repro.core.jax_compat import make_mesh

    mesh = make_mesh((1,), ("data",))
    specs = jax.tree.map(lambda _: P(), t)
    restored, _ = ckpt.restore(tmp_path, t, mesh=mesh, specs=specs)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert isinstance(b, jax.Array) and b.sharding is not None


def test_compression_error_bound_and_feedback():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0}
    ef = comp.init_ef(g)
    q, ef2 = comp.compress_tree(g, ef)
    deq = comp.decompress_tree(q, g)
    # int8 block quantization: error bounded by scale/2 per element
    err = jnp.abs(deq["w"] - g["w"])
    assert float(err.max()) < float(jnp.abs(g["w"]).max()) / 127.0
    # error feedback: residual equals the quantization error
    np.testing.assert_allclose(np.asarray(ef2.residual["w"]),
                               np.asarray(g["w"] - deq["w"]), atol=1e-6)
    # repeated application with EF: accumulated mean error stays ~0
    acc_true = jnp.zeros_like(g["w"])
    acc_q = jnp.zeros_like(g["w"])
    ef = comp.init_ef(g)
    for i in range(20):
        gi = {"w": g["w"] * (1 + 0.01 * i)}
        q, ef = comp.compress_tree(gi, ef)
        acc_q += comp.decompress_tree(q, gi)["w"]
        acc_true += gi["w"]
    rel = float(jnp.linalg.norm(acc_q - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 1e-2


def test_compression_byte_savings():
    g = {"w": jnp.zeros((4096, 128))}
    raw, small = comp.compressed_bytes(g)
    assert small < 0.6 * raw  # ~4x for bf16->int8(+scales)


def test_adamw_minimizes_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    cfg = optlib.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                             total_steps=200, grad_clip=0)
    state = optlib.init(params)
    for _ in range(150):
        g = {"x": 2 * (params["x"] - target)}
        params, state, _ = optlib.apply_updates(cfg, params, g, state)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=0.15)


def test_schedule_warmup_and_cosine():
    cfg = optlib.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                             min_lr_ratio=0.1)
    assert float(optlib.schedule(cfg, jnp.asarray(0.0))) == 0.0
    assert float(optlib.schedule(cfg, jnp.asarray(10.0))) == pytest.approx(1.0)
    assert float(optlib.schedule(cfg, jnp.asarray(100.0))) == pytest.approx(0.1)


def test_data_pipeline_determinism_and_sharding():
    c0 = TokenDataConfig(vocab_size=97, seq_len=16, global_batch=4,
                         host_id=0, num_hosts=2)
    c1 = TokenDataConfig(vocab_size=97, seq_len=16, global_batch=4,
                         host_id=1, num_hosts=2)
    d0, d1 = SyntheticLM(c0), SyntheticLM(c1)
    b0a, b0b = d0.batch_at(5), d0.batch_at(5)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])  # determinism
    assert not np.array_equal(d0.batch_at(5)["tokens"],
                              d1.batch_at(5)["tokens"])  # host sharding
    assert b0a["tokens"].shape == (2, 16)  # per-host split


def test_prefetch_and_straggler_policy():
    d = SyntheticLM(TokenDataConfig(vocab_size=97, seq_len=8, global_batch=2))
    it = PrefetchIterator(d, start_step=0)
    pol = StragglerPolicy(deadline_s=5.0)
    s0, b0 = pol.fetch(it.q)
    assert s0 == 0 and b0["tokens"].shape == (2, 8)
    it.close()
    # empty queue + deadline -> reuse previous batch (bounded staleness)
    pol2 = StragglerPolicy(deadline_s=0.05)
    pol2._last_batch = (s0, b0)
    empty_q = queue.Queue()
    s, b = pol2.fetch(empty_q)
    assert s == s0 and pol2.reused == 1


def test_preemption_guard_and_heartbeat(tmp_path):
    with PreemptionGuard() as g:
        assert not g.should_stop
        g.request_stop()
        assert g.should_stop
    hb = HeartbeatMonitor(tmp_path, host_id=0, stale_after_s=0.05)
    hb.beat()
    assert hb.stale_hosts() == []
    time.sleep(0.1)
    assert hb.stale_hosts() == [0]
