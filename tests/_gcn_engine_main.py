"""Subprocess body for the 8-device GCNEngine API tests.
Run by tests/test_gcn_engine.py with XLA_FLAGS forcing 8 devices."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_gcn_config
from repro.core.graph import erdos
from repro.gcn import GCNEngine, plan_cache_stats

V, E, F = 512, 4096, 16


def base_cfg(model="gcn", **over):
    cfg = get_gcn_config(f"gcn-{model}-rd", "smoke")
    return dataclasses.replace(cfg, agg_buffer_bytes=4 << 10, **over)


def test_plan_cache_same_key_same_object(g):
    e1 = GCNEngine.build(base_cfg(), g, (4, 2))
    e2 = GCNEngine.build(base_cfg(), g, (4, 2))
    assert e1.plan is e2.plan, "same key must return the cached CommPlan"
    # different message-passing model -> different plan...
    e3 = e1.with_config(message_passing="oppr")
    assert e3.plan is not e1.plan
    # ...but flipping back is a pure cache hit (no replanning)
    before = plan_cache_stats()
    e4 = e3.with_config(message_passing=base_cfg().message_passing)
    assert e4.plan is e1.plan
    after = plan_cache_stats()
    assert after["hits"] == before["hits"] + 1
    assert after["misses"] == before["misses"]
    print("ok plan-cache identity + hit accounting")


def test_global_vs_presharded_parity(g, feats):
    eng = GCNEngine.build(base_cfg(), g, (4, 2))
    eng.init_params(jax.random.PRNGKey(0), [F, 8])
    out_global = eng.forward(feats)  # (V, F) -> (V, 8)
    fs = jnp.asarray(eng.shard(feats))  # pre-sharded device array
    out_sharded = eng.forward(fs)
    assert out_sharded.ndim == 4  # (*dims, Vp, 8): same form as the input
    d = np.max(np.abs(eng.unshard(np.asarray(out_sharded)) - out_global))
    assert d == 0.0, d
    print("ok global/presharded parity")


def test_reference_agreement_all_models(g, feats):
    from repro.gcn import registered_models

    for model in registered_models():
        eng = GCNEngine.build(base_cfg(model), g, (4, 2))
        eng.init_params(jax.random.PRNGKey(1), [F, 12, 8])
        out = eng.forward(feats)
        ref = eng.reference(feats)
        err = np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9)
        assert err < 1e-4, (model, err)
        print(f"ok reference agreement {model} err={err:.2e}")


def test_bidir_matches_unidirectional(g, feats):
    uni = GCNEngine.build(base_cfg(), g, (4, 2))
    bi = GCNEngine.build(base_cfg(), g, (4, 2), bidir=True)
    params = uni.init_params(jax.random.PRNGKey(0), [F, 8])
    assert bi.plan is not uni.plan  # bidir is part of the plan key
    d = np.max(np.abs(bi.forward(feats, params) - uni.forward(feats, params)))
    assert d < 1e-5, d
    assert bi.stats()["link_feat_hops"] < uni.stats()["link_feat_hops"]
    print("ok bidir numerics + fewer hops")


def test_agg_backend_parity_multidevice(g, feats):
    """pallas (interpret off-TPU) and jnp aggregation agree on a real
    (4, 2) torus, and the backend switch shares one CommPlan."""
    eng = GCNEngine.build(base_cfg(), g, (4, 2))
    eng.init_params(jax.random.PRNGKey(0), [F, 8])
    out_j = eng.forward(feats, agg_impl="jnp")
    out_p = eng.forward(feats, agg_impl="pallas")
    d = np.max(np.abs(out_p - out_j)) / (np.max(np.abs(out_j)) + 1e-9)
    assert d < 1e-5, d
    k_j, k_p = eng.plan_key_for("jnp"), eng.plan_key_for("pallas")
    assert k_j != k_p and k_j.plan_identity() == k_p.plan_identity()
    st = eng.stats(feat_dim=F)
    assert st["agg_dense_bytes"] > 0 and st["agg_ell_bytes"] > 0
    print(f"ok agg-backend parity on 8 devices (rel err {d:.1e})")


def test_layer_major_parity_multidevice(g, feats):
    """Layer-major chunked inference is bit-identical to full-graph
    forward on a real (4, 2) torus, never builds the full plan on a
    fresh engine, and bounds the device feature working set."""
    from repro.gcn import cache

    eng = GCNEngine.build(base_cfg(), g, (4, 2))
    params = eng.init_params(jax.random.PRNGKey(2), [F, 12, 8])
    ref = np.asarray(eng.forward(feats, params))

    cache.clear_all()
    eng2 = GCNEngine.build(base_cfg(), g, (4, 2))
    out = eng2.forward_layer_major(feats, params, chunk_size=128)
    assert np.array_equal(out, ref), "layer-major != full on 8 devices"
    assert eng2._plan is None and not eng2.plan_cached
    st = eng2.inference_stats()
    assert st["inference_chunks"] == V // 128
    assert 0 < st["peak_feature_bytes"]
    print("ok layer-major parity on 8 devices "
          f"(peak {st['peak_feature_bytes']}B, "
          f"{st['inference_chunks']} chunks)")


def test_stats_link_byte_crosscheck(g, feats):
    eng = GCNEngine.build(base_cfg(), g, (4, 2))
    st = eng.stats(feat_dim=F)
    # independent measurement: traced exchange's actual ppermute operands
    assert eng.measured_link_bytes(feat_dim=F) == \
        st["plan_executor_link_bytes"]
    assert st["executor_link_bytes"] == st["plan_executor_link_bytes"]
    assert st["link_bytes"] == st["link_feat_hops"] * F * 4
    assert 0 < st["link_bytes"] <= st["executor_link_bytes"]
    # bidir plans route both ring directions; measurement must track that
    bi = GCNEngine.build(base_cfg(), g, (4, 2), bidir=True)
    assert bi.measured_link_bytes(feat_dim=F) == \
        bi.stats(feat_dim=F)["plan_executor_link_bytes"]
    print("ok stats cross-check (measured == analytic, uni + bidir)")


def main():
    g = erdos(V, E, seed=5)
    feats = np.random.default_rng(0).normal(size=(V, F)).astype(np.float32)
    test_plan_cache_same_key_same_object(g)
    test_global_vs_presharded_parity(g, feats)
    test_reference_agreement_all_models(g, feats)
    test_bidir_matches_unidirectional(g, feats)
    test_agg_backend_parity_multidevice(g, feats)
    test_layer_major_parity_multidevice(g, feats)
    test_stats_link_byte_crosscheck(g, feats)


if __name__ == "__main__":
    main()
    print("ALL_OK")
