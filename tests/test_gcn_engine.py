"""GCNEngine session API: plan-cache identity, global-vs-presharded
forward parity, reference agreement for every registered model, and
bidirectional-ring equivalence.

The multi-device assertions run in a subprocess (device count must be
set before jax initializes; see test_distributed_gcn.py). The cache /
registry / mesh-derivation tests run in-process on the 1-CPU view."""
import dataclasses
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest


@pytest.mark.slow
def test_engine_8dev():
    script = Path(__file__).parent / "_gcn_engine_main.py"
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ALL_OK" in r.stdout


def _cfg(**over):
    from repro.config import get_gcn_config

    cfg = get_gcn_config("gcn-gcn-rd", "smoke")
    return dataclasses.replace(cfg, agg_buffer_bytes=4 << 10, **over)


def test_plan_cache_identity_single_device():
    from repro.core.graph import erdos
    from repro.gcn import GCNEngine

    g = erdos(256, 2048, seed=3)
    e1 = GCNEngine.build(_cfg(), g, (1, 1))
    e2 = GCNEngine.build(_cfg(), g, (1, 1))
    assert e1.plan is e2.plan
    # every keyed field separates plans
    assert e1.with_config(message_passing="oppe").plan is not e1.plan
    assert e1.with_config(agg_buffer_bytes=8 << 10).plan is not e1.plan
    assert GCNEngine.build(_cfg(), g, (1,)).plan is not e1.plan
    # alpha shapes the round budget (2^x <= alpha*M/S): must key the cache
    e_alpha = e1.with_config(alpha=_cfg().alpha / 8)
    assert e_alpha.plan is not e1.plan
    assert e_alpha.plan.part.num_rounds == e_alpha.part.num_rounds


def test_mesh_pair_derived_from_one_spec():
    from repro.core.graph import erdos
    from repro.gcn import GCNEngine

    g = erdos(128, 512, seed=1)
    eng = GCNEngine.build(_cfg(), g, (1, 1))
    assert eng.torus.dims == eng.dims == (1, 1)
    assert len(eng.axis_names) == 2
    with pytest.raises(ValueError):
        GCNEngine.build(_cfg(), g)  # neither mesh_dims nor mesh
    with pytest.raises(ValueError):
        GCNEngine.build(_cfg(), g, (1, 1), axis_names=("a",))


def test_registry_pluggable_model_roundtrip():
    """A user-registered model runs through the same engine path and
    matches the engine's own oracle."""
    import jax
    from repro.core.graph import erdos
    from repro.gcn import (GCNEngine, get_model, register_model,
                           registered_models)

    def prepare(graph):  # plain (unweighted, no self loops) sum aggregation
        return graph, np.ones(graph.num_edges, np.float32)

    def init_layer(key, fi, fo):
        return {"w": jax.random.normal(key, (fi, fo)) / np.sqrt(fi)}

    def combine(layer, agg, self_feats, last):
        h = agg @ layer["w"]
        return h if last else jax.nn.relu(h)

    name = "plainsum-test"
    if name not in registered_models():
        register_model(name, prepare=prepare, init_layer=init_layer,
                       combine=combine)
    with pytest.raises(ValueError):
        register_model(name, prepare=prepare, init_layer=init_layer,
                       combine=combine)  # duplicate registration rejected
    assert get_model(name).prepare is prepare

    g = erdos(256, 2048, seed=9)
    eng = GCNEngine.build(_cfg(model=name), g, (1, 1))
    params = eng.init_params(jax.random.PRNGKey(2), [8, 4])
    feats = np.random.default_rng(2).normal(size=(256, 8)).astype(np.float32)
    out = eng.forward(feats)
    ref = eng.reference(feats)
    err = np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert err < 1e-4, err

    # overwrite=True must invalidate the cached prepared graph / plan:
    # doubling the edge weights must double the (linear, last-layer) output
    register_model(name, overwrite=True, init_layer=init_layer,
                   combine=combine,
                   prepare=lambda gr: (gr, np.full(gr.num_edges, 2.0,
                                                   np.float32)))
    eng2 = GCNEngine.build(_cfg(model=name), g, (1, 1))
    assert eng2.plan is not eng.plan, "stale plan served after overwrite"
    out2 = eng2.forward(feats, params)
    np.testing.assert_allclose(out2, 2.0 * out, rtol=1e-5, atol=1e-5)

    # a STALE engine built before the overwrite may keep running its old
    # spec (session semantics), but must not poison the cache for fresh
    # engines: exercise the stale engine's cache-filling paths first
    np.testing.assert_allclose(eng.reference(feats, params), out,
                               rtol=1e-5, atol=1e-5)
    eng3 = GCNEngine.build(_cfg(model=name), g, (1, 1))
    np.testing.assert_allclose(eng3.forward(feats, params), out2,
                               rtol=1e-5, atol=1e-5)


def test_forward_rejects_bad_shapes():
    import jax
    from repro.core.graph import erdos
    from repro.gcn import GCNEngine

    g = erdos(128, 512, seed=4)
    eng = GCNEngine.build(_cfg(), g, (1, 1))
    eng.init_params(jax.random.PRNGKey(0), [8, 4])
    with pytest.raises(ValueError):
        eng.forward(np.zeros((64, 8), np.float32))  # wrong |V|
    with pytest.raises(ValueError):
        eng.forward(np.zeros((2, 2, 2), np.float32))  # neither form
    with pytest.raises(ValueError):
        GCNEngine.build(_cfg(), g, (1, 1)).forward(
            np.zeros((128, 8), np.float32))  # no params anywhere
