"""OPPM-for-MoE: expert-parallel dispatch equals the TP reference, and
the dedup strictly reduces cross-shard replicas (the paper's saving)."""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.config import get_lm_config
from repro.core.moe_dispatch import dispatch_stats


@pytest.mark.slow
def test_ep_dispatch_equivalence_4dev():
    script = Path(__file__).parent / "_moe_dispatch_main.py"
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ALL_OK" in r.stdout


def test_dispatch_savings_grow_with_topk_density():
    ds = dispatch_stats(get_lm_config("deepseek-v2-lite-16b"), 16, 2048)
    mx = dispatch_stats(get_lm_config("mixtral-8x7b"), 4, 2048)
    assert 0.0 < ds["savings"] < 1.0
    # top-6-of-64 on 16 shards dedups more than top-2-of-8 on 4 shards
    assert ds["savings"] > mx["savings"] * 0.9
    # fewer shards -> more co-residency -> more savings
    ds4 = dispatch_stats(get_lm_config("deepseek-v2-lite-16b"), 4, 2048)
    assert ds4["savings"] > ds["savings"]
