"""Control-variate (historical-aggregation) sampled training — PR 10.

The tentpole's correctness pins, in dependency order:

  * **full-fanout identity** — with no dropped edges into any
    loss-relevant vertex, ``variance_reduction=True`` is bit-identical
    to the plain path: same loss, same grads, same multi-epoch
    parameter trajectory (property-tested over seeds/backends via the
    hypothesis shim). This is the strongest possible statement that the
    correction composes OUTSIDE the sampled term;
  * **missing-edge complement** — ``sampling.missing_in_edges`` is the
    exact complement of ``induce_in_edges`` over the same parent CSR
    (together they repartition every parent edge whose dst is in the
    batch);
  * **no extra exchange** — the CV backward carries exactly the plain
    step's ppermute payload on the same batch session (the history term
    is differentiation-inert), so the bench's fanout-2-CV vs
    fanout-8-plain byte comparison isolates the fanout effect;
  * **write-back coverage** — after one epoch, the history rows marked
    written are exactly the union of the batches' subgraph vertices,
    and the report's ``history_write_rows`` ledger closes;
  * **pipelined determinism** — the pipelined CV trajectory (history
    reads on the training thread, in consumption order; tracing ON) is
    bit-identical to serial;
  * **graceful degradation** — a zero history budget rejects every
    write-back: CV silently degrades toward plain sampling (layer-0
    correction stays exact) and the loss still decreases;
  * **HistoryStore unit contract** — LRU eviction under the byte
    budget, whole-entry admission (reject, never partial), budget
    validation, the cache-layer wiring (``set_cache_budget`` /
    ``cache_stats`` / ``clear_all`` / plan-evict cascade).

Runs in-process on the 1-CPU view (mesh ``(1, 1)``).
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

V, E, F, C = 256, 2048, 8, 4


def _trainer(gcn_setup, **kw):
    from repro.gcn import GCNTrainer

    eng, feats, labels, mask = gcn_setup(**kw)
    return GCNTrainer(eng, labels, mask), eng, feats, labels, mask


def _leaves_equal(a, b):
    import jax

    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# full-fanout identity (the parity anchor)
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 3), impl=st.sampled_from(["jnp", "pallas"]))
def test_full_fanout_cv_loss_grad_bit_identical(seed, impl):
    """One batch, full fanout: CV loss and every grad leaf equal the
    plain path bit-for-bit, on both aggregation backends."""
    import jax

    from repro.config import get_gcn_config
    from repro.core.graph import erdos
    from repro.gcn import GCNEngine, GCNTrainer, cache

    cache.clear_all()
    rng = np.random.default_rng(seed)
    g = erdos(V, E, seed=seed)
    feats = rng.normal(size=(V, F)).astype(np.float32)
    labels = rng.integers(0, C, size=V)
    mask = (rng.random(V) < 0.8).astype(np.float32)
    eng = GCNEngine.build(get_gcn_config("gcn-gcn-rd", "smoke"), g, (1, 1))
    eng.init_params(jax.random.PRNGKey(seed), [F, 8, C])
    tr = GCNTrainer(eng, labels, train_mask=mask)
    seeds = np.flatnonzero(mask > 0)[:64]

    l0, g0 = tr.sampled_loss_and_grad(feats, seeds, fanouts=(-1, -1),
                                      agg_impl=impl)
    l1, g1 = tr.sampled_loss_and_grad(feats, seeds, fanouts=(-1, -1),
                                      agg_impl=impl,
                                      variance_reduction=True)
    assert float(l0) == float(l1)
    _leaves_equal(g0, g1)


def test_full_fanout_cv_fit_trajectory_bit_identical(fresh_caches,
                                                     gcn_setup):
    """Multi-epoch ``fit_sampled``: the whole VR trajectory (per-epoch
    losses AND final params) equals plain at full fanout, write-backs
    and all."""
    tr0, _, feats, _, _ = _trainer(gcn_setup)
    rep0 = tr0.fit_sampled(feats, epochs=3, batch_size=64,
                           fanouts=(-1, -1))
    fresh_caches.clear_all()
    tr1, _, feats1, _, _ = _trainer(gcn_setup)
    rep1 = tr1.fit_sampled(feats1, epochs=3, batch_size=64,
                           fanouts=(-1, -1), variance_reduction=True)
    assert [h["loss"] for h in rep0.history] == \
        [h["loss"] for h in rep1.history]
    _leaves_equal(rep0.params, rep1.params)
    assert rep1.variance_reduction and not rep0.variance_reduction


# ---------------------------------------------------------------------------
# missing_in_edges: the exact complement
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 4), nbatch=st.sampled_from([1, 32, 128, 256]))
def test_missing_in_edges_is_exact_complement(seed, nbatch):
    """induced + missing repartition EVERY parent edge whose dst is in
    the batch: counts add up, and each edge lands on exactly the side
    its src membership dictates (weights carried through unchanged)."""
    from repro.core.graph import erdos
    from repro.core import sampling

    g = erdos(V, E, seed=seed)
    rng = np.random.default_rng(seed)
    indptr, src, vals = sampling.csr_in_with_values(
        g, rng.normal(size=E).astype(np.float32))
    nodes = np.sort(rng.choice(V, size=nbatch, replace=False))

    sub, svals = sampling.induce_in_edges(indptr, src, vals, nodes)
    mdst, msrc, mvals = sampling.missing_in_edges(indptr, src, vals,
                                                  nodes)
    in_batch = np.zeros(V, bool)
    in_batch[nodes] = True
    # every parent edge into a batch dst, by construction
    total = int(sum(indptr[v + 1] - indptr[v] for v in nodes))
    assert sub.src.size + msrc.size == total
    assert np.all(~in_batch[msrc])  # missing edges: src outside
    assert np.all(in_batch[nodes[sub.src]])  # induced: src inside
    # weight multiset is preserved across the split
    kept = np.concatenate([np.asarray(svals), np.asarray(mvals)])
    want = np.concatenate([vals[indptr[v]:indptr[v + 1]] for v in nodes])
    np.testing.assert_array_equal(np.sort(kept), np.sort(want))


# ---------------------------------------------------------------------------
# no extra exchange: CV backward payload == plain
# ---------------------------------------------------------------------------


def test_cv_exchange_payload_equals_plain(fresh_caches, gcn_setup):
    """On the same sampled batch session, the traced CV backward moves
    exactly the plain backward's ppermute bytes — the history term adds
    no exchange, so per-step bytes shrink with the fanout and nothing
    else."""
    from repro.gcn.train import _train_exchange_bytes

    tr, eng, feats, _, mask = _trainer(gcn_setup)
    seeds = np.flatnonzero(mask > 0)[:64]
    bs = tr._batch_session(
        tr._sampled_batch(tr._sampler((2, 2), 0), seeds))
    params = eng._resolve_params(None)
    plain = _train_exchange_bytes(bs.engine, params, tr.impl)
    cv = _train_exchange_bytes(bs.engine, params, tr.impl, cv=True)
    assert cv == plain


# ---------------------------------------------------------------------------
# write-back coverage
# ---------------------------------------------------------------------------


def test_write_back_rows_are_exactly_batch_vertices(fresh_caches,
                                                    gcn_setup):
    """After one VR epoch the history's written mask covers exactly the
    union of the epoch's subgraph vertex sets, and the report's
    ``history_write_rows`` equals (hidden layers) x (sum of subgraph
    sizes)."""
    from repro.gcn import history

    tr, eng, feats, _, mask = _trainer(gcn_setup)
    rep = tr.fit_sampled(feats, epochs=1, batch_size=64, fanouts=(2, 2),
                         variance_reduction=True)
    # replay the (deterministic, memoized) sampling to recover the
    # per-batch vertex sets the fit consumed
    sampler = tr._sampler((2, 2), 0)
    train_nodes = np.flatnonzero(mask > 0)
    expect = np.zeros(V, bool)
    rows = 0
    for seeds in sampler.epoch_batches(train_nodes, 64, epoch=0):
        batch = tr._sampled_batch(sampler, seeds)
        expect[batch.nodes] = True
        rows += int(batch.nodes.size)
    hist = history.default_history()
    got = hist.read(eng.graph_fp, 1, np.arange(V))
    assert got is not None
    np.testing.assert_array_equal(got[1], expect)
    assert rep.history_write_rows == rows  # one hidden layer (F,8,C)


# ---------------------------------------------------------------------------
# pipelined CV: bit-identical to serial, tracing on
# ---------------------------------------------------------------------------


def test_pipelined_cv_fit_bit_identical_to_serial(fresh_caches,
                                                  gcn_setup):
    """History is read on the training thread in consumption order, so
    overlapping prepare with execution — with tracing ON, and with its
    spans landing in the known-phase set — changes nothing about the
    VR trajectory."""
    from repro.gcn import obs

    tr0, _, feats, _, _ = _trainer(gcn_setup)
    rep0 = tr0.fit_sampled(feats, epochs=3, batch_size=64,
                           fanouts=(2, 2), variance_reduction=True)
    fresh_caches.clear_all()
    capacity = obs.trace._buf.maxlen
    obs.trace.configure(enabled=True, capacity=capacity)
    obs.trace.clear()
    try:
        tr1, _, feats1, _, _ = _trainer(gcn_setup)
        rep1 = tr1.fit_sampled(feats1, epochs=3, batch_size=64,
                               fanouts=(2, 2), variance_reduction=True,
                               pipeline_depth=2, pipeline_workers=2)
        names = {e["name"] for e in obs.trace.events()}
    finally:
        obs.trace.configure(enabled=False)
        obs.trace.clear()
    assert [h["loss"] for h in rep0.history] == \
        [h["loss"] for h in rep1.history]
    _leaves_equal(rep0.params, rep1.params)
    assert rep1.pipeline_depth == 2
    # the CV phases traced, and only known phases appeared
    assert {"history_agg", "history_write"} <= names
    assert names <= set(obs.KNOWN_PHASES)


# ---------------------------------------------------------------------------
# graceful degradation under eviction
# ---------------------------------------------------------------------------


def test_zero_history_budget_degrades_gracefully(fresh_caches, gcn_setup):
    """``history_bytes=0`` rejects every write-back: no entry ever
    exists, every layer>=1 correction falls back to zero (plain
    sampling), and training still converges — VR never makes things
    crash-or-garbage, it only sharpens the estimate when memory
    allows."""
    from repro.gcn import history

    fresh_caches.set_cache_budget(history_bytes=0)
    tr, _, feats, _, _ = _trainer(gcn_setup)
    rep = tr.fit_sampled(feats, epochs=4, batch_size=64, fanouts=(2, 2),
                         variance_reduction=True)
    s = history.default_history().stats()
    assert s["entries"] == 0 and s["rejected_writes"] > 0
    assert rep.history_write_rows == 0
    assert rep.history_bytes == 0
    assert rep.history[-1]["loss"] < rep.history[0]["loss"]


def test_mid_fit_budget_shrink_then_regrow(fresh_caches, gcn_setup):
    """Shrinking the history budget mid-run (epoch boundary) evicts the
    table; the next fit re-warms it through write-backs — the
    eviction/re-warm cycle is loss-monotone-harmless, not fatal."""
    from repro.gcn import history

    tr, _, feats, _, _ = _trainer(gcn_setup)
    tr.fit_sampled(feats, epochs=2, batch_size=64, fanouts=(2, 2),
                   variance_reduction=True)
    hist = history.default_history()
    assert hist.stats()["entries"] == 1
    fresh_caches.set_cache_budget(history_bytes=0)  # evict everything
    assert hist.stats()["entries"] == 0
    fresh_caches.set_cache_budget(history_bytes=None)  # lift the cap
    rep = tr.fit_sampled(feats, epochs=2, batch_size=64, fanouts=(2, 2),
                         variance_reduction=True)
    assert hist.stats()["entries"] == 1  # re-warmed
    assert rep.history_write_rows > 0
    assert np.isfinite(rep.history[-1]["loss"])


# ---------------------------------------------------------------------------
# HistoryStore unit contract
# ---------------------------------------------------------------------------


def test_history_store_read_write_and_fallback_masks():
    from repro.gcn.history import HistoryStore

    h = HistoryStore()
    h.ensure_height("g", 10)
    vals = np.arange(6, dtype=np.float32).reshape(3, 2)
    assert h.write("g", 1, [2, 5, 7], vals) == 3
    rows, valid = h.read("g", 1, [0, 2, 5, 7, 9])
    np.testing.assert_array_equal(valid, [False, True, True, True, False])
    np.testing.assert_array_equal(rows[1:4], vals)
    np.testing.assert_array_equal(rows[0], [0.0, 0.0])
    assert h.read("g", 2, [0]) is None  # absent layer: hard fallback
    assert h.version("g", 1) == 1 and h.version("g", 2) == 0
    s = h.stats()
    assert s["write_rows"] == 3 and s["read_rows"] == 3
    assert s["fallback_rows"] == 2 + 1


def test_history_store_budget_lru_and_rejection():
    from repro.gcn.history import HistoryStore

    entry_bytes = 8 * 4 * 4 + 8  # (8,4) f32 + (8,) bool
    h = HistoryStore(budget_bytes=2 * entry_bytes)
    h.ensure_height("g", 8)
    r = np.zeros((8, 4), np.float32)
    nodes = np.arange(8)
    assert h.write("g", 1, nodes, r) == 8
    assert h.write("g", 2, nodes, r) == 8
    h.read("g", 1, nodes)  # touch layer 1: layer 2 becomes LRU
    assert h.write("g", 3, nodes, r) == 8  # evicts layer 2
    assert h.read("g", 2, nodes) is None
    assert h.read("g", 1, nodes) is not None
    assert h.stats()["evictions"] == 1
    assert h.stats()["bytes"] <= 2 * entry_bytes
    # an entry that can never fit is rejected whole, not truncated
    big = np.zeros((8, 4096), np.float32)
    assert h.write("g", 4, nodes, big) == 0
    assert h.stats()["rejected_writes"] == 1
    # shrink-to-zero drops everything immediately
    h.set_budget(0)
    assert h.stats()["entries"] == 0 and h.stats()["bytes"] == 0
    with pytest.raises(ValueError, match="budget_bytes"):
        h.set_budget(-1)
    with pytest.raises(ValueError, match="budget_bytes"):
        HistoryStore(budget_bytes=-5)


def test_history_cache_wiring_and_plan_evict_cascade(fresh_caches):
    """The cache layer budgets, reports, clears and cascades the
    default history store exactly like the feature store."""
    from repro.gcn import cache
    from repro.gcn.history import default_history

    hist = default_history()
    hist.ensure_height("gfp", 4)
    hist.write("gfp", 1, [0, 1], np.ones((2, 3), np.float32))
    assert cache.cache_stats()["history"]["entries"] == 1
    cache.set_cache_budget(history_bytes=1 << 20)
    assert hist.budget_bytes == 1 << 20
    # the plan-eviction cascade releases that graph's history with it
    key = cache.PlanKey(graph_fp="gfp", model="gcn",
                        message_passing="rd", use_rounds=True,
                        mesh_dims=(1, 1), agg_buffer_bytes=4096,
                        bidir=False, alpha=1.0, feat_in=8, model_gen=0)
    cache._on_plan_evict(key, None)
    assert cache.cache_stats()["history"]["entries"] == 0
    hist.write("gfp", 1, [0], np.ones((1, 3), np.float32))
    cache.clear_all()
    assert cache.cache_stats()["history"]["entries"] == 0
