"""Use hypothesis when installed; otherwise a minimal deterministic
fallback so the property tests still run (this container has no
``hypothesis`` wheel and installing packages is not allowed).

The fallback supports exactly the subset our tests use — ``@given`` with
keyword strategies, ``@settings(max_examples=..., deadline=...)``,
``strategies.integers`` and ``strategies.sampled_from`` — and drives
each test with a fixed-seed random sample plus the strategy endpoints,
so runs are reproducible and bounds are always exercised.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised when hypothesis is available
    from hypothesis import given, settings, strategies
except ModuleNotFoundError:
    import inspect
    import random

    class _Strategy:
        def __init__(self, sample, endpoints=()):
            self.sample = sample  # fn(rng) -> value
            self.endpoints = tuple(endpoints)

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value),
                             endpoints=(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements),
                             endpoints=(elements[0], elements[-1]))

    def settings(max_examples: int = 20, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(0xC0FFEE)
                # endpoint case first: all strategies at their bounds
                for pick in (0, 1):
                    ex = {k: s.endpoints[min(pick, len(s.endpoints) - 1)]
                          for k, s in strats.items()}
                    fn(*args, **ex, **kwargs)
                for _ in range(max(n - 2, 0)):
                    ex = {k: s.sample(rng) for k, s in strats.items()}
                    fn(*args, **ex, **kwargs)

            # copy identity but NOT the signature: pytest must not treat
            # the strategy kwargs as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strats])
            return wrapper

        return deco
