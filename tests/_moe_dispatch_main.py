"""Subprocess body: EP MoE dispatch (OPPM dedup) == TP MoE on 4 devices."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import dataclasses
import functools
import os.path as osp
import sys

sys.path.insert(0, osp.join(osp.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import get_lm_config
from repro.core import jax_compat
from repro.core.moe_dispatch import EPConfig, ep_moe_apply
from repro.nn import moe as moe_lib
from repro.nn.module import init_tree


def main():
    cfg = get_lm_config("deepseek-v2-lite-16b", "smoke")
    cfg = dataclasses.replace(cfg, num_experts=8, top_k=4,
                              num_shared_experts=0, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = jax.tree.map(lambda x: x.astype(jnp.float32),
                     init_tree(moe_lib.moe_defs(cfg), key))
    T, D = 64, cfg.d_model
    x = jax.random.normal(key, (T, D), jnp.float32) * 0.5
    y_ref = moe_lib.moe_apply(cfg, p, x[None])[0][0]

    mesh = jax_compat.make_mesh((4,), ("model",))
    specs = {"router": P(), "w_gate": P("model"), "w_up": P("model"),
             "w_down": P("model")}
    reps = {}
    for dedup in (True, False):
        ep = EPConfig(axis="model", num_shards=4, capacity_factor=8.0,
                      dedup=dedup)

        @functools.partial(jax_compat.shard_map, mesh=mesh,
                           in_specs=(specs, P("model")),
                           out_specs=(P("model"), P("model")))
        def run(pl, xl):
            y, stats = ep_moe_apply(cfg, ep, pl, xl)
            return y, stats["replicas"][None]

        y, rep = run(p, x)
        err = float(jnp.max(jnp.abs(y - y_ref)))
        assert err < 1e-4, (dedup, err)
        reps[dedup] = int(jnp.asarray(rep).sum())
        print(f"ok dedup={dedup} err={err:.2e} replicas={reps[dedup]}")
    # the paper's dedup must strictly reduce cross-shard replicas
    assert reps[True] < reps[False], reps
    print("ALL_OK")


if __name__ == "__main__":
    main()
