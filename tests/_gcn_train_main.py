"""Subprocess body for the 8-device distributed-training tests.
Run by tests/test_gcn_train.py with XLA_FLAGS forcing 8 devices.

Covers the acceptance criteria on a REAL (4, 2) torus (2 mesh dims):
gradient parity against the single-node dense-adjacency reference for
all three models and both aggregation backends, decreasing loss under
``GCNTrainer.fit``, the measured backward-exchange payload (the VJP is
a reversed relay replay: one transposed replay per interior layer), and
the train->serve handoff through ``GCNService.adopt`` without
replanning."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_gcn_config
from repro.core.graph import erdos
from repro.gcn import (GCNEngine, GCNService, GCNTrainer, cache_stats,
                       reference_loss_and_grad)

# covers (among the full-batch acceptance criteria) the sampled
# mini-batch pipeline on a REAL 2-dim torus: full-fanout parity against
# full-batch loss/grads on both backends, and bounded-fanout training
# that decreases the loss without ever building the full-batch plan

V, E, F, C = 512, 4096, 8, 4
DIMS = (4, 2)


def base_cfg(model="gcn", **over):
    cfg = get_gcn_config(f"gcn-{model}-rd", "smoke")
    return dataclasses.replace(cfg, agg_buffer_bytes=4 << 10, **over)


def test_grad_parity_all_models_both_backends(g, feats, labels, mask):
    """Distributed gradients == dense single-node reference to fp32
    tolerance, for GCN/GIN/SAGE x {jnp, pallas} on the (4, 2) torus."""
    for model in ("gcn", "gin", "sage"):
        eng = GCNEngine.build(base_cfg(model), g, DIMS)
        eng.init_params(jax.random.PRNGKey(1), [F, 8, C])
        loss_r, grads_r = reference_loss_and_grad(eng, feats, labels, mask)
        for impl in ("jnp", "pallas"):
            loss_d, grads_d = eng.loss_and_grad(feats, labels, mask,
                                                agg_impl=impl)
            assert abs(float(loss_d) - float(loss_r)) < 1e-5, (model, impl)
            errs = [
                float(jnp.max(jnp.abs(a - b))
                      / (jnp.max(jnp.abs(b)) + 1e-9))
                for a, b in zip(jax.tree.leaves(grads_d),
                                jax.tree.leaves(grads_r))]
            assert max(errs) < 1e-4, (model, impl, max(errs))
            print(f"ok grad parity {model}/{impl} "
                  f"(max rel err {max(errs):.1e})")


def test_fit_decreasing_loss_and_backward_bytes(g, feats, labels, mask):
    """fit() decreases the loss on 2 mesh dims, and the measured
    training-step exchange is exactly 3 relay replays for the 2-layer
    equal-width net: two forward + ONE transposed backward (layer 1's
    input needs no cotangent — features are not differentiated)."""
    eng = GCNEngine.build(base_cfg(), g, DIMS)
    eng.init_params(jax.random.PRNGKey(0), [F, F, C])  # widths equal: F
    tr = GCNTrainer(eng, labels, mask)
    rep = tr.fit(feats, epochs=8)
    assert rep.loss_last < rep.loss_first, \
        (rep.loss_first, rep.loss_last)
    fwd_bytes = eng.measured_link_bytes(feat_dim=F)
    assert fwd_bytes > 0
    assert rep.exchange_bytes_per_step == 3 * fwd_bytes, \
        (rep.exchange_bytes_per_step, fwd_bytes)
    print(f"ok fit loss {rep.loss_first:.4f} -> {rep.loss_last:.4f}; "
          f"train-step exchange = 3 x forward ({fwd_bytes} B)")
    return eng, rep


def test_handoff_serves_without_replanning(eng, feats):
    """The trained session admitted via adopt() serves batches matching
    the oracle with zero plan misses and zero re-uploads."""
    svc = GCNService(DIMS, max_batch=4)
    m0 = cache_stats()["plan"]["misses"]
    assert eng.plan_uploaded()
    svc.adopt("trained", eng)
    for _ in range(3):
        svc.submit("trained", feats)
    done = svc.run()
    assert len(done) == 3
    assert cache_stats()["plan"]["misses"] == m0, "handoff must not replan"
    ref = eng.reference(feats)
    for r in done:
        err = np.max(np.abs(r.out - ref)) / (np.max(np.abs(ref)) + 1e-9)
        assert err < 1e-4, err
    st = svc.stats()
    assert st["uploads"] == 0, "adopted session was already resident"
    print(f"ok train->serve handoff (bucket rate "
          f"{st['batch_bucket_hit_rate']:.2f}, uploads {st['uploads']})")


def test_sampled_parity_and_bounded_training(g, feats, labels, mask):
    """Neighbor-sampled pipeline on the (4, 2) torus. Full fanout +
    seeds = every labeled vertex: one sampled batch's loss/grads equal
    full-batch ``loss_and_grad`` (both agg backends, each batch on its
    own padded subgraph plan). Bounded fanout: the loss decreases,
    recurring seed sets hit the batch-plan cache, and the full-batch
    plan store is never touched by training."""
    eng = GCNEngine.build(base_cfg(), g, DIMS)
    eng.init_params(jax.random.PRNGKey(2), [F, 8, C])
    tr = GCNTrainer(eng, labels, mask)
    seeds = np.flatnonzero(mask > 0)
    for impl in ("jnp", "pallas"):
        loss_f, grads_f = eng.loss_and_grad(feats, labels, mask,
                                            agg_impl=impl)
        loss_s, grads_s = tr.sampled_loss_and_grad(
            feats, seeds, fanouts=(-1, -1), agg_impl=impl)
        assert abs(float(loss_s) - float(loss_f)) < 1e-5, impl
        errs = [
            float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-9))
            for a, b in zip(jax.tree.leaves(grads_s),
                            jax.tree.leaves(grads_f))]
        assert max(errs) < 1e-4, (impl, max(errs))
        print(f"ok sampled full-fanout parity {impl} "
              f"(max rel err {max(errs):.1e})")

    eng2 = GCNEngine.build(base_cfg(), g, DIMS)
    eng2.init_params(jax.random.PRNGKey(3), [F, 8, C])
    tr2 = GCNTrainer(eng2, labels, mask)
    st0 = cache_stats()["plan"]
    rep = tr2.fit_sampled(feats, epochs=6, batch_size=128,
                          fanouts=(8, 8))
    assert rep.loss_last < rep.loss_first, (rep.loss_first, rep.loss_last)
    assert rep.batch_plan_hit_rate > 0, "fixed seed sets must hit"
    st1 = cache_stats()["plan"]
    assert (st1["misses"], st1["hits"]) == (st0["misses"], st0["hits"]), \
        "sampled training must not touch the full-batch plan store"
    print(f"ok sampled training loss {rep.loss_first:.4f} -> "
          f"{rep.loss_last:.4f} ({rep.batches_per_epoch} batches/epoch, "
          f"buckets {rep.vertex_buckets}, hit rate "
          f"{rep.batch_plan_hit_rate:.2f}, "
          f"{rep.train_step_compiles} step compiles)")


def main():
    g = erdos(V, E, seed=5)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(V, F)).astype(np.float32)
    labels = rng.integers(0, C, size=V)
    mask = (rng.random(V) < 0.8).astype(np.float32)
    test_grad_parity_all_models_both_backends(g, feats, labels, mask)
    eng, _ = test_fit_decreasing_loss_and_backward_bytes(
        g, feats, labels, mask)
    test_handoff_serves_without_replanning(eng, feats)
    test_sampled_parity_and_bounded_training(g, feats, labels, mask)


if __name__ == "__main__":
    main()
    print("ALL_OK")
