"""Shared fixtures. NOTE: device count deliberately NOT forced here —
smoke tests and benches should see the 1 real CPU device. Multi-device
tests live in files that spawn a subprocess or set XLA_FLAGS via
pytest-forked-style isolation (see test_distributed_gcn.py).

GCN-stack fixtures (used by test_gcn_train / test_gcn_service /
test_gcn_agg_impl / test_gcn_cache / test_gcn_train_sampled, which used
to each re-implement them):

  * ``gcn_cfg``      — smoke-config factory (small aggregation buffer so
                       the SREM rounds path is exercised even at test
                       scale);
  * ``erdos_graph``  — seeded graph factory, session-memoized so the
                       same (V, E, seed) triple is built once per run;
  * ``gcn_setup``    — (engine, feats, labels, mask) factory for
                       trainer-shaped tests;
  * ``fresh_caches`` — cleared process-wide GCN caches with ALL budgets
                       saved/restored, so budget games never leak
                       across tests;
  * ``feature_store``— seeded features registered in the process-wide
                       feature store under a chosen byte budget
                       (composes ``fresh_caches`` for restore).
"""
import os
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device tests that spawn a subprocess")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def gcn_cfg():
    """Factory: smoke GCNConfig for ``model`` with overrides. The small
    default aggregation buffer forces several SREM rounds at |V|=256."""
    import dataclasses

    from repro.config import get_gcn_config

    def make(model="gcn", *, agg_buffer_bytes=4 << 10, **over):
        cfg = get_gcn_config(f"gcn-{model}-rd", "smoke")
        return dataclasses.replace(cfg, agg_buffer_bytes=agg_buffer_bytes,
                                   **over)

    return make


@pytest.fixture(scope="session")
def erdos_graph():
    """Factory: seeded Erdos graph, memoized per (V, E, seed) — graphs
    are immutable inputs, so one build serves every module."""
    from repro.core.graph import erdos

    memo = {}

    def make(V=256, E=2048, seed=0):
        key = (int(V), int(E), int(seed))
        if key not in memo:
            memo[key] = erdos(*key[:2], seed=key[2])
        return memo[key]

    return make


@pytest.fixture
def gcn_setup(gcn_cfg, erdos_graph):
    """Factory: one GCN training workload — a fresh engine on a seeded
    Erdos graph with initialized params, plus seeded features, integer
    labels and a 0/1 train mask. Engines are built per call (tests
    play cache games); graphs/arrays are deterministic per seed."""
    import jax

    from repro.gcn import GCNEngine

    def make(model="gcn", dims=(1, 1), *, V=256, E=2048, F=8, C=4,
             seed=7, layer_dims=None, train_frac=0.8, **cfg_over):
        g = erdos_graph(V, E, seed=seed)
        eng = GCNEngine.build(gcn_cfg(model, **cfg_over), g, dims)
        eng.init_params(jax.random.PRNGKey(0),
                        list(layer_dims or (F, 8, C)))
        arr = np.random.default_rng(seed)
        feats = arr.normal(size=(V, F)).astype(np.float32)
        labels = arr.integers(0, C, size=V)
        mask = (arr.random(V) < train_frac).astype(np.float32)
        return eng, feats, labels, mask

    return make


@pytest.fixture
def fresh_caches():
    """Cleared GCN caches + all six budgets saved/restored, so the
    budget games below never leak into other tests. The default
    FeatureStore's HOST column store is cleared explicitly on both
    sides (``clear_all`` routes through ``FeatureStore.clear``, but
    hygiene must not hinge on that wiring): two tests registering
    different features under the same graph fingerprint must never see
    each other's rows (regression-pinned in test_feature_store.py).
    The store's shape knobs (``block_vertices``/``hot_fraction``) are
    saved/restored too."""
    from repro.gcn import cache, featurestore, history

    store = featurestore.default_store()
    hist = history.default_history()
    cache.clear_all()
    store.clear()  # belt and braces: no host columns survive either
    saved = (cache._PLANS.budget_bytes, cache._ELL.budget_bytes,
             cache._PREP.budget_bytes, cache._STEPS.max_entries,
             cache._BATCH.budget_bytes, store.budget_bytes,
             store.block_vertices, store.hot_fraction,
             hist.budget_bytes)
    yield cache
    store.block_vertices, store.hot_fraction = saved[6], saved[7]
    cache.set_cache_budget(plan_bytes=saved[0], ell_bytes=saved[1],
                           prep_bytes=saved[2], step_entries=saved[3],
                           batch_bytes=saved[4], feature_bytes=saved[5],
                           history_bytes=saved[8])
    cache.clear_all()
    store.clear()


@pytest.fixture
def feature_store(fresh_caches, erdos_graph):
    """Factory: seeded features registered in the process-wide feature
    store under a fresh budget. Returns ``(store, graph, feats,
    handle)``; budgets are restored by ``fresh_caches``."""
    from repro.gcn import cache, featurestore

    def make(V=256, E=2048, F=8, seed=7, *, budget=64 << 20,
             block_vertices=32):
        store = featurestore.default_store()
        cache.set_cache_budget(feature_bytes=budget)
        g = erdos_graph(V, E, seed=seed)
        feats = (np.random.default_rng(seed)
                 .normal(size=(V, F)).astype(np.float32))
        handle = store.register(g, feats, block_vertices=block_vertices)
        return store, g, feats, handle

    return make
