"""Shared fixtures. NOTE: device count deliberately NOT forced here —
smoke tests and benches should see the 1 real CPU device. Multi-device
tests live in files that spawn a subprocess or set XLA_FLAGS via
pytest-forked-style isolation (see test_distributed_gcn.py)."""
import os
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device tests that spawn a subprocess")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
