"""The sampling pipeline (``repro.gcn.pipeline``): bit-identity and
fault harness for the overlapped sample→plan→gather→upload chain.

The pipelined ``fit_sampled`` path reorders every host-side build
behind the training thread, so the pins here are deliberately
adversarial:

  * **bit-identity property test** — the pipelined trajectory (losses,
    final params, consumed batch-fingerprint order) equals the serial
    ``pipeline_depth=0`` run EXACTLY, across depths {1, 2, 4}, worker
    counts {1, 3}, both aggregation backends, and with per-epoch
    reshuffling (seeded epoch permutations must match);
  * **fault injection** — a builder thread raising mid-epoch surfaces
    the exception on the training thread, drains the pool (no orphan
    ``gcn-pipe`` threads), and the trainer stays usable;
  * **eviction during background builds** — shrinking the batch/feature
    budgets while builders are in flight neither deadlocks nor changes
    a single bit of the trajectory;
  * **SamplePipeline unit properties** — in-order delivery under random
    worker delays, bounded look-ahead, fail-fast drain, idempotent
    close, overlap accounting sanity.

Runs in-process on the 1-CPU view (mesh ``(1, 1)``).
"""
import threading
import time

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

V, E, F, C = 256, 2048, 8, 4


def _trainer(gcn_setup, **kw):
    from repro.gcn import GCNTrainer

    eng, feats, labels, mask = gcn_setup(**kw)
    return GCNTrainer(eng, labels, mask), eng, feats, labels, mask


def _fit(gcn_setup, cache, *, depth, workers=2, impl="jnp",
         reshuffle=False, epochs=3, **fit_kw):
    """Fresh engine + cleared caches -> one fit_sampled run; returns
    (losses, param leaves, fingerprints, report, engine)."""
    import jax

    cache.clear_all()
    tr, eng, feats, _, _ = _trainer(gcn_setup)
    rep = tr.fit_sampled(feats, epochs=epochs, batch_size=64,
                         fanouts=(4, 4), agg_impl=impl,
                         reshuffle_each_epoch=reshuffle,
                         pipeline_depth=depth, pipeline_workers=workers,
                         **fit_kw)
    losses = [h["loss"] for h in rep.history]
    leaves = [np.asarray(a) for a in jax.tree.leaves(rep.params)]
    return losses, leaves, rep.batch_fingerprints, rep, eng


def _no_pipe_threads():
    return [t.name for t in threading.enumerate()
            if t.name.startswith("gcn-pipe")]


# ---------------------------------------------------------------------------
# bit-identity property test
# ---------------------------------------------------------------------------


# serial references, one per (backend, reshuffle) — recomputed lazily so
# each property example diffs against the right serial trajectory
_SERIAL_REFS: dict = {}


@settings(max_examples=6, deadline=None)
@given(depth=st.sampled_from([1, 2, 4]),
       workers=st.sampled_from([1, 3]),
       impl=st.sampled_from(["jnp", "pallas"]),
       reshuffle=st.sampled_from([False, True]))
def test_pipelined_fit_is_bit_identical_to_serial(
        fresh_caches, gcn_setup, depth, workers, impl, reshuffle):
    """THE contract: for every (depth, workers, backend, reshuffle)
    combination, the pipelined trajectory equals the serial one
    bit-for-bit — same per-epoch losses, same final params, same batch
    consumption order (fingerprints). Reordered background builds must
    change cost only, never a single bit."""
    key = (impl, reshuffle)
    if key not in _SERIAL_REFS:
        _SERIAL_REFS[key] = _fit(gcn_setup, fresh_caches, depth=0,
                                 impl=impl, reshuffle=reshuffle)[:3]
    ref_losses, ref_leaves, ref_fps = _SERIAL_REFS[key]
    losses, leaves, fps, rep, _ = _fit(
        gcn_setup, fresh_caches, depth=depth, workers=workers,
        impl=impl, reshuffle=reshuffle)
    assert losses == ref_losses, (depth, workers, impl, reshuffle)
    assert fps == ref_fps, "batch consumption order diverged"
    assert len(leaves) == len(ref_leaves)
    for a, b in zip(leaves, ref_leaves):
        np.testing.assert_array_equal(a, b)
    assert rep.pipeline_depth == depth
    assert rep.pipeline_workers == workers
    assert not _no_pipe_threads()


def test_serial_path_reports_zero_pipeline_stats(fresh_caches, gcn_setup):
    """depth=0 keeps the exact pre-pipeline behavior: no threads, no
    overlap accounting, fingerprints still recorded (the serial run is
    the reference the property test diffs against)."""
    losses, _, fps, rep, eng = _fit(gcn_setup, fresh_caches, depth=0,
                                    epochs=2)
    assert rep.pipeline_depth == 0 and rep.pipeline_workers == 0
    assert rep.pipeline_overlap_fraction == 0.0
    assert rep.pipeline_prepare_s == 0.0
    assert len(fps) == rep.batches_per_epoch * 2
    st_ = eng.stats()
    assert st_["pipeline_depth"] == 0
    assert st_["pipeline_overlap_fraction"] == 0.0
    assert not _no_pipe_threads()


def test_pipelined_fit_exposes_overlap_via_engine_stats(
        fresh_caches, gcn_setup):
    """A pipelined run reports its overlap accounting both on the
    report and through ``engine.stats()`` (the surface the bench
    records): fraction in [0, 1], prepare time > 0, queue occupancy
    within the depth bound."""
    _, _, _, rep, eng = _fit(gcn_setup, fresh_caches, depth=2, workers=2)
    assert rep.pipeline_prepare_s > 0.0
    assert 0.0 <= rep.pipeline_overlap_fraction <= 1.0
    assert 0.0 <= rep.pipeline_queue_occupancy <= 2.0
    st_ = eng.stats()
    assert st_["pipeline_depth"] == 2
    assert st_["pipeline_overlap_fraction"] == \
        rep.pipeline_overlap_fraction
    assert st_["pipeline_queue_occupancy"] == rep.pipeline_queue_occupancy


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------


class _BoomError(RuntimeError):
    pass


def test_worker_failure_surfaces_and_drains(
        fresh_caches, gcn_setup, monkeypatch):
    """A sampler raising on a builder thread mid-epoch re-raises on the
    training thread (in batch order, so within one step of the failed
    index), the pool drains — zero orphan ``gcn-pipe`` threads — and
    the same trainer trains fine once the fault is removed."""
    from repro.core import sampling

    before = set(threading.enumerate())
    tr, eng, feats, _, _ = _trainer(gcn_setup)
    real_sample = sampling.NeighborSampler.sample
    calls = {"n": 0}
    calls_lock = threading.Lock()

    def failing_sample(self, seeds, **kw):
        with calls_lock:
            calls["n"] += 1
            n = calls["n"]
        if n == 3:  # mid-epoch: batches 1-2 built fine
            raise _BoomError("injected sampler fault")
        return real_sample(self, seeds, **kw)

    monkeypatch.setattr(sampling.NeighborSampler, "sample", failing_sample)
    with pytest.raises(_BoomError, match="injected sampler fault"):
        tr.fit_sampled(feats, epochs=2, batch_size=64, fanouts=(4, 4),
                       pipeline_depth=2, pipeline_workers=3)
    assert not _no_pipe_threads(), "worker pool must drain on failure"
    delta = set(threading.enumerate()) - before
    assert not [t for t in delta if t.name.startswith("gcn-pipe")], \
        "no pipeline thread may leak (delta pinned)"

    # the fault was transient state, not corruption: same trainer runs
    monkeypatch.setattr(sampling.NeighborSampler, "sample", real_sample)
    rep = tr.fit_sampled(feats, epochs=2, batch_size=64, fanouts=(4, 4),
                         pipeline_depth=2)
    assert len(rep.history) == 2
    assert not _no_pipe_threads()


def test_failure_in_first_batch_drains_too(
        fresh_caches, gcn_setup, monkeypatch):
    """Edge case: the very first prepared batch fails — get(0) is the
    re-raise site and nothing was ever consumed."""
    from repro.core import sampling

    tr, _, feats, _, _ = _trainer(gcn_setup)

    def always_fail(self, seeds, **kw):
        raise _BoomError("first batch fault")

    monkeypatch.setattr(sampling.NeighborSampler, "sample", always_fail)
    with pytest.raises(_BoomError):
        tr.fit_sampled(feats, epochs=1, batch_size=64, fanouts=(4, 4),
                       pipeline_depth=4, pipeline_workers=3)
    assert not _no_pipe_threads()


def test_eviction_during_background_builds_is_benign(
        fresh_caches, gcn_setup, monkeypatch):
    """Budget shrinks (batch AND feature layers) fired from a builder
    thread mid-run: no deadlock (the stores' lock is reentrant and every
    mutator self-locks), and the trajectory stays bit-identical to the
    unbounded serial reference — eviction changes cost, never values."""
    from repro.core import sampling
    from repro.gcn import cache as gcache

    ref_losses, ref_leaves, ref_fps = _fit(
        gcn_setup, fresh_caches, depth=0, epochs=3)[:3]

    real_sample = sampling.NeighborSampler.sample
    calls = {"n": 0}
    calls_lock = threading.Lock()

    def shrinking_sample(self, seeds, **kw):
        # sample() only runs on sampler-memo misses — 4 distinct
        # batches total — so fire the shrink on the 3rd: builders for
        # batches 3-4 are in flight while batches 1-2 sit committed
        with calls_lock:
            calls["n"] += 1
            n = calls["n"]
        if n == 3:
            gcache.set_cache_budget(batch_bytes=1 << 12,
                                    feature_bytes=1 << 12)
        return real_sample(self, seeds, **kw)

    monkeypatch.setattr(sampling.NeighborSampler, "sample",
                        shrinking_sample)
    fresh_caches.clear_all()
    import jax

    tr, _, feats, _, _ = _trainer(gcn_setup)
    rep = tr.fit_sampled(feats, epochs=3, batch_size=64, fanouts=(4, 4),
                         pipeline_depth=2, pipeline_workers=3)
    assert [h["loss"] for h in rep.history] == ref_losses
    assert rep.batch_fingerprints == ref_fps
    for a, b in zip(jax.tree.leaves(rep.params), ref_leaves):
        np.testing.assert_array_equal(np.asarray(a), b)
    # the shrink actually bit: the batch layer evicted under pressure
    st_ = fresh_caches.cache_stats()["batch"]
    assert st_["evictions"] > 0
    assert not _no_pipe_threads()


# ---------------------------------------------------------------------------
# SamplePipeline unit properties
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(n=st.integers(1, 24), depth=st.integers(1, 5),
       workers=st.integers(1, 4))
def test_pipeline_orders_results_under_random_delays(n, depth, workers):
    """Workers finishing out of order never reorder consumption, and
    the look-ahead bound holds at every claim."""
    from repro.gcn.pipeline import SamplePipeline

    rng = np.random.default_rng(n * 100 + depth * 10 + workers)
    delays = rng.uniform(0, 0.003, size=n)
    state = {"pipe": None, "max_ahead": 0}
    lock = threading.Lock()

    def prepare(i):
        while state["pipe"] is None:  # workers may beat the assignment
            time.sleep(1e-4)
        pipe = state["pipe"]
        with lock:
            ahead = pipe._next_claim - pipe._next_consume
            state["max_ahead"] = max(state["max_ahead"], ahead)
        time.sleep(delays[i])
        return i * i

    pipe = SamplePipeline(list(range(n)), prepare, depth=depth,
                          workers=workers)
    state["pipe"] = pipe
    try:
        got = [pipe.get(i) for i in range(n)]
    finally:
        pipe.close()
    assert got == [i * i for i in range(n)]
    assert state["max_ahead"] <= depth
    s = pipe.stats()
    assert s["prepared"] == n and s["tasks"] == n
    assert 0.0 <= s["overlap_fraction"] <= 1.0
    assert s["queue_occupancy_mean"] <= depth
    assert not _no_pipe_threads()


def test_pipeline_get_contract_and_close_idempotence():
    from repro.gcn.pipeline import SamplePipeline

    pipe = SamplePipeline([10, 20, 30], lambda t: t + 1, depth=2,
                          workers=2)
    assert pipe.get(0) == 11
    with pytest.raises(ValueError, match="out-of-order"):
        pipe.get(2)  # index 1 is next
    pipe.close()
    pipe.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        pipe.get(1)
    assert not _no_pipe_threads()


def test_pipeline_get_returns_committed_result_despite_racing_close():
    """The close-vs-get race regression (PR 10): a result already
    COMMITTED to the reorder buffer must be delivered even when
    ``close()`` lands between the consumer entering ``get`` and
    popping the slot — the old implementation checked the closed flag
    before the buffer and raised, silently dropping a prepared batch.
    The ``_drain_barrier`` hook holds ``close()`` at its widest race
    window (closed flag set + waiters notified, buffer still intact)
    so the interleaving is deterministic, not timing-dependent."""
    from repro.gcn.pipeline import SamplePipeline

    pipe = SamplePipeline([10, 20], lambda t: t + 1, depth=2, workers=1)
    # wait until the worker has committed result 0
    deadline = time.time() + 5.0
    while 0 not in pipe._ready:
        assert time.time() < deadline, "worker never committed task 0"
        time.sleep(0.001)

    barrier = threading.Barrier(2)
    pipe._drain_barrier = barrier.wait
    closer = threading.Thread(target=pipe.close)
    closer.start()
    # close() has set the flag and notified; it is now parked at the
    # barrier with the buffer untouched
    while not pipe._closed:
        time.sleep(0.001)

    assert pipe.get(0) == 11  # committed result survives the close
    barrier.wait()  # release close(): it joins workers, drops buffer
    closer.join(timeout=5.0)
    assert not closer.is_alive()
    # after close completes, further gets fail loudly as before
    with pytest.raises(RuntimeError, match="closed"):
        pipe.get(1)
    assert not _no_pipe_threads()


def test_pipeline_worker_error_reraises_and_drains():
    from repro.gcn.pipeline import SamplePipeline

    def prepare(i):
        if i == 2:
            raise _BoomError("task 2 broke")
        return i

    pipe = SamplePipeline(list(range(6)), prepare, depth=3, workers=2)
    try:
        assert pipe.get(0) == 0 and pipe.get(1) == 1
        with pytest.raises(_BoomError, match="task 2 broke"):
            pipe.get(2)
    finally:
        pipe.close()
    assert not _no_pipe_threads()


def test_pipeline_close_midstream_leaves_no_threads():
    """Abandoning a half-consumed pipeline (the trainer's finally path
    on any consumer-side error) joins every worker, even ones blocked
    waiting for a claim slot."""
    from repro.gcn.pipeline import SamplePipeline

    pipe = SamplePipeline(list(range(50)),
                          lambda i: (time.sleep(0.001), i)[1],
                          depth=2, workers=3)
    assert pipe.get(0) == 0
    pipe.close()
    assert not _no_pipe_threads()
    # the reorder buffer was drained with the pool
    assert not pipe._ready
