"""The feature store (``repro.gcn.featurestore``): the storage tier's
correctness pins.

  * gather parity — rows served through the store (any mix of pinned /
    cold-resident / host tiers) are bit-identical to the dense slice;
  * forward / forward_batched / full ``fit_sampled`` trajectories are
    bit-exact whether features arrive as a dense array or a store
    handle, on BOTH aggregation backends — and independent of the byte
    budget (a zero-budget store serves everything from host, same
    bits);
  * the device byte budget is never exceeded under random access
    sequences and random budgets (property test via the hypothesis
    shim), including across budget shrinks;
  * degree-ordered admission: the pinned blocks are exactly the top-k
    in-degree-mass blocks (a rank prefix);
  * cross-graph isolation: registrations are keyed by graph
    fingerprint — same-shaped graphs never serve each other's rows,
    and releasing one graph's device blocks leaves the other warm;
  * the sampled-training regression pin: ``fit_sampled`` through the
    store never materializes a full ``(V, F)`` gather
    (``full_gathers == 0``) and reads strictly less than the dense
    baseline per batch.

Runs in-process on the 1-CPU view (mesh ``(1, 1)``).
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

V, E, F, C = 256, 2048, 8, 4


def _feats(V=V, F=F, seed=7):
    return (np.random.default_rng(seed)
            .normal(size=(V, F)).astype(np.float32))


# ---------------------------------------------------------------------------
# gather parity across tiers
# ---------------------------------------------------------------------------


def test_gather_is_bit_exact_across_all_tiers(feature_store):
    """Rows assembled from pinned, cold-admitted and host-served blocks
    all equal the dense slice bit-for-bit."""
    store, g, feats, handle = feature_store(budget=64 << 20,
                                            block_vertices=32)
    rng = np.random.default_rng(0)
    for _ in range(5):
        nodes = rng.integers(0, V, size=rng.integers(1, 200))
        np.testing.assert_array_equal(handle.gather(nodes), feats[nodes])
    np.testing.assert_array_equal(handle.gather_all(), feats)

    # starve the device tiers entirely: everything comes from host,
    # bits unchanged
    store.set_budget(0)
    assert store.device_bytes == 0
    nodes = rng.integers(0, V, size=300)
    np.testing.assert_array_equal(handle.gather(nodes), feats[nodes])
    assert store.device_bytes == 0  # nothing admitted under budget 0


def test_gather_validates_inputs(feature_store):
    store, g, feats, handle = feature_store()
    with pytest.raises(ValueError):
        handle.gather([V])  # out of range
    with pytest.raises(ValueError):
        handle.gather([-1])
    with pytest.raises(KeyError):
        store.gather("not-a-registered-fp", [0])
    assert handle.gather([]).shape == (0, F)


def test_reregistering_identical_content_keeps_warm_tiers(feature_store):
    """Same bytes, same blocking -> no-op (pins survive); changed
    content drops the stale device blocks and replaces the store."""
    store, g, feats, handle = feature_store(block_vertices=32)
    pinned_before = handle.stats()["pinned"]
    assert pinned_before > 0
    h2 = store.register(g, feats.copy(), block_vertices=32)
    assert h2.stats()["pinned"] == pinned_before  # no re-pin churn

    changed = feats + 1.0
    h3 = store.register(g, changed, block_vertices=32)
    nodes = np.arange(0, V, 3)
    np.testing.assert_array_equal(h3.gather(nodes), changed[nodes])


# ---------------------------------------------------------------------------
# consumer parity: forward / forward_batched / fit_sampled, both backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_forward_parity_dense_vs_handle_both_backends(
        fresh_caches, gcn_setup, impl):
    """``forward``/``forward_batched`` fed a store handle produce the
    same bits as the dense array, on both aggregation backends."""
    from repro.gcn import default_store

    eng, feats, labels, mask = gcn_setup()
    handle = default_store().register(eng.graph, feats,
                                      graph_fp=eng.graph_fp)
    y_dense = np.asarray(eng.forward(feats, agg_impl=impl))
    y_handle = np.asarray(eng.forward(handle, agg_impl=impl))
    np.testing.assert_array_equal(y_dense, y_handle)

    yb = np.asarray(eng.forward_batched(handle, agg_impl=impl))
    assert yb.shape[0] == 1  # a handle is one request
    np.testing.assert_array_equal(yb[0], y_dense)


def test_forward_rejects_mismatched_handle(fresh_caches, gcn_setup,
                                           erdos_graph):
    """A handle registered for a DIFFERENT graph is refused, not
    silently gathered."""
    from repro.gcn import cache, default_store

    eng, feats, labels, mask = gcn_setup()
    other = erdos_graph(V, E, seed=99)
    h = default_store().register(other, _feats(seed=99),
                                 graph_fp=cache.graph_fingerprint(other))
    with pytest.raises(ValueError):
        eng.forward(h)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_fit_sampled_trajectory_invariant_to_budget(
        fresh_caches, gcn_setup, impl):
    """The WHOLE sampled-training trajectory (per-epoch losses and
    final params) is bit-identical under a generous budget (everything
    pinned) and a zero budget (every row from host) — the store is a
    cache, never a semantic. Both aggregation backends."""
    import jax

    from repro.gcn import GCNTrainer, cache

    reports = []
    for budget in (64 << 20, 0):
        fresh_caches.clear_all()
        cache.set_cache_budget(feature_bytes=budget)
        eng, feats, labels, mask = gcn_setup(agg_impl=impl)
        tr = GCNTrainer(eng, labels, mask)
        reports.append(tr.fit_sampled(feats, epochs=3, batch_size=64,
                                      fanouts=(4, 4)))
    ra, rb = reports
    assert [h["loss"] for h in ra.history] == \
        [h["loss"] for h in rb.history]
    for a, b in zip(jax.tree.leaves(ra.params),
                    jax.tree.leaves(rb.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # generous budget serves device-resident; zero budget cannot
    assert ra.feature_hit_rate > 0.9
    assert rb.feature_hit_rate == 0.0
    assert rb.feature_bytes_gathered > 0


# ---------------------------------------------------------------------------
# the sampled-training regression pin (the dense-slice miss)
# ---------------------------------------------------------------------------


def test_fit_sampled_never_gathers_full_graph(fresh_caches, gcn_setup):
    """The regression this PR fixes: ``_batch_inputs`` used to slice a
    dense (V, F) host array per batch. Through the store, sampled
    training must never materialize a full-graph gather
    (``full_gathers == 0``) and each batch reads only its sampled
    rows — strictly less than V per batch."""
    from repro.gcn import GCNTrainer, default_store

    eng, feats, labels, mask = gcn_setup()
    tr = GCNTrainer(eng, labels, mask)
    rep = tr.fit_sampled(feats, epochs=2, batch_size=64, fanouts=(4, 4))

    h = default_store().handle_for(eng.graph_fp)
    assert h is not None  # dense input was routed through the store
    s = h.stats()
    assert s["full_gathers"] == 0
    # row-honest: every batch touched < V rows, so the dense baseline
    # for the run is strictly below epochs * batches * V rows
    batches = rep.epochs * rep.batches_per_epoch
    assert batches > 0
    assert 0 < s["dense_bytes"] < batches * V * F * 4


def test_sampled_batch_feature_blocks_helper(fresh_caches, gcn_setup):
    """``SampledBatch.feature_blocks`` names exactly the store blocks a
    batch's gather touches."""
    from repro.core.sampling import NeighborSampler

    eng, feats, labels, mask = gcn_setup()
    s = NeighborSampler(eng.graph, (4, 4), seed=0)
    batch = s.sample(np.arange(0, V, 5))
    bv = 32
    blocks = batch.feature_blocks(bv)
    np.testing.assert_array_equal(blocks, np.unique(batch.nodes // bv))
    with pytest.raises(ValueError):
        batch.feature_blocks(0)


# ---------------------------------------------------------------------------
# budget safety (property test)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(budget_blocks=st.integers(0, 12), bv=st.sampled_from([16, 32, 64]),
       seed=st.integers(0, 5))
def test_device_bytes_never_exceed_budget(budget_blocks, bv, seed):
    """Standalone store, random budget (in units of blocks), random
    access sequence: ``device_bytes <= budget_bytes`` after every
    gather, and after a mid-sequence budget shrink."""
    from repro.core.graph import erdos
    from repro.gcn.featurestore import FeatureStore

    g = erdos(V, E, seed=seed)
    block_bytes = bv * F * 4
    budget = budget_blocks * block_bytes
    store = FeatureStore(budget_bytes=budget, block_vertices=bv)
    feats = _feats(seed=seed)
    handle = store.register(g, feats)
    assert store.device_bytes <= budget

    rng = np.random.default_rng(seed)
    for i in range(8):
        nodes = rng.integers(0, V, size=rng.integers(1, 128))
        np.testing.assert_array_equal(handle.gather(nodes), feats[nodes])
        assert store.device_bytes <= budget
    # shrink mid-flight: invariant holds immediately, bits unchanged
    store.set_budget(budget // 2)
    assert store.device_bytes <= budget // 2
    nodes = rng.integers(0, V, size=64)
    np.testing.assert_array_equal(handle.gather(nodes), feats[nodes])
    assert store.device_bytes <= budget // 2


def test_block_larger_than_budget_serves_rows_from_host():
    """A block that can never fit is served row-by-row (touched rows
    only) without being admitted — the invariant survives pathological
    budgets."""
    from repro.core.graph import erdos
    from repro.gcn.featurestore import FeatureStore

    g = erdos(V, E, seed=0)
    store = FeatureStore(budget_bytes=8, block_vertices=64)  # < one row
    feats = _feats()
    handle = store.register(g, feats)
    nodes = np.array([0, 1, 200])
    np.testing.assert_array_equal(handle.gather(nodes), feats[nodes])
    assert store.device_bytes == 0
    s = handle.stats()
    assert s["pinned"] == 0 and s["hits"] == 0
    assert s["gathered_bytes"] == 3 * F * 4  # touched rows, not blocks


# ---------------------------------------------------------------------------
# degree-ordered admission
# ---------------------------------------------------------------------------


def test_admission_pins_topk_in_degree_blocks(erdos_graph):
    """The pinned set is exactly the top-k blocks by total in-degree
    mass (rank prefix 0..k-1), hottest block first to be admitted."""
    from repro.gcn import cache
    from repro.gcn.featurestore import FeatureStore

    g = erdos_graph(V, E, seed=3)
    bv = 32
    block_bytes = bv * F * 4
    k = 3
    # hot_fraction=1.0: the whole budget is pinnable -> exactly k pins
    store = FeatureStore(budget_bytes=k * block_bytes, block_vertices=bv,
                         hot_fraction=1.0)
    handle = store.register(g, _feats(seed=3))
    s = handle.stats()
    assert s["pinned"] == k
    assert s["pinned_ranks"] == list(range(k))  # a rank prefix

    # independently recompute the ranking the store must have used
    mass = np.add.reduceat(g.in_degrees().astype(np.int64),
                           np.arange(0, V, bv))
    expect = set(np.argsort(-mass, kind="stable")[:k].tolist())
    fp = cache.graph_fingerprint(g)
    got = set(store._graphs[fp].pinned.keys())
    assert got == expect

    # telemetry mirrors it process-wide
    layer = store.layer_stats()
    assert layer["pinned_entries"] == k
    assert layer["admission"][fp[:12]]["pinned_ranks"] == list(range(k))


def test_pinned_blocks_absorb_hot_traffic(erdos_graph):
    """Touching only pinned-block vertices is a 100 % device hit with
    zero host bytes gathered — the paper's hub-reuse claim, storage
    edition."""
    from repro.gcn import cache
    from repro.gcn.featurestore import FeatureStore

    g = erdos_graph(V, E, seed=3)
    bv = 32
    store = FeatureStore(budget_bytes=4 * bv * F * 4, block_vertices=bv,
                         hot_fraction=1.0)
    feats = _feats(seed=3)
    handle = store.register(g, feats)
    fp = cache.graph_fingerprint(g)
    pinned = sorted(store._graphs[fp].pinned.keys())
    nodes = np.concatenate([np.arange(b * bv, (b + 1) * bv)
                            for b in pinned])
    np.testing.assert_array_equal(handle.gather(nodes), feats[nodes])
    s = handle.stats()
    assert s["hit_rate"] == 1.0
    assert s["gathered_bytes"] == 0


# ---------------------------------------------------------------------------
# cross-graph isolation
# ---------------------------------------------------------------------------


def test_cross_graph_fingerprint_isolation(feature_store, erdos_graph):
    """Two same-shaped graphs registered in one store: gathers never
    cross, per-graph stats stay separate, and releasing one graph's
    device blocks leaves the other fully warm."""
    from repro.gcn import cache

    store, g1, f1, h1 = feature_store(seed=7)
    g2 = erdos_graph(V, E, seed=8)
    f2 = _feats(seed=8)
    h2 = store.register(g2, f2, block_vertices=h1.block_vertices)
    assert h1.graph_fp != h2.graph_fp

    nodes = np.arange(0, V, 2)
    np.testing.assert_array_equal(h1.gather(nodes), f1[nodes])
    np.testing.assert_array_equal(h2.gather(nodes), f2[nodes])
    assert h1.stats()["hits"] > 0 and h2.stats()["hits"] > 0

    # release graph 1's device blocks: graph 2 keeps its pins, graph 1
    # still serves correct bits (from host, re-warming the cold tier)
    pinned2 = h2.stats()["pinned"]
    store.release_device(h1.graph_fp)
    assert h1.stats()["pinned"] == 0
    assert h2.stats()["pinned"] == pinned2
    np.testing.assert_array_equal(h1.gather(nodes), f1[nodes])

    layer = store.layer_stats()
    assert layer["graphs"] >= 2
    assert cache.cache_stats()["features"]["graphs"] == layer["graphs"]


def test_fresh_caches_clears_host_column_store_between_tests(
        fresh_caches, erdos_graph):
    """Regression pin for the test-suite hygiene contract: the
    ``fresh_caches`` teardown clears the default store's HOST column
    store, so a later test registering DIFFERENT features under the
    same graph fingerprint can never be served the earlier test's rows
    (or inherit its warm pins / counters)."""
    from repro.gcn import cache, featurestore

    store = featurestore.default_store()
    g = erdos_graph(V, E, seed=7)

    # "test 1": register features A and warm the tiers
    fa = _feats(seed=1)
    ha = store.register(g, fa)
    ha.gather(np.arange(64))
    assert ha.stats()["hit_rows"] + ha.stats()["miss_rows"] > 0

    # simulate the fixture boundary (exactly what fresh_caches runs)
    cache.clear_all()
    store.clear()
    assert store.handle_for(ha.graph_fp) is None, \
        "no registration may survive the fixture boundary"
    with pytest.raises(KeyError):
        store.gather(ha.graph_fp, [0])  # stale handles go stale loudly

    # "test 2": same graph fingerprint, different features — must see
    # ONLY its own rows, with counters starting from zero
    fb = _feats(seed=2)
    assert not np.array_equal(fa, fb)
    hb = store.register(g, fb)
    np.testing.assert_array_equal(hb.gather(np.arange(V)), fb)
    s = hb.stats()
    assert s["dense_bytes"] == V * F * 4  # exactly this test's accesses


# ---------------------------------------------------------------------------
# budget-math corners: unset (None) and zero budgets (PR 10 bugfix sweep)
# ---------------------------------------------------------------------------


def test_unset_budget_is_unlimited_and_survives_register(erdos_graph):
    """``budget_bytes=None`` means *unlimited*, not *zero*: every block
    the access pattern touches is admitted, the hot-fraction math never
    multiplies through ``None``, and ``set_budget(None)`` after a
    finite budget restores unlimited admission."""
    from repro.gcn.featurestore import FeatureStore

    g = erdos_graph(V, E, seed=3)
    store = FeatureStore(budget_bytes=None, block_vertices=32)
    handle = store.register(g, _feats(seed=3))
    np.testing.assert_array_equal(handle.gather(np.arange(V)),
                                  _feats(seed=3))
    assert store.budget_bytes is None
    assert store.device_bytes > 0  # blocks were admitted, unbounded

    # finite -> None round-trip keeps serving identical bits
    store.set_budget(0)
    assert store.device_bytes == 0
    store.set_budget(None)
    nodes = np.arange(0, V, 3)
    np.testing.assert_array_equal(handle.gather(nodes),
                                  _feats(seed=3)[nodes])
    assert store.device_bytes > 0


def test_zero_budget_store_is_host_only_but_bit_exact(erdos_graph):
    """``budget_bytes=0`` is a degenerate but LEGAL configuration: no
    block is ever admitted (``device_bytes == 0`` throughout, no pins),
    yet every gather is bit-exact from the host tier."""
    from repro.gcn.featurestore import FeatureStore

    g = erdos_graph(V, E, seed=4)
    feats = _feats(seed=4)
    store = FeatureStore(budget_bytes=0, block_vertices=32)
    handle = store.register(g, feats)
    rng = np.random.default_rng(0)
    for _ in range(4):
        nodes = rng.integers(0, V, size=rng.integers(1, 96))
        np.testing.assert_array_equal(handle.gather(nodes), feats[nodes])
        assert store.device_bytes == 0
    assert handle.stats()["pinned"] == 0
    assert handle.stats()["hits"] == 0  # nothing resident to hit


def test_budget_validation_rejects_garbage():
    """Negative budgets (constructor AND ``set_budget``), non-positive
    block sizes and out-of-range hot fractions fail loudly instead of
    corrupting the admission math downstream."""
    from repro.gcn.featurestore import FeatureStore

    with pytest.raises(ValueError, match="budget_bytes"):
        FeatureStore(budget_bytes=-1)
    with pytest.raises(ValueError, match="block_vertices"):
        FeatureStore(block_vertices=0)
    with pytest.raises(ValueError, match="hot_fraction"):
        FeatureStore(hot_fraction=1.5)
    store = FeatureStore(budget_bytes=1 << 20)
    with pytest.raises(ValueError, match="budget_bytes"):
        store.set_budget(-7)
    # the failed set_budget must not have clobbered the old budget
    assert store.budget_bytes == 1 << 20
