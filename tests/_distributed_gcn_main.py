"""Subprocess body for the 8-device distributed-GCN equivalence test.
Run by tests/test_distributed_gcn.py with XLA_FLAGS forcing 8 devices."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_gcn_config
from repro.core import gcn_models as gm
from repro.core.graph import erdos
from repro.core.message_passing import shard_features, unshard_features
from repro.core.partition import TorusMesh


def main():
    mesh_jax = jax.make_mesh((4, 2), ("x", "y"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    tor = TorusMesh((4, 2))
    V, E, F = 512, 4096, 16
    g = erdos(V, E, seed=5)
    feats = np.random.default_rng(0).normal(size=(V, F)).astype(np.float32)

    combos = [("gcn", "oppm", True), ("gcn", "oppm", False),
              ("gcn", "oppe", True), ("gcn", "oppr", False),
              ("gin", "oppm", True), ("sage", "oppm", True)]
    for model, mpm, rounds in combos:
        cfg = get_gcn_config(f"gcn-{model}-rd", "smoke")
        cfg = dataclasses.replace(cfg, message_passing=mpm,
                                  use_rounds=rounds, agg_buffer_bytes=4 << 10)
        plan = gm.build_gcn_plan(cfg, g, tor)
        params = gm.gcn_params(cfg, jax.random.PRNGKey(0), [F, 8])
        fs = jnp.asarray(shard_features(plan, feats))
        out = gm.distributed_forward(cfg, params, plan, mesh_jax,
                                     ("x", "y"), fs)
        out_g = unshard_features(plan, np.asarray(out), V)
        ref = np.asarray(gm.reference_forward(cfg, params, g,
                                              jnp.asarray(feats)))
        err = np.max(np.abs(out_g - ref)) / (np.max(np.abs(ref)) + 1e-9)
        assert err < 1e-4, (model, mpm, rounds, err)
        print(f"ok {model}/{mpm}/rounds={rounds} err={err:.2e}")

    # bidirectional rings (§Perf cell 3): numerics must be unchanged
    from repro.core.partition import make_partition
    from repro.core.plan import build_plan

    cfgb = get_gcn_config("gcn-gcn-rd", "smoke")
    cfgb = dataclasses.replace(cfgb, agg_buffer_bytes=4 << 10)
    g2, w = gm.model_graph_and_weights(cfgb, g)
    partb = make_partition(cfgb, 8, num_vertices=g.num_vertices)
    planb = build_plan(cfgb, g2, tor, partb, edge_weights=w, bidir=True)
    params = gm.gcn_params(cfgb, jax.random.PRNGKey(0), [F, 8])
    fs = jnp.asarray(shard_features(planb, feats))
    out = gm.distributed_forward(cfgb, params, planb, mesh_jax, ("x", "y"), fs)
    out_g = unshard_features(planb, np.asarray(out), V)
    ref = np.asarray(gm.reference_forward(cfgb, params, g, jnp.asarray(feats)))
    err = np.max(np.abs(out_g - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert err < 1e-4, ("bidir", err)
    print(f"ok bidir err={err:.2e}")

    # 3D torus (pod-like) on 8 devices: (2, 2, 2)
    mesh3 = jax.make_mesh((2, 2, 2), ("p", "x", "y"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 3)
    tor3 = TorusMesh((2, 2, 2))
    cfg = get_gcn_config("gcn-gcn-rd", "smoke")
    cfg = dataclasses.replace(cfg, agg_buffer_bytes=4 << 10)
    plan = gm.build_gcn_plan(cfg, g, tor3)
    params = gm.gcn_params(cfg, jax.random.PRNGKey(0), [F, 8])
    fs = jnp.asarray(shard_features(plan, feats))
    out = gm.distributed_forward(cfg, params, plan, mesh3, ("p", "x", "y"), fs)
    out_g = unshard_features(plan, np.asarray(out), V)
    ref = np.asarray(gm.reference_forward(cfg, params, g, jnp.asarray(feats)))
    err = np.max(np.abs(out_g - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert err < 1e-4, ("3d", err)
    print(f"ok 3d-torus err={err:.2e}")


if __name__ == "__main__":
    main()
    print("ALL_OK")
