"""Subprocess body for the 8-device distributed-GCN equivalence test.
Run by tests/test_distributed_gcn.py with XLA_FLAGS forcing 8 devices.

All GCN execution flows through the ``GCNEngine`` session API."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.config import get_gcn_config
from repro.core.graph import erdos
from repro.gcn import GCNEngine


def main():
    V, E, F = 512, 4096, 16
    g = erdos(V, E, seed=5)
    feats = np.random.default_rng(0).normal(size=(V, F)).astype(np.float32)

    combos = [("gcn", "oppm", True), ("gcn", "oppm", False),
              ("gcn", "oppe", True), ("gcn", "oppr", False),
              ("gin", "oppm", True), ("sage", "oppm", True)]
    for model, mpm, rounds in combos:
        cfg = get_gcn_config(f"gcn-{model}-rd", "smoke")
        cfg = dataclasses.replace(cfg, message_passing=mpm,
                                  use_rounds=rounds, agg_buffer_bytes=4 << 10)
        eng = GCNEngine.build(cfg, g, (4, 2))
        eng.init_params(jax.random.PRNGKey(0), [F, 8])
        out = eng.forward(feats)
        ref = eng.reference(feats)
        err = np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9)
        assert err < 1e-4, (model, mpm, rounds, err)
        print(f"ok {model}/{mpm}/rounds={rounds} err={err:.2e}")

    # bidirectional rings (§Perf cell 3): numerics must be unchanged
    cfgb = get_gcn_config("gcn-gcn-rd", "smoke")
    cfgb = dataclasses.replace(cfgb, agg_buffer_bytes=4 << 10)
    engb = GCNEngine.build(cfgb, g, (4, 2), bidir=True)
    engb.init_params(jax.random.PRNGKey(0), [F, 8])
    out = engb.forward(feats)
    ref = engb.reference(feats)
    err = np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert err < 1e-4, ("bidir", err)
    print(f"ok bidir err={err:.2e}")

    # 3D torus (pod-like) on 8 devices: (2, 2, 2)
    cfg = get_gcn_config("gcn-gcn-rd", "smoke")
    cfg = dataclasses.replace(cfg, agg_buffer_bytes=4 << 10)
    eng3 = GCNEngine.build(cfg, g, (2, 2, 2))
    eng3.init_params(jax.random.PRNGKey(0), [F, 8])
    out = eng3.forward(feats)
    ref = eng3.reference(feats)
    err = np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert err < 1e-4, ("3d", err)
    print(f"ok 3d-torus err={err:.2e}")


if __name__ == "__main__":
    main()
    print("ALL_OK")
