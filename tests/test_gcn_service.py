"""The multi-graph serving layer (``repro.gcn.service.GCNService``):
cross-graph parity against each session's single-device oracle, per-step
batching of compatible requests, async-vs-sync upload equivalence
(bit-identical), and byte-budget eviction driven through the service
(evicted graph re-admitted -> replans exactly once).

Runs in-process on the 1-CPU view (mesh ``(1, 1)``); the multi-device
serving path is exercised by ``benchmarks/run.py --suite serve`` /
``make check`` on 8 forced host devices. Config/cache fixtures come
from the shared conftest (``gcn_cfg``, ``fresh_caches``)."""
import numpy as np
import pytest


@pytest.fixture
def mixed_service(gcn_cfg):
    """Factory: three sessions with distinct RMAT sizes AND models on
    one mesh."""
    from repro.core.rmat import rmat
    from repro.gcn import GCNService

    def make(*, async_upload=True, max_batch=4, seed0=30):
        svc = GCNService((1, 1), max_batch=max_batch,
                         async_upload=async_upload)
        graphs = {}
        for i, (model, scale) in enumerate(
                [("gcn", 8), ("gin", 9), ("sage", 8)]):
            name = f"{model}{scale}"
            g = rmat(scale, 1 << (scale + 2), seed=seed0 + i, name=name)
            svc.admit(name, gcn_cfg(model), g, layer_dims=[8, 8, 4],
                      seed=i)
            graphs[name] = g
        return svc, graphs

    return make


def _submit_mixed(svc, graphs, n, seed=5):
    rng = np.random.default_rng(seed)
    names = list(graphs)
    return [svc.submit(names[k % len(names)],
                       rng.normal(size=(graphs[names[k % len(names)]]
                                        .num_vertices, 8))
                       .astype(np.float32))
            for k in range(n)]


def test_service_multigraph_parity(fresh_caches, mixed_service):
    """Every served request matches its own session's
    ``engine.reference()`` oracle — across >= 3 graphs with different
    sizes and message-passing models sharing one cache."""
    svc, graphs = mixed_service()
    reqs = _submit_mixed(svc, graphs, 9)
    done = svc.run()
    assert len(done) == 9 and all(r.done for r in reqs)
    for r in reqs:
        eng = svc.sessions[r.session]
        ref = eng.reference(r.feats)
        err = np.max(np.abs(r.out - ref)) / (np.max(np.abs(ref)) + 1e-9)
        assert err < 1e-4, (r.session, err)
    st = svc.stats()
    assert st["sessions"] == 3 and st["requests"] == 9
    # one shared plan store served all three graphs
    assert st["cache"]["plan"]["entries"] == 3


def test_service_batches_compatible_requests(fresh_caches, mixed_service):
    """Head-of-line batching groups same-session same-shape requests up
    to ``max_batch``; incompatible requests stay queued in order."""
    svc, graphs = mixed_service(max_batch=4)
    name = next(iter(graphs))
    other = list(graphs)[1]
    rng = np.random.default_rng(1)

    def feats_for(n):
        return rng.normal(size=(graphs[n].num_vertices, 8)) \
                  .astype(np.float32)

    for _ in range(3):
        svc.submit(name, feats_for(name))
    svc.submit(other, feats_for(other))
    svc.submit(name, feats_for(name))
    first = svc.step()
    # 4 compatible requests batched through one executor call...
    assert [r.session for r in first] == [name] * 4
    # ...and the incompatible one is served next, order preserved
    second = svc.step()
    assert [r.session for r in second] == [other]
    assert svc.stats()["mean_batch"] == pytest.approx(2.5)


def test_async_upload_bit_identical_to_sync(fresh_caches, mixed_service):
    """The double-buffered background upload changes WHEN plan arrays
    reach the device, never what executes: outputs are bit-identical to
    the synchronous fallback."""
    svc_a, graphs_a = mixed_service(async_upload=True)
    reqs_a = _submit_mixed(svc_a, graphs_a, 9, seed=11)
    svc_a.run()
    st = svc_a.stats()
    assert st["uploads_async"] > 0, "async path must actually prefetch"

    fresh_caches.clear_all()  # force the sync run to re-upload too
    svc_s, graphs_s = mixed_service(async_upload=False)
    reqs_s = _submit_mixed(svc_s, graphs_s, 9, seed=11)
    svc_s.run()
    assert svc_s.stats()["uploads_async"] == 0
    for ra, rs in zip(reqs_a, reqs_s):
        assert ra.session == rs.session
        np.testing.assert_array_equal(ra.out, rs.out)


def test_service_eviction_and_readmit_replans_once(fresh_caches, mixed_service, gcn_cfg):
    """Serving under a byte budget that holds two plans: graph A is
    evicted after B and C are served — and A's LIVE session is released
    with it (``set_cache_budget`` bounds the process, not just the
    shared store). Serving A again replans exactly once, then hits; the
    budget keeps holding two plans throughout."""
    cache = fresh_caches
    svc, graphs = mixed_service()
    names = list(graphs)
    a, b, c = names
    rng = np.random.default_rng(2)

    def serve_one(n, feats=None):
        if feats is None:
            feats = rng.normal(size=(graphs[n].num_vertices, 8)) \
                       .astype(np.float32)
        svc.submit(n, feats)
        (req,) = svc.run()
        return req

    serve_one(a)
    pa = cache.cache_stats()["plan"]["bytes"]
    serve_one(b)
    serve_one(c)
    total = cache.cache_stats()["plan"]["bytes"]
    # room for exactly B+C (one byte short of also holding A): applying
    # the budget evicts the least-recently-served plan — A — and only A
    cache.set_cache_budget(plan_bytes=total - 1)
    st = cache.cache_stats()["plan"]
    assert st["entries"] == 2 and st["evictions"] == 1
    assert cache.cache_stats()["plan"]["bytes"] == total - pa
    assert not svc.sessions[a].plan_cached, "A must have been evicted"
    assert svc.sessions[b].plan_cached and svc.sessions[c].plan_cached
    # the release hook did its job: A's live session pins nothing —
    # neither the plan object nor its uploaded device arrays
    assert svc.sessions[a]._plan is None
    assert not svc.sessions[a].plan_uploaded()

    # serving A through the SAME session transparently replans exactly
    # once (one miss; the rebuild evicts the now-LRU plan), then hits
    feats_a = rng.normal(size=(graphs[a].num_vertices, 8)) \
                 .astype(np.float32)
    misses0 = cache.cache_stats()["plan"]["misses"]
    req1 = serve_one(a, feats_a)
    assert cache.cache_stats()["plan"]["misses"] == misses0 + 1
    req2 = serve_one(a, feats_a)
    assert cache.cache_stats()["plan"]["misses"] == misses0 + 1, \
        "second serve must be a pure cache hit"
    np.testing.assert_array_equal(req1.out, req2.out)
    assert cache.cache_stats()["plan"]["entries"] == 2, \
        "the budget must keep binding after the rebuild"

    # re-admitting A as a FRESH session is now also a pure hit (the
    # old session's rebuild refilled the shared store)
    svc.evict(a)
    svc.admit(a, gcn_cfg("gcn"), graphs[a], layer_dims=[8, 8, 4], seed=0)
    req3 = serve_one(a, feats_a)
    assert cache.cache_stats()["plan"]["misses"] == misses0 + 1
    # same seed, same graph, same plan -> the same served function
    np.testing.assert_allclose(req3.out, req1.out, rtol=1e-5, atol=1e-5)


def test_evict_during_inflight_prefetch_is_harmless(fresh_caches, mixed_service):
    """Evicting the session a background prefetch is uploading must not
    poison later steps: the thread holds the engine object (not a name
    lookup), and a failed upload for a no-longer-admitted session is
    dropped at the fence instead of re-raised."""
    svc, graphs = mixed_service(async_upload=True, max_batch=2)
    names = list(graphs)
    rng = np.random.default_rng(4)
    for k in range(6):
        n = names[k % 3]
        svc.submit(n, rng.normal(size=(graphs[n].num_vertices, 8))
                   .astype(np.float32))
    svc.step()  # serves names[0]; prefetch targets names[1]
    svc.evict(names[1])  # mid-flight
    done = svc.run()  # must not raise
    assert all(r.session != names[1] for r in done)
    assert all(r.done for r in done)


def test_execution_error_requeues_batch(fresh_caches, mixed_service):
    """A batch that fails during execution (e.g. feature width not
    matching the session's params) goes back to the head of the queue —
    requests stay observable/retryable instead of vanishing."""
    svc, graphs = mixed_service()
    name = next(iter(graphs))
    bad = np.zeros((graphs[name].num_vertices, 5), np.float32)  # F=5 != 8
    req = svc.submit(name, bad)
    with pytest.raises(Exception):
        svc.step()
    assert svc.queue and svc.queue[0] is req and not req.done


def test_service_rejects_bad_requests(fresh_caches, mixed_service, gcn_cfg):
    svc, graphs = mixed_service()
    name = next(iter(graphs))
    with pytest.raises(KeyError):
        svc.submit("never-admitted", np.zeros((4, 8), np.float32))
    with pytest.raises(ValueError):
        svc.submit(name, np.zeros((7, 8), np.float32))  # wrong |V|
    with pytest.raises(ValueError):
        svc.admit(name, gcn_cfg(), graphs[name])  # duplicate name
