"""Per-arch smoke tests (required): reduced same-family config, one
forward/train step on CPU, assert output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import get_lm_config, list_lm_archs
from repro.models import lm
from repro.train import optimizer as optlib


def _batch(cfg, B=2, S=16, key=None):
    key = key or jax.random.PRNGKey(0)
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        b["frames"] = 0.01 * jnp.ones((B, cfg.frontend_seq_len, cfg.d_model),
                                      jnp.bfloat16)
    if cfg.frontend == "patch_stub":
        b["patches"] = 0.01 * jnp.ones((B, cfg.frontend_seq_len, cfg.d_model),
                                       jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", list_lm_archs())
def test_forward_shapes_and_finite(arch):
    cfg = get_lm_config(arch, "smoke")
    params = lm.lm_init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    b = _batch(cfg, B, S)
    hidden, _, aux = lm.forward_hidden(
        cfg, params, b["tokens"], memory=None if not cfg.is_encdec else
        lm.encode(cfg, params, b["frames"], remat=False),
        extra_embeds=b.get("patches"), remat=False)
    S_total = S + (cfg.frontend_seq_len if cfg.frontend == "patch_stub" else 0)
    assert hidden.shape == (B, S_total, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))


@pytest.mark.parametrize("arch", list_lm_archs())
def test_train_step_finite(arch):
    cfg = get_lm_config(arch, "smoke")
    params = lm.lm_init(cfg, jax.random.PRNGKey(1))
    opt_state = optlib.init(params)
    b = _batch(cfg)

    from repro.launch.steps import make_train_step

    step = jax.jit(make_train_step(cfg, None))
    params2, opt2, metrics = step(params, opt_state, b)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32)))) > 0
        for a, b_ in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved
