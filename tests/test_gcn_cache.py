"""The process-wide cache layer (``repro.gcn.cache``): byte-bounded LRU
eviction with coherent cascades, compiled-step sharing across sessions,
and the one-call clearing contract (clear/invalidate sweep plans, ELL
layouts, prepared graphs AND compiled steps together).

Runs in-process on the 1-CPU view (mesh ``(1, 1)``). Config/graph/
cache fixtures come from the shared conftest (``gcn_cfg``,
``erdos_graph``, ``fresh_caches`` — which saves/restores ALL budgets).
"""
import dataclasses

import numpy as np
import pytest


@pytest.fixture
def _engine(gcn_cfg):
    from repro.gcn import GCNEngine

    def make(graph, **over):
        return GCNEngine.build(gcn_cfg(**over), graph, (1, 1))

    return make


@pytest.fixture
def _graphs(erdos_graph):
    def make(n, seed0=50):
        return [erdos_graph(256, 2048, seed=seed0 + i) for i in range(n)]

    return make


def test_plan_lru_evicts_under_byte_budget(fresh_caches, _engine, _graphs):
    """Plans for distinct graphs evict least-recently-served first once
    the configurable byte budget is exceeded; a re-planned graph counts
    exactly one extra miss."""
    cache = fresh_caches
    ga, gb, gc = _graphs(3)
    ea = _engine(ga)
    _ = ea.plan
    per_plan = cache.cache_stats()["plan"]["bytes"]
    assert per_plan > 0
    # room for two plans: admitting the third must evict the oldest (A)
    cache.set_cache_budget(plan_bytes=int(per_plan * 2.5))
    _ = _engine(gb).plan
    assert cache.cache_stats()["plan"]["entries"] == 2
    _ = _engine(gc).plan
    st = cache.cache_stats()["plan"]
    assert st["entries"] == 2 and st["evictions"] == 1
    assert not _engine(ga).plan_cached, "A must be the evicted plan"
    assert _engine(gb).plan_cached and _engine(gc).plan_cached

    # re-admission replans EXACTLY once: one miss to rebuild, then hits
    misses0 = cache.cache_stats()["plan"]["misses"]
    ea2 = _engine(ga)
    _ = ea2.plan
    assert cache.cache_stats()["plan"]["misses"] == misses0 + 1
    _ = _engine(ga).plan
    st = cache.cache_stats()["plan"]
    assert st["misses"] == misses0 + 1, "second touch must be a pure hit"
    # eviction RELEASED the pre-eviction session's memo (no pinning —
    # the budget bounds the process, not just the store); its next
    # access refetches the store's rebuilt object, the same one fresh
    # sessions see
    assert ea._plan is None
    assert ea.plan is ea2.plan


def test_plan_eviction_cascades_to_ell_and_steps(fresh_caches, _engine, _graphs):
    """Evicting a plan drops the ELL layouts and compiled steps built
    from it — a re-admitted graph can never pair a fresh plan with a
    stale derived entry."""
    import jax

    cache = fresh_caches
    ga, gb = _graphs(2, seed0=60)
    ea = _engine(ga)
    ea.init_params(jax.random.PRNGKey(0), [8, 4])
    feats = np.zeros((256, 8), np.float32)
    ea.forward(feats, agg_impl="pallas")  # plan + ELL + compiled step
    st = cache.cache_stats()
    assert st["plan"]["entries"] == 1
    assert st["ell"]["entries"] == 1
    assert st["step"]["entries"] >= 1
    # budget below two plans: B's arrival evicts A and all A-derived state
    cache.set_cache_budget(plan_bytes=int(st["plan"]["bytes"] * 1.5))
    _ = _engine(gb).plan
    st = cache.cache_stats()
    assert st["plan"]["entries"] == 1 and st["plan"]["evictions"] == 1
    assert st["ell"]["entries"] == 0, "ELL layout must die with its plan"
    assert st["step"]["entries"] == 0, "steps must die with their plan"


def test_plan_eviction_releases_feature_blocks(fresh_caches, _engine,
                                               _graphs):
    """The feature layer joins the eviction cascade: evicting a graph's
    plan drops its device-resident feature blocks (pins AND cold
    entries) — but the host column store survives, so the graph keeps
    serving correct rows and re-warms through the cold tier."""
    from repro.gcn import default_store

    cache = fresh_caches
    cache.set_cache_budget(feature_bytes=64 << 20)
    ga, gb = _graphs(2, seed0=70)
    ea = _engine(ga)
    _ = ea.plan
    feats = (np.random.default_rng(0)
             .normal(size=(256, 8)).astype(np.float32))
    store = default_store()
    h = store.register(ga, feats, graph_fp=ea.graph_fp,
                       block_vertices=32)
    assert h.stats()["pinned"] > 0
    st = cache.cache_stats()
    assert st["features"]["bytes"] > 0

    # budget below two plans: B's arrival evicts A, cascading into the
    # feature layer
    cache.set_cache_budget(plan_bytes=int(st["plan"]["bytes"] * 1.5))
    _ = _engine(gb).plan
    st = cache.cache_stats()
    assert st["plan"]["evictions"] == 1
    assert h.stats()["pinned"] == 0, "pins must die with the plan"
    assert st["features"]["bytes"] == 0, "no device bytes for evicted A"

    # host tier intact: bits still exact, and the next touch re-warms
    # the cold tier (device bytes grow again, within budget)
    nodes = np.arange(0, 256, 3)
    np.testing.assert_array_equal(h.gather(nodes), feats[nodes])
    assert h.stats()["registered"]
    assert 0 < store.device_bytes <= store.budget_bytes


def test_clear_and_invalidate_sweep_all_layers(fresh_caches, _engine, _graphs):
    """One coherent clear: ``clear_plan_cache()`` and
    ``invalidate_model()`` drop plan, ELL, prepared-graph AND
    compiled-step entries together (the pre-refactor bug was stale ELL /
    step entries surviving a plan clear)."""
    import jax

    cache = fresh_caches
    from repro.gcn import clear_plan_cache
    from repro.gcn.engine import invalidate_model

    (g,) = _graphs(1, seed0=70)
    for model in ("gcn", "gin"):
        e = _engine(g, model=model)
        e.init_params(jax.random.PRNGKey(0), [8, 4])
        e.forward(np.zeros((256, 8), np.float32), agg_impl="pallas")
    st = cache.cache_stats()
    assert st["plan"]["entries"] == 2 and st["ell"]["entries"] == 2
    assert st["prep"]["entries"] == 2 and st["step"]["entries"] == 2

    invalidate_model("gin")
    st = cache.cache_stats()
    assert st["plan"]["entries"] == 1 and st["ell"]["entries"] == 1
    assert st["prep"]["entries"] == 1 and st["step"]["entries"] == 1

    clear_plan_cache()
    st = cache.cache_stats()
    for layer in ("plan", "ell", "prep", "step"):
        assert st[layer]["entries"] == 0, layer


def test_compiled_step_shared_across_sessions(fresh_caches, _engine, _graphs):
    """Two sessions with the same executor identity get the SAME jitted
    layer step (one compile serves both); a different schedule (other
    graph) or backend gets its own."""
    import jax

    cache = fresh_caches
    ga, gb = _graphs(2, seed0=80)
    e1, e2 = _engine(ga), _engine(ga)
    for e in (e1, e2):
        e.init_params(jax.random.PRNGKey(0), [8, 4])
    assert e1._compiled_layer_step() is e2._compiled_layer_step()
    assert cache.cache_stats()["step"]["hits"] == 1
    # batched and unbatched variants are distinct compiled entries
    assert e1._compiled_layer_step(batched=True) \
        is not e1._compiled_layer_step()
    # the mesh identity must be construction-mode stable: a sibling
    # created AFTER e1's lazy mesh materialized still shares its steps
    _ = e1.mesh_jax
    sib = e1.with_config(message_passing=e1.cfg.message_passing)
    assert sib._compiled_layer_step(batched=True) \
        is e1._compiled_layer_step(batched=True)
    # another graph's schedule -> its own entry (no false sharing)
    e3 = _engine(gb)
    e3.init_params(jax.random.PRNGKey(0), [8, 4])
    assert e3._compiled_layer_step() is not e1._compiled_layer_step()


def test_step_store_shares_modulo_graph_fingerprint(fresh_caches):
    """Contract of the step layer itself: the key is the executor
    fingerprint ALONE — two plan identities differing only in graph
    fingerprint share one compiled entry when their schedules match —
    while eviction back-pointers still drop a plan's steps."""
    cache = fresh_caches
    ka = dataclasses.replace(_plan_key_stub(), graph_fp="aaaa")
    kb = dataclasses.replace(_plan_key_stub(), graph_fp="bbbb")
    fp = ("same-schedule",)
    builds = []
    sa = cache.get_step(ka, fp, lambda: builds.append("a") or object())
    sb = cache.get_step(kb, fp, lambda: builds.append("b") or object())
    assert sa is sb and builds == ["a"], \
        "equal exec fingerprints must share one compiled step"
    # evicting A's plan drops the shared entry; B re-fills on next use
    cache._on_plan_evict(ka.plan_identity(), None)
    assert not cache.step_cached(kb, fp)
    sb2 = cache.get_step(kb, fp, lambda: builds.append("b2") or object())
    assert sb2 is not sa and builds == ["a", "b2"]


def _plan_key_stub():
    from repro.gcn import PlanKey

    return PlanKey("", "gcn", "oppm", True, (1, 1), 4096, False, 0.75,
                   8, 0)


def test_forward_batched_matches_forward(fresh_caches, _engine, _graphs):
    """The folded-feature batched executor is numerically exact against
    per-request forward calls (the exchange is linear per column, so the
    relay sums in the same order)."""
    import jax

    (g,) = _graphs(1, seed0=90)
    for model in ("gcn", "gin", "sage"):
        e = _engine(g, model=model)
        e.init_params(jax.random.PRNGKey(1), [8, 6, 4])
        fb = np.random.default_rng(3).normal(
            size=(3, 256, 8)).astype(np.float32)
        out = e.forward_batched(fb)
        assert out.shape == (3, 256, 4)
        for b in range(3):
            single = e.forward(fb[b])
            np.testing.assert_allclose(out[b], single, rtol=1e-5,
                                       atol=1e-5)
    with pytest.raises(ValueError):
        e.forward_batched(np.zeros((2, 100, 8), np.float32))  # wrong |V|
