"""End-to-end: tiny LM training run (loss decreases, checkpoint/resume,
preemption) and the batched serving engine vs step-by-step decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_lm_config
from repro.models import lm
from repro.serve.engine import Request, ServeEngine
from repro.train import optimizer as optlib
from repro.train.loop import TrainConfig, train


@pytest.mark.slow
def test_train_loss_decreases_and_resumes(tmp_path):
    cfg = get_lm_config("glm4-9b", "smoke")
    tcfg = TrainConfig(steps=30, log_every=10, ckpt_every=15,
                       ckpt_dir=str(tmp_path),
                       opt=optlib.AdamWConfig(lr=3e-3, warmup_steps=5,
                                              total_steps=60))
    out = train(cfg, tcfg, resume=False)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0], losses
    # resume from the step-30 world and keep going
    tcfg2 = TrainConfig(steps=40, log_every=10, ckpt_every=0,
                        ckpt_dir=str(tmp_path),
                        opt=tcfg.opt)
    out2 = train(cfg, tcfg2, resume=True)
    assert out2["history"][0]["step"] >= 30


@pytest.mark.slow
def test_serve_engine_matches_reference_decode():
    cfg = get_lm_config("minitron-8b", "smoke")
    # f32 params: bf16 leaves near-tied logits whose argmax legitimately
    # flips between compilation paths (verified: logit deltas ~7e-3)
    params = jax.tree.map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        lm.lm_init(cfg, jax.random.PRNGKey(0)))
    prompts = [np.array([3, 5, 7, 11]), np.array([2, 4, 6, 8, 10, 12])]

    # reference: sequential prefill+decode, teacher-forced on the
    # engine's emitted tokens, returning the logits of every step.
    # Both paths use f32 KV caches: with the default bf16 cache the
    # batched-slot engine and this single-request reference (different
    # compiled shapes) round differently by up to ~0.06 logits, enough
    # to flip near-tied greedy tokens between runs.
    def ref_logits(prompt, tokens):
        st = lm.init_decode_state(cfg, 1, 64, dtype=jnp.float32)
        last_h, st = lm.prefill(cfg, params, jnp.asarray(prompt[None]), st)
        W = lm.lm_head_matrix(params.get("head", {}), params["embed"], cfg)
        steps = [(last_h @ W.astype(last_h.dtype)).astype(jnp.float32)[0]]
        for t in tokens[:-1]:
            tok = jnp.asarray([[t]], jnp.int32)
            logits, st = lm.decode_step(cfg, params, tok, st)
            steps.append(logits[0])
        return np.asarray(steps)

    engine = ServeEngine(cfg, params, slots=2, max_len=64,
                         cache_dtype=jnp.float32)
    reqs = [Request(rid=i, prompt=p, max_new=6) for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_done(max_ticks=50)
    for r, p in zip(reqs, prompts):
        assert len(r.out) >= 6
        toks = r.out[:6]
        logits = ref_logits(p, toks)
        # each engine token must be the reference argmax up to f32
        # noise (the two paths compile with different batch shapes, so
        # bit-identical logits are not guaranteed even at f32); a real
        # divergence — wrong cache row, wrong position — shifts the
        # whole hidden state and yields O(1) gaps, far above this
        best = logits.max(axis=-1)
        chosen = logits[np.arange(len(toks)), toks]
        gap = best - chosen
        assert np.all(gap <= 1e-3), (toks, gap.tolist())
