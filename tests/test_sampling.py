"""Property tests for the neighbor sampler (``repro.core.sampling``),
via the hypothesis shim in ``_hypothesis_compat``:

  * the per-layer fanout bound is respected (per frontier vertex AND in
    aggregate);
  * subgraph edges are a subset of the parent's under the local<->global
    node map (vertex-induced contract);
  * the same sampler seed + seed set reproduces the batch bit-for-bit
    (and the fingerprint with it), independent of draw order;
  * full fanout on a small graph yields exactly the closed k-hop
    in-neighborhood of the seeds;
  * induced prepared subgraphs carry the PARENT's edge weights (degree
    normalization never recomputed on the truncated subgraph).
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

V, E = 128, 768


def _graph(seed=3):
    from repro.core.graph import erdos

    return erdos(V, E, seed=seed)


def _khop_in_neighborhood(graph, seeds, k):
    """BFS reference: the closed k-hop in-neighborhood of ``seeds``."""
    indptr, src = graph.csr_in()
    nodes = set(int(s) for s in seeds)
    for _ in range(k):
        nxt = set()
        for v in nodes:
            nxt |= set(src[indptr[v]:indptr[v + 1]].tolist())
        nodes |= nxt
    return np.array(sorted(nodes), np.int64)


@settings(max_examples=8, deadline=None)
@given(fanout=st.integers(0, 6), seed=st.integers(0, 3))
def test_fanout_bound_respected_per_layer(fanout, seed):
    """Each layer adds at most fanout * |frontier| new vertices, and
    the per-vertex primitive never returns more than fanout
    in-neighbors (and only true in-neighbors)."""
    from repro.core.sampling import NeighborSampler

    g = _graph()
    s = NeighborSampler(g, (fanout, fanout), seed=seed)
    batch = s.sample(np.arange(0, V, 7))
    assert len(batch.layers) == 3  # seeds + one per fanout entry
    for lo, hi in zip(batch.layers, batch.layers[1:]):
        assert hi.size - lo.size <= fanout * lo.size
        assert np.all(np.isin(lo, hi))  # cumulative

    indptr, src = g.csr_in()
    rng = np.random.default_rng(0)
    for v in batch.layers[0][:16]:
        picked = s.sample_in_neighbors([v], fanout, rng)
        assert picked.size <= fanout
        assert np.all(np.isin(picked, src[indptr[v]:indptr[v + 1]]))


@settings(max_examples=6, deadline=None)
@given(fanout=st.integers(1, 5), seed=st.integers(0, 5))
def test_subgraph_edges_subset_of_parent(fanout, seed):
    """Every subgraph edge, mapped local->global, is a parent edge; and
    the subgraph is vertex-INDUCED: it has every parent edge whose two
    endpoints were both visited."""
    from repro.core.sampling import NeighborSampler

    g = _graph()
    batch = NeighborSampler(g, (fanout, fanout), seed=seed).sample(
        np.arange(0, V, 11))
    sub = batch.subgraph
    assert sub.num_vertices == batch.num_nodes
    parent_edges = set(zip(g.src.tolist(), g.dst.tolist()))
    mapped = set(zip(batch.nodes[sub.src].tolist(),
                     batch.nodes[sub.dst].tolist()))
    assert mapped <= parent_edges
    # induced completeness: parent edges inside the node set all appear
    node_set = set(batch.nodes.tolist())
    inside = set((int(s), int(d)) for s, d in zip(g.src, g.dst)
                 if s in node_set and d in node_set)
    assert mapped == inside


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 7), fanout=st.integers(1, 4))
def test_same_seed_identical_batches(seed, fanout):
    """Same sampler seed + same seed set => identical nodes, edges and
    fingerprint — even when the two samplers drew different batches
    before (per-seed-set rng derivation)."""
    from repro.core.sampling import NeighborSampler

    g = _graph()
    sa = NeighborSampler(g, (fanout, fanout), seed=seed)
    sb = NeighborSampler(g, (fanout, fanout), seed=seed)
    sb.sample(np.arange(0, 40))  # perturb sb's call history
    seeds = np.arange(0, V, 5)
    ba, bb = sa.sample(seeds), sb.sample(seeds)
    np.testing.assert_array_equal(ba.nodes, bb.nodes)
    np.testing.assert_array_equal(ba.subgraph.src, bb.subgraph.src)
    np.testing.assert_array_equal(ba.subgraph.dst, bb.subgraph.dst)
    assert ba.fingerprint() == bb.fingerprint()
    # a different sampler seed is allowed to differ (and here does not
    # have to), but a different SEED SET must change the fingerprint
    assert sa.sample(seeds[:-1]).fingerprint() != ba.fingerprint()


@settings(max_examples=6, deadline=None)
@given(seed_v=st.integers(0, V - 1), depth=st.integers(1, 3))
def test_full_fanout_covers_khop_neighborhood(seed_v, depth):
    """fanout = -1 at every layer => the visited set is exactly the
    closed k-hop in-neighborhood, and the induced subgraph carries all
    of its internal edges."""
    from repro.core.sampling import NeighborSampler

    g = _graph()
    batch = NeighborSampler(g, (-1,) * depth, seed=0).sample([seed_v])
    ref = _khop_in_neighborhood(g, [seed_v], depth)
    np.testing.assert_array_equal(batch.nodes, ref)


def test_epoch_batches_partition_and_determinism():
    from repro.core.sampling import NeighborSampler

    g = _graph()
    s = NeighborSampler(g, (2,), seed=9)
    train = np.arange(0, V, 3)
    batches = s.epoch_batches(train, 16, epoch=0)
    # a partition: disjoint, complete, all within batch_size
    got = np.sort(np.concatenate(batches))
    np.testing.assert_array_equal(got, train)
    assert all(b.size <= 16 for b in batches)
    # deterministic per (seed, epoch); different epoch reshuffles
    again = s.epoch_batches(train, 16, epoch=0)
    assert all(np.array_equal(a, b) for a, b in zip(batches, again))
    other = s.epoch_batches(train, 16, epoch=1)
    assert any(not np.array_equal(a, b) for a, b in zip(batches, other))


def test_induced_prepared_carries_parent_weights():
    """Subgraphs induced from the parent PREPARED graph keep the
    parent's per-edge weights — the degree normalization a truncated
    subgraph cannot reproduce (GCN weights use both endpoints'
    parent in-degrees)."""
    from repro.core.gcn_models import gcn_prepare
    from repro.core.sampling import csr_in_with_values, induce_in_edges

    g = _graph()
    g2, w = gcn_prepare(g)
    indptr, src, wv = csr_in_with_values(g2, w)
    nodes = np.unique(np.arange(0, V, 4).astype(np.int64))
    sub, w_sub = induce_in_edges(indptr, src, wv, nodes, num_vertices=64)
    assert sub.num_vertices == 64
    # look up each induced edge in the parent and compare weights
    parent = {}
    for s_, d_, ww in zip(g2.src.tolist(), g2.dst.tolist(), w.tolist()):
        parent[(s_, d_)] = ww  # duplicate edges share one prepared w
    for s_, d_, ww in zip(nodes[sub.src], nodes[sub.dst], w_sub):
        assert parent[(int(s_), int(d_))] == pytest.approx(float(ww))
    # self loops (added by prepare) survive induction for every node
    loops = set(zip(sub.src[sub.src == sub.dst].tolist(),
                    sub.dst[sub.src == sub.dst].tolist()))
    assert loops == {(i, i) for i in range(len(nodes))}


def test_sampler_rejects_bad_inputs():
    from repro.core.sampling import NeighborSampler

    g = _graph()
    with pytest.raises(ValueError):
        NeighborSampler(g, (1, -2))
    s = NeighborSampler(g, (1,))
    with pytest.raises(ValueError):
        s.sample([])
    with pytest.raises(ValueError):
        s.sample([V + 5])
    with pytest.raises(ValueError):
        s.epoch_batches(np.arange(8), 0)
    batch = s.sample([0])
    outside = np.setdiff1d(np.arange(V), batch.nodes)[0]
    with pytest.raises(ValueError):
        batch.local_of([outside])
