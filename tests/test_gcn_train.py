"""The distributed training subsystem (``repro.gcn.train``):
differentiation THROUGH the multicast exchange.

Property coverage (the in-process 1-CPU view; the multi-device versions
run in the ``_gcn_train_main.py`` subprocess):

  * the exchange VJP is linear — the cotangent is independent of the
    primal point, and the exchange itself is additive/homogeneous;
  * ``loss_and_grad`` matches the dense single-node oracle
    (``reference_loss_and_grad``) for every registered model, on BOTH
    aggregation backends (the pallas ELL kernel carries an explicit
    transpose kernel);
  * two identical ``fit`` runs are bit-identical (determinism);
  * ``fit`` decreases the loss and hands trained params to serving
    without replanning or recompiling (``GCNService.adopt``);
  * ``forward_batched`` buckets batch sizes to powers of two (satellite:
    distinct request counts stop triggering per-B recompiles);
  * plan eviction under a byte budget releases live-session memos
    (satellite: ``set_cache_budget`` bounds the whole process).
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

V, E, F, C = 256, 2048, 8, 4


@pytest.mark.slow
def test_train_8dev():
    """Multi-device acceptance run (subprocess; device count must be
    set before jax initializes): gradient parity vs the dense reference
    for all 3 models x both backends on a (4, 2) torus, decreasing
    loss, backward-exchange byte accounting, the train->serve handoff,
    and the neighbor-sampled pipeline (full-fanout parity + bounded-
    fanout training that never builds the full-batch plan). See
    ``_gcn_train_main.py``."""
    script = Path(__file__).parent / "_gcn_train_main.py"
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ALL_OK" in r.stdout


# engine/graph/feats/labels/mask setup is shared with the other GCN
# test modules via the seeded conftest fixtures (gcn_cfg, erdos_graph,
# gcn_setup, fresh_caches)


def test_exchange_vjp_is_linear(fresh_caches, gcn_setup):
    """The exchange is linear in the features, so (a) outputs are
    additive/homogeneous and (b) its VJP cotangent does not depend on
    the primal point — the backward pass is a pure reversed relay
    replay, with no stored activations from the forward."""
    import jax
    import jax.numpy as jnp

    eng, feats, _, _ = gcn_setup()
    exch = eng.exchange_fn()
    pdev = eng.plan_arrays()
    x1 = jnp.asarray(eng.shard(feats))
    x2 = jnp.asarray(eng.shard(feats[::-1].copy()))

    out = exch(pdev, 2.0 * x1 + 3.0 * x2)
    ref = 2.0 * exch(pdev, x1) + 3.0 * exch(pdev, x2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)

    ct = jnp.asarray(
        np.random.default_rng(0).normal(size=out.shape).astype(np.float32))
    _, vjp1 = jax.vjp(lambda xx: exch(pdev, xx), x1)
    _, vjp2 = jax.vjp(lambda xx: exch(pdev, xx), x2)
    (g1,), (g2,) = vjp1(ct), vjp2(ct)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


def test_grad_parity_all_models_both_backends(fresh_caches, gcn_setup):
    """``loss_and_grad`` through the distributed exchange matches the
    dense single-node oracle to fp32 tolerance for GCN/GIN/SAGE, and
    the two aggregation backends agree with each other."""
    import jax
    import jax.numpy as jnp

    from repro.gcn import reference_loss_and_grad

    for model in ("gcn", "gin", "sage"):
        eng, feats, labels, mask = gcn_setup(model)
        loss_r, grads_r = reference_loss_and_grad(eng, feats, labels, mask)
        for impl in ("jnp", "pallas"):
            loss_d, grads_d = eng.loss_and_grad(feats, labels, mask,
                                                agg_impl=impl)
            assert abs(float(loss_d) - float(loss_r)) < 1e-5, (model, impl)
            for gd, gr in zip(jax.tree.leaves(grads_d),
                              jax.tree.leaves(grads_r)):
                err = float(jnp.max(jnp.abs(gd - gr))
                            / (jnp.max(jnp.abs(gr)) + 1e-9))
                assert err < 1e-4, (model, impl, err)


def test_fit_decreases_loss_and_is_deterministic(fresh_caches, gcn_setup):
    """Two identical ``fit`` runs produce bit-identical parameters and
    a decreasing loss trajectory."""
    import jax

    from repro.gcn import GCNTrainer

    reports = []
    for _ in range(2):
        eng, feats, labels, mask = gcn_setup()
        tr = GCNTrainer(eng, labels, mask)
        reports.append(tr.fit(feats, epochs=10))
    ra, rb = reports
    assert ra.loss_last < ra.loss_first
    assert [h["loss"] for h in ra.history] == \
        [h["loss"] for h in rb.history], "fit must be deterministic"
    for a, b in zip(jax.tree.leaves(ra.params), jax.tree.leaves(rb.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_mask_excludes_vertices(fresh_caches, gcn_setup):
    """The loss only sees masked vertices: flipping an UNmasked
    vertex's label changes nothing."""
    eng, feats, labels, mask = gcn_setup()
    off = int(np.flatnonzero(mask == 0)[0])
    loss0, _ = eng.loss_and_grad(feats, labels, mask)
    labels2 = labels.copy()
    labels2[off] = (labels2[off] + 1) % C
    loss1, _ = eng.loss_and_grad(feats, labels2, mask)
    assert float(loss0) == float(loss1)


def test_train_serve_handoff_no_replan_no_recompile(fresh_caches, gcn_setup):
    """``GCNService.adopt`` serves a trainer's session as-is: no plan
    misses at handoff, and the second identical request batch reuses
    the compiled batched step (no step-cache miss either)."""
    from repro.gcn import GCNService, GCNTrainer

    cache = fresh_caches
    eng, feats, labels, mask = gcn_setup()
    tr = GCNTrainer(eng, labels, mask)
    tr.fit(feats, epochs=4)

    svc = GCNService((1, 1))
    plan_m0 = cache.cache_stats()["plan"]["misses"]
    svc.adopt("trained", eng)
    out = svc.infer("trained", feats)
    assert cache.cache_stats()["plan"]["misses"] == plan_m0, \
        "handoff must not replan"
    ref = eng.reference(feats)
    err = np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert err < 1e-4, err
    step_m0 = cache.cache_stats()["step"]["misses"]
    out2 = svc.infer("trained", feats)
    assert cache.cache_stats()["step"]["misses"] == step_m0, \
        "second serve must not recompile"
    np.testing.assert_array_equal(out, out2)

    # adoption validation: mesh mismatch, missing params, dup name
    eng2, *_ = gcn_setup(dims=(1,))
    with pytest.raises(ValueError):
        svc.adopt("other-mesh", eng2)
    eng3, *_ = gcn_setup()
    eng3.params = None
    with pytest.raises(ValueError):
        svc.adopt("untrained", eng3)
    with pytest.raises(ValueError):
        svc.adopt("trained", eng)


def test_loss_and_grad_rejects_bad_shapes(fresh_caches, gcn_setup):
    eng, feats, labels, _ = gcn_setup()
    with pytest.raises(ValueError):
        eng.loss_and_grad(feats[:100], labels)  # wrong |V|
    with pytest.raises(ValueError):
        eng.loss_and_grad(feats, labels[:100])  # wrong label count
    with pytest.raises(ValueError):
        eng.loss_and_grad(feats, labels, np.ones(7))  # wrong mask


def test_forward_batched_buckets_batch_sizes(fresh_caches, gcn_setup):
    """Satellite: B is padded to the next power of two, so request
    counts 3 and 4 share one compiled step; results stay exact against
    per-request forward, and ``stats()`` reports the hit rate."""
    eng, feats, _, _ = gcn_setup()
    rng = np.random.default_rng(1)
    fb3 = rng.normal(size=(3, V, F)).astype(np.float32)
    out3 = eng.forward_batched(fb3)
    assert out3.shape == (3, V, C)
    for b in range(3):
        np.testing.assert_allclose(out3[b], eng.forward(fb3[b]),
                                   rtol=1e-5, atol=1e-5)
    st = eng.stats(feat_dim=F)
    assert st["batch_bucket_calls"] == 1 and st["batch_bucket_hits"] == 0
    assert st["batch_buckets"] == [4]  # 3 padded up to 4

    fb4 = rng.normal(size=(4, V, F)).astype(np.float32)
    eng.forward_batched(fb4)  # same bucket: a hit, no new bucket
    st = eng.stats(feat_dim=F)
    assert st["batch_bucket_calls"] == 2 and st["batch_bucket_hits"] == 1
    assert st["batch_bucket_hit_rate"] == pytest.approx(0.5)
    assert st["batch_buckets"] == [4]

    eng.forward_batched(fb4[:1])  # B=1 -> its own bucket
    st = eng.stats(feat_dim=F)
    assert st["batch_buckets"] == [1, 4]


def test_service_reports_bucket_hit_rate(fresh_caches, gcn_cfg,
                                         erdos_graph):
    """Varying per-step batch sizes that share a bucket are served
    without growing the bucket set; the service aggregates the rate."""
    from repro.gcn import GCNService

    g = erdos_graph(V, E, seed=11)
    svc = GCNService((1, 1), max_batch=4)
    svc.admit("g", gcn_cfg(), g, layer_dims=[F, C])
    rng = np.random.default_rng(2)

    def submit(n):
        for _ in range(n):
            svc.submit("g", rng.normal(size=(V, F)).astype(np.float32))

    submit(3)
    svc.run()  # one batch of 3 -> bucket 4
    submit(4)
    svc.run()  # one batch of 4 -> bucket 4 again: hit
    st = svc.stats()
    assert st["batch_bucket_calls"] == 2
    assert st["batch_bucket_hits"] == 1
    assert st["batch_bucket_hit_rate"] == pytest.approx(0.5)


def test_plan_eviction_releases_live_session(fresh_caches, gcn_cfg, erdos_graph):
    """Satellite: evicting a plan under byte pressure clears the live
    session's memoized plan/device arrays/compiled steps (the session
    no longer pins them), and the session transparently rebuilds
    through the store on next use — exactly one extra plan miss."""
    import jax

    from repro.gcn import GCNEngine

    cache = fresh_caches
    ga, gb = erdos_graph(V, E, seed=21), erdos_graph(V, E, seed=22)
    ea = GCNEngine.build(gcn_cfg(), ga, (1, 1))
    ea.init_params(jax.random.PRNGKey(0), [F, C])
    feats = np.random.default_rng(3).normal(size=(V, F)).astype(np.float32)
    out_before = ea.forward(feats)
    assert ea.plan_uploaded()
    per_plan = cache.cache_stats()["plan"]["bytes"]

    # budget below two plans: B's arrival evicts A AND releases ea
    cache.set_cache_budget(plan_bytes=int(per_plan * 1.5))
    _ = GCNEngine.build(gcn_cfg(), gb, (1, 1)).plan
    assert not ea.plan_cached
    assert ea._plan is None, "eviction must release the memoized plan"
    assert not ea.plan_uploaded(), "device arrays must be released"
    assert ea._layer_step == {} and ea._train_fns == {}

    # next use transparently replans (one miss) and matches exactly
    misses0 = cache.cache_stats()["plan"]["misses"]
    out_after = ea.forward(feats)
    assert cache.cache_stats()["plan"]["misses"] == misses0 + 1
    np.testing.assert_array_equal(out_before, out_after)
    # the budget still binds: only the LRU-allowed entries are resident
    assert cache.cache_stats()["plan"]["entries"] == 1
