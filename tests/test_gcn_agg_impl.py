"""Aggregation-backend (``agg_impl``) coverage: pallas-vs-jnp parity
against the engine's exact oracle for every registered model and every
message-passing mode (including the SREM rounds path), plus the cache
contract — ``agg_impl`` is part of the PlanKey, but switching backends
never replans.

Runs in-process on the 1-CPU view with a (1, 1) mesh (the pallas kernel
runs in interpret mode off-TPU — the same code path a TPU takes, minus
Mosaic lowering). The 8-device variants live in _gcn_engine_main.py.
Config/graph setup comes from the shared conftest fixtures (``gcn_cfg``
builds the smoke config with the small aggregation buffer that forces
several SREM rounds even at |V|=256; ``erdos_graph`` memoizes the
seeded graph).
"""
import numpy as np
import pytest

V, E, F = 256, 2048, 8


def _feats(rng_seed=0, f=F):
    return np.random.default_rng(rng_seed).normal(
        size=(V, f)).astype(np.float32)


def _rel_err(a, b):
    return np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)


def test_parity_all_registered_models(gcn_cfg, erdos_graph):
    """pallas and jnp backends both match reference() for every model
    in the registry (GCN / GIN / SAGE + any user-registered)."""
    import jax
    from repro.gcn import GCNEngine, registered_models

    g = erdos_graph(V, E, seed=11)
    feats = _feats()
    for model in registered_models():
        eng = GCNEngine.build(gcn_cfg(model=model), g, (1, 1))
        eng.init_params(jax.random.PRNGKey(3), [F, 12, 6])
        assert eng.plan.num_rounds > 1, "rounds path must be exercised"
        ref = eng.reference(feats)
        for impl in ("jnp", "pallas"):
            err = _rel_err(eng.forward(feats, agg_impl=impl), ref)
            assert err < 1e-4, (model, impl, err)


@pytest.mark.parametrize("mpm", ["oppe", "oppr", "oppm"])
@pytest.mark.parametrize("use_rounds", [True, False])
def test_parity_all_modes(mpm, use_rounds, gcn_cfg, erdos_graph):
    """The ELL path must agree with the oracle under every
    message-passing model, with and without SREM rounds."""
    import jax
    from repro.gcn import GCNEngine

    eng = GCNEngine.build(
        gcn_cfg(message_passing=mpm, use_rounds=use_rounds), erdos_graph(V, E, seed=11), (1, 1))
    eng.init_params(jax.random.PRNGKey(0), [F, 6])
    feats = _feats(1)
    ref = eng.reference(feats)
    assert _rel_err(eng.forward(feats, agg_impl="pallas"), ref) < 1e-4
    assert (eng.plan.num_rounds > 1) == use_rounds


def test_agg_impl_is_part_of_key_but_never_replans(gcn_cfg, erdos_graph):
    from repro.gcn import GCNEngine, plan_cache_stats

    g = erdos_graph(V, E, seed=11)
    e_jnp = GCNEngine.build(gcn_cfg(agg_impl="jnp"), g, (1, 1))
    e_pal = GCNEngine.build(gcn_cfg(agg_impl="pallas"), g, (1, 1))
    # agg_impl IS part of the (full) key: layouts/compiled steps are
    # per-backend...
    assert e_jnp.plan_key != e_pal.plan_key
    assert e_jnp.plan_key.agg_impl == "jnp"
    assert e_pal.plan_key.agg_impl == "pallas"
    # ...but NOT of the plan identity: switching backends never replans
    assert e_jnp.plan_key.plan_identity() == e_pal.plan_key.plan_identity()
    before = plan_cache_stats()
    p1 = e_jnp.plan
    after_first = plan_cache_stats()
    assert e_pal.plan is p1, "same CommPlan object across backends"
    after = plan_cache_stats()
    assert after["misses"] == after_first["misses"], \
        "backend switch must not replan"
    assert after["hits"] == after_first["hits"] + 1
    # flipping a *plan-shaping* field still separates plans
    assert e_jnp.with_config(message_passing="oppe").plan is not p1
    del before


def test_ell_layout_cached_alongside_plan(gcn_cfg, erdos_graph):
    """The host-side ELL layout is built once per full PlanKey, shared
    by engines on the same workload, and keyed apart by block shape."""
    from repro.gcn import GCNEngine, plan_cache_stats

    g = erdos_graph(V, E, seed=11)
    e1 = GCNEngine.build(gcn_cfg(), g, (1, 1))
    e2 = GCNEngine.build(gcn_cfg(), g, (1, 1))
    l1 = e1.ell_layout()
    assert e2.ell_layout() is l1, "same workload must share one layout"
    seg, rows, w = l1
    R, N = e1.plan.num_rounds, e1.plan.num_nodes
    nb = -(-e1.plan.part.slots_per_round // e1.cfg.ell_block_slots)
    assert seg.shape[:3] == (R, N, nb) and seg.shape == rows.shape == w.shape
    assert seg.shape[3] % e1.cfg.ell_edge_align == 0
    # padding invariant: seg == -1 exactly where the weight is the
    # neutral 0 (the builder drops the planner's zero-weight COO padding
    # before layout, so every kept entry carries a real weight)
    assert np.all((seg < 0) == (w == 0.0))
    # a different block shape is a different full key -> separate layout
    S = e1.plan.part.slots_per_round
    small = max(1, S // 2)
    e3 = GCNEngine.build(gcn_cfg(ell_block_slots=small), g, (1, 1))
    l3 = e3.ell_layout()
    assert l3 is not l1 and l3[0].shape[2] == -(-S // small)
    assert e3.plan is e1.plan, "block shape must not replan either"
    assert plan_cache_stats()["ell_entries"] >= 2


def test_resolution_and_stats_traffic_keys(gcn_cfg, erdos_graph):
    import jax
    from repro.gcn import GCNEngine, resolve_agg_impl

    assert resolve_agg_impl("jnp") == "jnp"
    assert resolve_agg_impl("pallas") == "pallas"
    auto = resolve_agg_impl("auto")
    assert auto == ("pallas" if jax.default_backend() == "tpu" else "jnp")
    with pytest.raises(ValueError):
        resolve_agg_impl("systolic")

    eng = GCNEngine.build(gcn_cfg(), erdos_graph(V, E, seed=11), (1, 1))
    eng.init_params(jax.random.PRNGKey(0), [F, 4])
    st = eng.stats(feat_dim=F)
    assert st["agg_impl"] == auto
    assert st["agg_dense_bytes"] > 0 and st["agg_ell_bytes"] > 0
    assert st["agg_traffic_reduction"] == pytest.approx(
        1.0 - st["agg_ell_bytes"] / st["agg_dense_bytes"])
    # the links are untouched by the aggregation backend: the traced
    # ppermute payload is identical under both impls
    assert eng.measured_link_bytes(feat_dim=F, agg_impl="jnp") == \
        eng.measured_link_bytes(feat_dim=F, agg_impl="pallas")
    # forward accepts "auto" and the env-var-free explicit spellings
    feats = _feats(2)
    out_auto = eng.forward(feats, agg_impl="auto")
    np.testing.assert_allclose(out_auto, eng.forward(feats), atol=1e-6)


def test_ell_layout_rounds_matches_coo(gcn_cfg, erdos_graph):
    """Property check of the batched layout builder itself: rebuilding
    the COO sum from the ELL tensors reproduces every (round, node)
    accumulator."""
    from repro.gcn import GCNEngine
    from repro.kernels.spmm import ref as spr
    import jax.numpy as jnp

    eng = GCNEngine.build(gcn_cfg(), erdos_graph(V, E, seed=11), (1, 1))
    plan = eng.plan
    seg, rows, w = eng.ell_layout()
    R, N = plan.num_rounds, plan.num_nodes
    S = plan.part.slots_per_round
    bs = eng.cfg.ell_block_slots
    rng = np.random.default_rng(7)
    replica = rng.normal(size=(plan.replica_rows, 4)).astype(np.float32)
    for r in range(0, R, max(1, R // 3)):
        for n in range(N):
            ref = np.asarray(spr.spmm_coo_ref(
                jnp.asarray(replica), jnp.asarray(plan.edge_repl[r, n]),
                jnp.asarray(plan.edge_slot[r, n]),
                jnp.asarray(plan.edge_w[r, n]), S))
            msgs = replica[rows[r, n].reshape(-1)].reshape(
                seg.shape[2], seg.shape[3], -1) * w[r, n][..., None]
            ell = np.asarray(spr.spmm_ell_ref(
                jnp.asarray(seg[r, n]), jnp.asarray(msgs), bs))
            ell = ell.reshape(-1, 4)[:S]
            np.testing.assert_allclose(ell, ref, atol=1e-4, rtol=1e-4)
