"""Roofline HLO parser: while-loop trip scaling must reconcile the scanned
and unrolled versions of the same program (the thing cost_analysis gets
wrong), and collective bytes must match hand counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import analyze_hlo


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_scaling():
    D, L = 64, 12

    def f_scan(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    def f_unroll(w, x):
        h = x
        for i in range(L):
            h = jnp.tanh(h @ w[i])
        return h

    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((4, D), jnp.float32)
    c_scan = analyze_hlo(_compile_text(f_scan, w, x))
    c_unroll = analyze_hlo(_compile_text(f_unroll, w, x))

    # XLA's own cost_analysis undercounts the scan by ~L; the parser fixes it
    assert any(t == L for _, t in c_scan.loops), c_scan.loops
    assert c_scan.dot_flops == pytest.approx(c_unroll.dot_flops, rel=0.01)
    expected = 2.0 * L * 4 * D * D
    assert c_scan.dot_flops == pytest.approx(expected, rel=0.01)


def test_cost_analysis_undercounts_scans():
    """Documents WHY the parser exists (guards against upstream changes)."""
    D, L = 32, 8

    def f_scan(w, x):
        def body(h, wl):
            return h @ wl, None
        return jax.lax.scan(body, x, w)[0]

    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((4, D), jnp.float32)
    from repro.core.jax_compat import cost_analysis

    ca = cost_analysis(jax.jit(f_scan).lower(w, x).compile())
    assert ca["flops"] < 2 * L * 4 * D * D * 0.5  # counted once, not L times


def test_nested_scan_multiplies():
    D, L1, L2 = 16, 5, 7

    def f(w, x):
        def outer(h, wl):
            def inner(h2, _):
                return jnp.tanh(h2 @ wl), None
            h2, _ = jax.lax.scan(inner, h, None, length=L2)
            return h2, None
        return jax.lax.scan(outer, x, w)[0]

    w = jax.ShapeDtypeStruct((L1, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((2, D), jnp.float32)
    c = analyze_hlo(_compile_text(f, w, x))
    assert c.dot_flops == pytest.approx(2.0 * L1 * L2 * 2 * D * D, rel=0.05)


def test_dot_flops_formula():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    c = analyze_hlo(_compile_text(f, a, b))
    assert c.dot_flops == pytest.approx(2 * 128 * 256 * 64, rel=0.01)
