"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode).
Hypothesis drives the spmm COO generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.flash_attention import kernel as fak, ref as far
from repro.kernels.matmul import kernel as mmk, ref as mmr
from repro.kernels.spmm import ops as spo, ref as spr

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hkv,G,Sq,Skv,D,causal,window", [
    (1, 1, 1, 128, 128, 64, True, 0),
    (2, 2, 2, 128, 256, 64, True, 0),
    (1, 2, 4, 256, 128, 32, False, 0),
    (1, 1, 2, 256, 256, 128, True, 96),
])
def test_flash_kernel_sweep(dtype, B, Hkv, G, Sq, Skv, D, causal, window):
    ks = jax.random.split(KEY, 3)
    q = (jax.random.normal(ks[0], (B, Hkv, G, Sq, D)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, Hkv, Skv, D)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, Hkv, Skv, D)) * 0.5).astype(dtype)
    out, _ = fak.flash_attention_fwd(q, k, v, scale=1.0 / np.sqrt(D),
                                     causal=causal, window=window,
                                     block_q=64, block_k=64, interpret=True)
    ref = far.attention_ref(q, k, v, scale=1.0 / np.sqrt(D), causal=causal,
                            window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,N,act", [
    (128, 256, 128, "none"), (256, 128, 384, "relu"), (128, 512, 128, "gelu"),
])
def test_matmul_kernel_sweep(dtype, M, K, N, act):
    k1, k2 = jax.random.split(KEY)
    x = (jax.random.normal(k1, (M, K)) * 0.3).astype(dtype)
    w = (jax.random.normal(k2, (K, N)) * 0.3).astype(dtype)
    b = jnp.ones((N,), dtype) * 0.1
    y = mmk.matmul_fused(x, w, b, act=act, interpret=True)
    ref = mmr.matmul_fused_ref(x, w, b, act=act)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@settings(max_examples=15, deadline=None)
@given(
    n_repl=st.integers(4, 200),
    n_slots=st.integers(1, 300),
    n_edges=st.integers(1, 800),
    feat=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmm_kernel_property(n_repl, n_slots, n_edges, feat, seed):
    rng = np.random.default_rng(seed)
    er = rng.integers(0, n_repl, n_edges).astype(np.int32)
    es = rng.integers(0, n_slots, n_edges).astype(np.int32)
    ew = rng.normal(size=n_edges).astype(np.float32)
    replica = jnp.asarray(rng.normal(size=(n_repl, feat)).astype(np.float32))
    seg, rows, w = spo.build_ell_layout(er, es, ew, n_slots)
    acc = spo.aggregate(replica, jnp.asarray(seg), jnp.asarray(rows),
                        jnp.asarray(w), num_slots=n_slots)
    ref = spr.spmm_coo_ref(replica, jnp.asarray(er), jnp.asarray(es),
                           jnp.asarray(ew), n_slots)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
