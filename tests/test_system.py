"""System-level sanity: registry, configs, assigned-cell coverage."""
import pytest

from repro.config import (
    LM_SHAPES,
    get_gcn_config,
    get_lm_config,
    list_gcn_archs,
    list_lm_archs,
    lm_cells,
)

ASSIGNED = [
    "minitron-8b", "glm4-9b", "starcoder2-15b", "mistral-large-123b",
    "zamba2-2.7b", "whisper-tiny", "internvl2-76b", "mixtral-8x7b",
    "deepseek-v2-lite-16b", "rwkv6-1.6b",
]

# published parameter counts (B) — analytic count must land within 12 %
PUBLISHED_PARAMS = {
    "minitron-8b": 8.0, "glm4-9b": 9.4, "starcoder2-15b": 16.0,
    "mistral-large-123b": 123.0, "whisper-tiny": 0.039,
    "internvl2-76b": 70.6,  # LLM backbone only (llama-3-70B class)
    "mixtral-8x7b": 46.7, "deepseek-v2-lite-16b": 15.7, "rwkv6-1.6b": 1.6,
    "zamba2-2.7b": 2.7,
}


def test_all_assigned_archs_registered():
    assert sorted(ASSIGNED) == list_lm_archs()


def test_param_counts_match_published():
    for arch, published in PUBLISHED_PARAMS.items():
        got = get_lm_config(arch).param_count() / 1e9
        tol = 0.15 if arch == "zamba2-2.7b" else 0.12  # zamba2: LoRA deltas
        assert abs(got - published) / published < tol, (arch, got, published)


def test_cell_matrix_covers_40():
    cells = lm_cells(include_skipped=True)
    assert len(cells) == 40  # 10 archs x 4 shapes
    runnable = [c for c in cells if c[2] == "run"]
    # skips: long_500k for 6 full-attention archs + whisper enc-dec
    assert len(runnable) == 33
    for arch, shape, status in cells:
        if shape == "long_500k" and status == "run":
            assert arch in ("zamba2-2.7b", "rwkv6-1.6b", "mixtral-8x7b")


def test_moe_active_params():
    mix = get_lm_config("mixtral-8x7b")
    assert mix.active_param_count() < 0.35 * mix.param_count()
    ds = get_lm_config("deepseek-v2-lite-16b")
    assert ds.active_param_count() < 0.25 * ds.param_count()


def test_gcn_workloads_registered():
    assert len(list_gcn_archs()) == 24  # 3 models x 8 graphs
    cfg = get_gcn_config("gcn-gcn-rd")
    assert cfg.graph.avg_degree == 489.0
    assert cfg.graph.feat_in == 602


def test_shapes_table():
    assert LM_SHAPES["train_4k"].global_batch == 256
    assert LM_SHAPES["long_500k"].seq_len == 524_288
    assert LM_SHAPES["decode_32k"].kind == "decode"
