"""Attention correctness: blockwise vs naive oracle, GQA, sliding window,
custom VJP, decode attention (scalar + vector positions)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ref as fa_ref
from repro.nn import attention as A

KEY = jax.random.PRNGKey(0)


def _qkv(B, Hkv, G, Sq, Skv, D, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, Sq, Hkv * G, D), dtype) * 0.5
    k = jax.random.normal(k2, (B, Skv, Hkv, D), dtype) * 0.5
    v = jax.random.normal(k3, (B, Skv, Hkv, D), dtype) * 0.5
    return q, k, v


@pytest.mark.parametrize("B,Hkv,G,S,D,window", [
    (2, 2, 2, 128, 32, 0),
    (1, 1, 4, 96, 16, 0),
    (2, 2, 1, 128, 32, 48),
    (1, 3, 2, 64, 8, 16),
])
def test_blockwise_matches_naive(B, Hkv, G, S, D, window):
    q, k, v = _qkv(B, Hkv, G, S, S, D)
    out = A.causal_attention(q, k, v, num_kv_heads=Hkv, window=window,
                             q_chunk=32, kv_chunk=32)
    qg = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    ref = fa_ref.attention_ref(qg, k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3),
                               scale=1.0 / np.sqrt(D), causal=True,
                               window=window)
    ref = ref.transpose(0, 3, 1, 2, 4).reshape(B, S, Hkv * G, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_blockwise_grads_match_naive():
    B, Hkv, G, S, D = 1, 2, 2, 64, 16
    q, k, v = _qkv(B, Hkv, G, S, S, D)
    qg = q.reshape(B, S, Hkv, G, D)

    def f_block(q, k, v):
        return (A.blockwise_attention(q, k, v, 0.25, True, 0, 16, 16, 0)
                ** 2).sum()

    def f_naive(q, k, v):
        qk = q.transpose(0, 2, 3, 1, 4)
        o = fa_ref.attention_ref(qk, k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3), scale=0.25,
                                 causal=True)
        return (o.transpose(0, 3, 1, 2, 4) ** 2).sum()

    g1 = jax.grad(f_block, argnums=(0, 1, 2))(qg, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(qg, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


def test_decode_attention_vector_pos():
    B, Hkv, G, S, D = 3, 2, 2, 32, 16
    _, k, v = _qkv(B, Hkv, G, 1, S, D)
    q = jax.random.normal(KEY, (B, 1, Hkv * G, D)) * 0.5
    pos = jnp.asarray([5, 17, 32])
    out_v = A.decode_attention(q, k, v, pos, num_kv_heads=Hkv)
    for i in range(B):
        out_s = A.decode_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                   pos[i], num_kv_heads=Hkv)
        np.testing.assert_allclose(np.asarray(out_v[i]), np.asarray(out_s[0]),
                                   atol=1e-5, rtol=1e-5)


def test_causal_flops_skip_upper_blocks():
    """The blockwise scan must enumerate ~half the blocks for causal."""
    pairs = A._block_pairs(8, 8, 64, 64, causal=True, window=0)
    assert len(pairs) == 36  # n(n+1)/2
    pairs_w = A._block_pairs(8, 8, 64, 64, causal=True, window=64)
    assert len(pairs_w) < 36  # window prunes further
