"""Layer-major chunked inference (``repro.gcn.inference``): the
serving path for graphs whose full plan exceeds the cache budget.

Pins, in order of importance:

  * **bit-identity property test** — ``forward_layer_major`` equals
    full-graph ``forward`` EXACTLY (``np.array_equal``, not allclose)
    across models {gcn, gin, sage}, both aggregation backends, chunk
    sizes {64, 128, V} and serial vs pipelined preparation — the fp32
    scatter-add order argument in the module docstring, made load
    bearing;
  * **bounded working set** — on a sparse graph the device-resident
    feature high-water mark stays under what full-graph forward
    allocates, and a store-handle input never triggers ``gather_all``;
  * **over-budget admission** — a graph whose plan bytes provably
    exceed ``set_cache_budget(plan_bytes=...)`` is admitted by
    ``GCNService(admission="auto")`` and served bit-identically to an
    unbudgeted full forward, with the full plan NEVER built (the
    acceptance pin for the serve bench record);
  * **eval path scaling** — ``fit_sampled(eval_every=...)`` on an
    over-budget graph evaluates layer-major; the full-batch plan is
    still never built (the PR-5 guarantee extended to eval);
  * **cache-key hygiene** — chunk sub-plans live in the ``batch``
    cache layer under ``"chunk:{parent_fp}:{sha1}"`` keys: chunks and
    trainer batches never cross-hit, and two parents sharing a chunk
    node set never share a sub-plan (edge-direction regression);
  * **eviction mid-inference benign** — a batch budget too small for
    all chunk sub-plans forces rebuilds, never wrong bits.

Runs in-process on the 1-CPU view (mesh ``(1, 1)``); the 8-device
layer-major parity case lives in ``tests/_gcn_engine_main.py``.
"""
import numpy as np

from _hypothesis_compat import given, settings, strategies as st

V, E, F, C = 256, 2048, 8, 4

# full-forward references, memoized per (model, impl): gcn_setup's
# engines/params/features are deterministic per seed, so one oracle
# serves every property example
_FULL_REFS: dict = {}


def _full_ref(eng, feats, model, impl):
    key = (model, impl)
    if key not in _FULL_REFS:
        _FULL_REFS[key] = np.asarray(eng.forward(feats, agg_impl=impl))
    return _FULL_REFS[key]


@settings(max_examples=8, deadline=None)
@given(model=st.sampled_from(["gcn", "gin", "sage"]),
       impl=st.sampled_from(["jnp", "pallas"]),
       chunk=st.sampled_from([64, 128, V]),
       depth=st.sampled_from([0, 2]))
def test_layer_major_bit_identical_to_full(fresh_caches, gcn_setup,
                                           model, impl, chunk, depth):
    """THE contract: layer-major output equals full-graph forward
    bit-for-bit for every (model, backend, chunk size, pipelining)
    combination — including chunk == V (one chunk spanning the graph)
    and depth 0 (serial preparation)."""
    eng, feats, _, _ = gcn_setup(model)
    ref = _full_ref(eng, feats, model, impl)
    out = eng.forward_layer_major(feats, agg_impl=impl, chunk_size=chunk,
                                  pipeline_depth=depth)
    assert out.shape == ref.shape and out.dtype == ref.dtype
    assert np.array_equal(out, ref), (model, impl, chunk, depth)
    st_ = eng.inference_stats()
    assert st_["inference_chunks"] == -(-V // chunk)
    # rerun is a pure sub-plan cache hit and still exact
    again = eng.forward_layer_major(feats, agg_impl=impl,
                                    chunk_size=chunk,
                                    pipeline_depth=depth)
    assert np.array_equal(again, ref)
    st2 = eng.inference_stats()
    assert st2["chunk_plan_misses"] == 0  # rerun: pure sub-plan hits
    assert st2["chunk_plan_hits"] > 0


def test_peak_feature_bytes_bounded_store_routed(fresh_caches, gcn_cfg):
    """On a sparse graph the chunked schedule's device feature
    high-water mark stays strictly under the full-forward dense
    allocation, and a FeatureHandle input gathers per chunk through
    the store — ``gather_all`` is never called."""
    import jax

    from repro.core.rmat import rmat
    from repro.gcn import GCNEngine, featurestore

    g = rmat(12, 8192, seed=7, name="sparse-infer")
    eng = GCNEngine.build(gcn_cfg("gcn"), g, (1, 1))
    eng.init_params(jax.random.PRNGKey(0), [F, 8, C])
    feats = (np.random.default_rng(3)
             .normal(size=(g.num_vertices, F)).astype(np.float32))
    handle = featurestore.default_store().register(g, feats)
    ref = np.asarray(eng.forward(feats))  # dense input: store untouched

    out = eng.forward_layer_major(handle, chunk_size=128)
    assert np.array_equal(out, ref)
    st_ = eng.inference_stats()
    assert 0 < st_["peak_feature_bytes"] < st_["dense_feature_bytes"]
    assert handle.stats()["full_gathers"] == 0
    assert st_["chunk_bucket_hit_rate"] > 0.5  # pow2 buckets shared


def test_overbudget_graph_admitted_and_served_layer_major(fresh_caches,
                                                          gcn_cfg):
    """The acceptance pin: a graph whose plan provably exceeds
    ``set_cache_budget(plan_bytes=...)`` is admitted under
    ``admission="auto"``, served bit-identically to an UNBUDGETED
    full-graph forward, at bounded peak bytes with overlap won — and
    the session's full plan is never built."""
    import jax

    from repro.core.rmat import rmat
    from repro.gcn import GCNEngine, GCNService, cache

    cfg = gcn_cfg("gcn")
    g = rmat(12, 8192, seed=7, name="overbudget-serve")
    x = (np.random.default_rng(3)
         .normal(size=(g.num_vertices, F)).astype(np.float32))

    ref_eng = GCNEngine.build(cfg, g, (1, 1))
    params = ref_eng.init_params(jax.random.PRNGKey(0), [F, 8, C])
    ref = np.asarray(ref_eng.forward(x, params))
    cache.clear_all()

    cache.set_cache_budget(plan_bytes=64 << 10)  # < 12 * (E + V)
    svc = GCNService((1, 1), admission="auto", chunk_size=128)
    svc.admit("big", cfg, g, layer_dims=[F, 8, C], seed=0)
    assert svc.session_mode("big") == "layer-major"
    eng = svc.sessions["big"]
    eng.params = params  # align with the oracle's init
    assert not eng.plan_cached and eng._plan is None

    r = svc.submit("big", x)
    svc.run()
    assert r.done and np.array_equal(r.out, ref)
    assert eng._plan is None and not eng.plan_cached  # still never built
    st_ = svc.stats()
    assert st_["admission"] == "auto"
    assert st_["sessions_layer_major"] == 1
    assert 0 < st_["peak_feature_bytes"] < st_["dense_feature_bytes"]
    assert st_["inference_overlap_fraction"] > 0
    assert st_["chunk_bucket_hit_rate"] > 0


def test_forced_admission_modes(fresh_caches, gcn_cfg, erdos_graph):
    """``admission="layer-major"`` chunks even an in-budget graph;
    ``admission="full"`` never does; both serve identical bits."""
    from repro.gcn import GCNService

    g = erdos_graph(V, E, seed=7)
    x = (np.random.default_rng(1)
         .normal(size=(V, F)).astype(np.float32))
    outs = {}
    for adm in ("full", "layer-major"):
        svc = GCNService((1, 1), admission=adm, chunk_size=64)
        svc.admit("g", gcn_cfg("gcn"), g, layer_dims=[F, 8, C], seed=0)
        assert svc.session_mode("g") == adm
        r = svc.submit("g", x)
        svc.run()
        outs[adm] = r.out
    assert np.array_equal(outs["full"], outs["layer-major"])


def test_eval_during_fit_sampled_never_builds_full_plan(fresh_caches,
                                                        gcn_cfg):
    """Satellite-2 pin: on an over-budget graph,
    ``fit_sampled(eval_every=1)`` records eval loss/accuracy every
    epoch via the layer-major path — and the full-batch plan is STILL
    never built, extending PR 5's training guarantee to evaluation."""
    from repro.core.rmat import rmat
    from repro.gcn import GCNEngine, GCNTrainer, cache

    g = rmat(12, 8192, seed=7, name="overbudget-eval")
    Vb = g.num_vertices
    rng = np.random.default_rng(0)
    x = rng.normal(size=(Vb, F)).astype(np.float32)
    labels = rng.integers(0, C, size=Vb).astype(np.int32)
    mask = (rng.random(Vb) < 0.1).astype(np.float32)

    cache.set_cache_budget(plan_bytes=64 << 10)
    eng = GCNEngine.build(gcn_cfg("gcn"), g, (1, 1))
    tr = GCNTrainer(eng, labels, mask)
    rep = tr.fit_sampled(x, epochs=2, batch_size=64, fanouts=(4, 4),
                         layer_dims=[F, 8, C], seed=0, eval_every=1)
    assert eng._plan is None and not eng.plan_cached
    evals = [h for h in rep.history if "eval_loss" in h]
    assert len(evals) == 2
    assert all(np.isfinite(h["eval_loss"]) for h in evals)
    assert eng.inference_stats()["inference_chunks"] > 0

    # forcing the two modes on the SAME params agrees exactly
    cache.set_cache_budget(plan_bytes=None)
    assert tr.evaluate(x, mode="full") == tr.evaluate(x,
                                                      mode="layer-major")


def test_chunk_and_batch_cache_keys_never_cross_hit(fresh_caches,
                                                    gcn_setup):
    """Satellite-6 regression: chunk sub-plans and the trainer's
    sampled-batch sub-plans share the byte-bounded ``batch`` layer but
    live in disjoint key namespaces (``chunk:`` vs ``batch:`` graph-fp
    prefixes) — running both on one graph adds entries, never
    cross-hits, and reruns of each are pure hits."""
    from repro.gcn import GCNTrainer, cache

    eng, feats, labels, mask = gcn_setup("gcn")
    params0 = eng.params  # fit_sampled trains in place; pin the oracle
    ref = np.asarray(eng.forward(feats, params0))

    out = eng.forward_layer_major(feats, params0, chunk_size=64)
    assert np.array_equal(out, ref)
    s1 = cache.cache_stats()["batch"]
    n_chunks = s1["entries"]
    assert n_chunks == V // 64 and s1["misses"] == n_chunks
    assert s1["hits"] == n_chunks  # layer 1 reused layer 0's sessions

    tr = GCNTrainer(eng, labels, mask)
    tr.fit_sampled(feats, epochs=1, batch_size=64, fanouts=(4, 4),
                   layer_dims=[F, 8, C], seed=0)
    s2 = cache.cache_stats()["batch"]
    assert s2["entries"] > n_chunks  # batches did NOT reuse chunk slots

    # rerunning inference hits every chunk entry, misses nothing
    assert np.array_equal(
        eng.forward_layer_major(feats, params0, chunk_size=64), ref)
    s3 = cache.cache_stats()["batch"]
    assert s3["entries"] == s2["entries"]
    assert s3["misses"] == s2["misses"]
    assert s3["hits"] == s2["hits"] + 2 * n_chunks  # both layers hit


def test_chunk_keys_distinguish_parent_graphs(fresh_caches, gcn_cfg):
    """Two parents can induce the SAME chunk node set (here: one edge,
    opposite directions, both endpoints inside the chunk) — the parent
    fingerprint in the ``chunk:{parent_fp}:{sha1}`` key must keep
    their sub-plans apart, or the second graph would silently serve
    the first graph's aggregation."""
    import jax

    from repro.core.graph import Graph
    from repro.gcn import GCNEngine, cache
    from repro.gcn import inference

    Vs = 64
    g1 = Graph(Vs, np.array([5], np.int32), np.array([6], np.int32),
               name="fwd-edge")
    g2 = Graph(Vs, np.array([6], np.int32), np.array([5], np.int32),
               name="rev-edge")
    engines = []
    for g in (g1, g2):
        e = GCNEngine.build(gcn_cfg("gcn"), g, (1, 1))
        e.init_params(jax.random.PRNGKey(0), [F, C])
        engines.append(e)
    e1, e2 = engines
    x = (np.random.default_rng(2)
         .normal(size=(Vs, F)).astype(np.float32))

    # identical chunk node sets...
    cs1 = e1.forward_layer_major(x, chunk_size=Vs)
    cs2 = e2.forward_layer_major(x, chunk_size=Vs)
    assert cache.cache_stats()["batch"]["entries"] == 2  # ...two plans
    # ...and each matches ITS OWN full forward (a collision would make
    # g2 reuse g1's plan and fail this exactness)
    assert np.array_equal(cs1, np.asarray(e1.forward(x)))
    assert np.array_equal(cs2, np.asarray(e2.forward(x)))
    assert not np.array_equal(cs1, cs2)

    ch1 = inference._chunk_session(e1, 0, Vs,
                                   inference._chunk_nodes(
                                       *inference._prepared_csr(e1)[:2],
                                       0, Vs))
    assert ch1.engine.graph_fp.startswith("chunk:")


def test_eviction_mid_inference_is_benign(fresh_caches, gcn_setup):
    """A batch budget too small to hold every chunk sub-plan forces
    eviction + rebuild DURING inference — results stay bit-exact (the
    builds are pure and content-keyed), only the hit rate suffers."""
    from repro.gcn import cache

    eng, feats, _, _ = gcn_setup("gcn")
    ref = np.asarray(eng.forward(feats))
    one = cache.cache_stats()["batch"]["bytes"]  # 0: sizing probe below

    eng.forward_layer_major(feats, chunk_size=64)
    full_bytes = cache.cache_stats()["batch"]["bytes"]
    assert full_bytes > 0 and one == 0
    cache.set_cache_budget(batch_bytes=max(1, full_bytes // 2))
    assert cache.cache_stats()["batch"]["evictions"] > 0

    out = eng.forward_layer_major(feats, chunk_size=64)
    assert np.array_equal(out, ref)
    assert cache.cache_stats()["batch"]["evictions"] > 0
    # and again, still exact, still churning
    assert np.array_equal(eng.forward_layer_major(feats, chunk_size=64),
                          ref)
