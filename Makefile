PY ?= python

.PHONY: smoke test bench bench-json serve train train-sampled \
	train-cv docs-check trace-check check

# engine example + tier-1 tests, multi-device (8 forced host devices)
smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --suite smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

# multi-graph GCNService smoke bench (8 forced host devices); writes its
# record to a scratch path so the CI gate never churns the checked-in
# baseline
serve:
	PYTHONPATH=src $(PY) -m benchmarks.run --suite serve \
		--json /tmp/BENCH_gcn.json

# distributed GCN training smoke bench (grad through the exchange,
# GCN/GIN/SAGE on a 2x2 torus, train->serve handoff); scratch path for
# the same reason as `serve`
train:
	PYTHONPATH=src $(PY) -m benchmarks.run --suite train \
		--json /tmp/BENCH_gcn.json

# neighbor-sampled mini-batch training smoke bench (per-batch subgraph
# plans, batch-plan cache hit rate asserted > 0, feature-store hit rate
# asserted > 0.5 with gathered bytes below the dense baseline, sampling
# pipeline at PIPELINE_DEPTH with overlap > 0 and pipelined wall <=
# serial wall asserted); scratch path as above
PIPELINE_DEPTH ?= 2
train-sampled:
	PYTHONPATH=src $(PY) -m benchmarks.run --suite train-sampled \
		--pipeline-depth $(PIPELINE_DEPTH) \
		--json /tmp/BENCH_gcn.json

# control-variate sampled-training gate: fanout-2 CV must move strictly
# fewer exchange bytes per step than plain fanout-8 at matched (+-2%)
# train accuracy, with the pipelined CV trajectory asserted
# bit-identical to serial (tracing on); scratch path as above
train-cv:
	PYTHONPATH=src $(PY) -m benchmarks.run --suite train-cv \
		--json /tmp/BENCH_gcn.json

# machine-readable perf trajectory: refresh ALL suite records in
# BENCH_gcn.json in place so PRs can diff serve + train perf against
# the checked-in baseline
bench-json:
	PYTHONPATH=src $(PY) -m benchmarks.run --suite serve \
		--json BENCH_gcn.json
	PYTHONPATH=src $(PY) -m benchmarks.run --suite train \
		--json BENCH_gcn.json
	PYTHONPATH=src $(PY) -m benchmarks.run --suite train-sampled \
		--json BENCH_gcn.json
	PYTHONPATH=src $(PY) -m benchmarks.run --suite train-cv \
		--json BENCH_gcn.json

# execute every fenced ```python block in README.md and docs/*.md
docs-check:
	PYTHONPATH=src $(PY) tools/check_docs.py

# observability gate: a small pipelined sampled-training run with
# --trace-out, then tools/check_trace.py proves the Chrome trace is
# well-formed AND that gcn-pipe prepare spans overlap main-thread
# execute spans (the checker's own fixtures run first)
trace-check:
	PYTHONPATH=src $(PY) tools/check_trace.py --selftest
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	PYTHONPATH=src $(PY) -m repro.launch.gcn_train --mesh 2x2 \
		--models gcn --scale 9 --epochs 6 --sampler \
		--batch-size 128 --fanout 8,8 --pipeline-depth 2 \
		--trace-out /tmp/gcn_trace.json
	PYTHONPATH=src $(PY) tools/check_trace.py /tmp/gcn_trace.json \
		--require-overlap

# the CI-style gate: everything a PR must keep green
check: smoke serve train train-sampled train-cv trace-check docs-check
