PY ?= python

.PHONY: smoke test bench docs-check check

# engine example + tier-1 tests, multi-device (8 forced host devices)
smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --suite smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

# execute every fenced ```python block in README.md and docs/*.md
docs-check:
	PYTHONPATH=src $(PY) tools/check_docs.py

# the CI-style gate: everything a PR must keep green
check: smoke docs-check
