PY ?= python

.PHONY: smoke test bench

# engine example + tier-1 tests, multi-device (8 forced host devices)
smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --suite smoke

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run
